"""Shared benchmark substrate: a tiny needle-retrieval model pre-trained on
CPU, with distilled write-gates — the stand-in for Llama-3.1-8B + FineWeb
in the offline container (DESIGN.md §7). Trained once, cached to
benchmarks/artifacts/.
"""
from __future__ import annotations

import functools
import os
import time
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, WGKVConfig
from repro.data.synthetic import lm_loss, needle_task
from repro.models import transformer as T
from repro.training import checkpoint as C
from repro.training import trainer as TR
from repro.training.optimizer import adamw_init, adamw_update, cosine_schedule

ART = os.path.join(os.path.dirname(__file__), "artifacts")
VOCAB = 256
SEQ = 128      # needles live in the first 55% => always > W_LOCAL from the query
W_LOCAL = 16


def bench_cfg(**wg) -> ModelConfig:
    wk = dict(enabled=True, w_local=W_LOCAL, tau=0.1, gate_hidden=32,
              global_budget_frac=1.0, sink=2, lam=0.1)
    wk.update(wg)
    return ModelConfig(
        name="bench-tiny", arch_type="dense", d_model=128, n_heads=4,
        n_kv_heads=2, head_dim=32, d_ff=256, vocab_size=VOCAB,
        block_pattern=("attn",), n_repeats=2, rope_theta=10000.0,
        dtype="float32", wgkv=WGKVConfig(**wk))


def _pretrain(cfg: ModelConfig, steps: int = 2000) -> Dict:
    """Train the teacher until induction-head retrieval emerges (the
    circuit needs ~1-2k steps at this scale; weight decay off helps)."""
    key = jax.random.PRNGKey(0)
    params = T.init_model(key, cfg)
    opt = adamw_init(params)
    lr = cosine_schedule(2e-3, steps)

    @jax.jit
    def step(params, opt, toks, mask, i):
        def loss_fn(p):
            out = T.forward(p, cfg, toks, mode="teacher")
            return lm_loss(out.logits, toks) + 4.0 * lm_loss(out.logits,
                                                             toks, mask)
        g = jax.grad(loss_fn)(params)
        return adamw_update(g, opt, params, lr=lr(i), weight_decay=0.0)

    for i in range(steps):
        b = needle_task(jax.random.PRNGKey(i + 1), 16, SEQ, VOCAB, payload=2)
        params, opt = step(params, opt, b["tokens"], b["loss_mask"], i)
    return params


def _distill(cfg: ModelConfig, params, lam: float, steps: int = 150):
    state = TR.init_train_state(params)
    step = TR.make_train_step(cfg, lr=cosine_schedule(2e-3, steps), lam=lam)
    for i in range(steps):
        b = needle_task(jax.random.PRNGKey(10_000 + i), 4, SEQ, VOCAB,
                        payload=2)
        state, m = step(state, params, batch={"tokens": b["tokens"]})
    return TR.set_gates(params, state.gates), m


@functools.lru_cache(maxsize=1)
def trained_model(lam: float = 0.15) -> Tuple[ModelConfig, Dict]:
    """Teacher + distilled gates, cached on disk across benchmark runs."""
    cfg = bench_cfg(lam=lam)
    path = os.path.join(ART, f"bench_model_lam{lam}.npz")
    key = jax.random.PRNGKey(0)
    like = T.init_model(key, cfg)
    if os.path.exists(path):
        return cfg, C.restore(path, like)
    params = _pretrain(cfg)
    params, _ = _distill(cfg, params, lam)
    os.makedirs(ART, exist_ok=True)
    C.save(path, params, meta={"lam": lam, "vocab": VOCAB, "seq": SEQ})
    return cfg, params


def needle_accuracy(cfg: ModelConfig, params, *, mode: str = "hard",
                    n: int = 32, seed: int = 777,
                    gate_override_fn=None) -> float:
    b = needle_task(jax.random.PRNGKey(seed), n, SEQ, VOCAB, payload=2)
    out = T.forward(params, cfg, b["tokens"], mode=mode)
    qpos = int(b["query_pos"])
    pred = jnp.argmax(out.logits[:, qpos:qpos + 2], -1)
    return float((np.asarray(pred) == np.asarray(b["answer"])).mean())


def cache_size_at(cfg: ModelConfig, params, tau: float, n: int = 16,
                  seed: int = 778) -> float:
    """Mean normalized KV cache size (admitted + window) / full."""
    b = needle_task(jax.random.PRNGKey(seed), n, SEQ, VOCAB, payload=2)
    out = T.forward(params, cfg, b["tokens"], mode="gated")
    adm = (out.gates >= tau).mean()
    return float(min(float(adm) + cfg.wgkv.w_local / SEQ, 1.0))


def timeit(fn, *args, warmup: int = 1, iters: int = 5) -> float:
    """Median wall time per call in microseconds (blocking on device)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)
