"""Fig. 12 (Appendix G) — necessity of the Local Cache.

Retrain gates with w_local=1 (no grace period: immediate admit-or-drop)
and compare the loss-memory point against the full dual-cache design.
Expected: marked degradation without the local window ("transient
utility" hypothesis)."""
from __future__ import annotations

import functools

from benchmarks.common import (bench_cfg, _distill, cache_size_at,
                               needle_accuracy, trained_model)


@functools.lru_cache(maxsize=1)
def _no_local_model(lam: float = 0.15):
    cfg = bench_cfg(lam=lam, w_local=1)
    _, base = trained_model()
    params, m = _distill(cfg, base, lam, steps=120)
    return cfg, params


def run():
    rows = []
    cfg_full, params_full = trained_model()
    cfg_nl, params_nl = _no_local_model()
    for tau in (0.05, 0.2, 0.5):
        import dataclasses as dc

        a_full = needle_accuracy(
            cfg_full.replace(wgkv=dc.replace(cfg_full.wgkv, tau=tau)),
            params_full, mode="hard")
        s_full = cache_size_at(cfg_full, params_full, tau)
        a_nl = needle_accuracy(
            cfg_nl.replace(wgkv=dc.replace(cfg_nl.wgkv, tau=tau)),
            params_nl, mode="hard")
        s_nl = cache_size_at(cfg_nl, params_nl, tau)
        rows.append((f"fig12/full_tau{tau}", 0.0,
                     f"cache={s_full:.3f},acc={a_full:.3f}"))
        rows.append((f"fig12/no_local_tau{tau}", 0.0,
                     f"cache={s_nl:.3f},acc={a_nl:.3f}"))
    return rows
