"""Serving-throughput benchmark: continuous-batching orchestrator over the
tiny bench substrate — requests/s, mean TTFT, mean TPOT, and paged-pool
utilization under a synthetic multi-request arrival burst.

Emits CSV rows for benchmarks.run and writes ``BENCH_serving.json`` so the
serving perf trajectory is tracked across PRs.
"""
from __future__ import annotations

import json
import os

import jax

from benchmarks.common import bench_cfg, timeit  # noqa: F401 (harness)
from repro.models import transformer as T
from repro.serving.engine import Engine
from repro.serving.orchestrator import Orchestrator, SchedulerConfig

N_REQUESTS = 12
PROMPT_LEN = 96
MAX_NEW = 16
SLOTS = 4
CHUNK = 32
CAPACITY = 192

JSON_PATH = os.environ.get("BENCH_SERVING_JSON", "BENCH_serving.json")


def _prompts(n: int, vocab: int, seed: int = 0):
    key = jax.random.PRNGKey(seed)
    out = []
    for _ in range(n):
        key, k = jax.random.split(key)
        out.append(jax.random.randint(k, (PROMPT_LEN,), 0, vocab - 8).tolist())
    return out


def _serve(eng: Engine, prompts) -> Orchestrator:
    orch = Orchestrator(eng, sched=SchedulerConfig(chunk_tokens=CHUNK))
    for p in prompts:
        orch.submit(p, max_new=MAX_NEW)
    orch.run()
    return orch


def run():
    cfg = bench_cfg()
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    eng = Engine(params, cfg, slots=SLOTS, capacity=CAPACITY)
    # warmup: compile prefill/extend/decode shapes on the same engine (the
    # jit caches live on the engine's partials), then measure a fresh burst
    _serve(eng, _prompts(SLOTS, cfg.vocab_size, seed=99))
    orch = _serve(eng, _prompts(N_REQUESTS, cfg.vocab_size, seed=1))

    s = orch.telemetry.summary()
    record = {
        "requests": s["requests"],
        "requests_per_s": s["requests_per_s"],
        "tokens_per_s": s["tokens_per_s"],
        "mean_ttft_s": s["ttft_mean_s"],
        "mean_tpot_s": s["tpot_mean_s"],
        "pool_utilization": s["pool_util_mean"],
        "mean_admission": s["mean_admission"],
        "decode_steps": s["counters"]["decode_steps"],
        "prefill_chunks": s["counters"]["prefill_chunks"],
    }
    with open(JSON_PATH, "w") as fh:
        json.dump(record, fh, indent=2)

    wall_us = (s["wall_s"] or 0.0) * 1e6
    rows = [
        ("serving/burst", wall_us,
         f"req_per_s={s['requests_per_s']:.2f}"),
        ("serving/ttft_mean", (s["ttft_mean_s"] or 0.0) * 1e6,
         f"p90={(s['ttft_p90_s'] or 0.0) * 1e3:.1f}ms"),
        ("serving/tpot_mean", (s["tpot_mean_s"] or 0.0) * 1e6,
         f"tok_per_s={s['tokens_per_s']:.1f}"),
        ("serving/pool_util", 0.0,
         f"util={s['pool_util_mean']:.3f} "
         f"pages_peak={s['pool_pages_peak']}"),
        ("serving/json", 0.0, JSON_PATH),
    ]
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
