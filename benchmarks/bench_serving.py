"""Serving A/B benchmark: replay one recorded arrival trace through each
requested engine backend (WG-KV, dense full-KV, static admission) under
the same continuous-batching orchestrator, and emit per-backend
throughput, TTFT/TPOT percentiles, and peak KV/paged-pool memory.

This is the paper's headline comparison (46-68% memory reduction,
1.85-2.56x decode speedup vs full-KV) recast as a regression-tracked
serving scenario: identical traffic, identical scheduler, only the cache
policy behind the ``EngineBackend`` protocol changes.

    PYTHONPATH=src python -m benchmarks.bench_serving \
        --backends wgkv,dense [--smoke] [--arrival poisson:0.5] [--mesh 2x4]

Arrival processes: the default ``burst`` trace scatters arrivals over the
first ``n`` scheduler ticks; ``poisson:<rate>`` draws i.i.d. exponential
inter-arrival gaps (``rate`` = mean arrivals per tick), the open-loop
traffic model the roadmap's latency-SLO tracking needs — p50/p99 TTFT per
backend land in BENCH_serving.json either way.

With ``--mesh dxm`` every backend runs its jitted decode/extend SPMD over
a ("data", "model") device mesh (serving/sharded.py); on a dev box use
the debug recipe ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.

Emits CSV rows for benchmarks.run and writes ``BENCH_serving.json``
(``{"trace": ..., "backends": {name: metrics}, "ab": ratios-vs-dense}``)
so the serving trajectory is tracked across PRs.
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List, Optional, Sequence

import jax

from benchmarks.common import trained_model
from repro.serving.backend import BACKEND_NAMES, make_backend
from repro.serving.orchestrator import Orchestrator, SchedulerConfig
from repro.serving.sharded import build_mesh

N_REQUESTS = 12
PROMPT_LEN = 96
MAX_NEW = 16
SLOTS = 4
CHUNK = 32
CAPACITY = 192
SMOKE = dict(n_requests=4, prompt_len=48, max_new=4)

JSON_PATH = os.environ.get("BENCH_SERVING_JSON", "BENCH_serving.json")


def poisson_rate(arrival: str) -> Optional[float]:
    """Validate an arrival spec; returns the rate for ``poisson:<rate>``
    (mean arrivals per scheduler tick), None for ``burst``."""
    if arrival == "burst":
        return None
    if arrival.startswith("poisson:"):
        try:
            rate = float(arrival.split(":", 1)[1])
        except ValueError:
            raise ValueError(f"bad poisson rate in {arrival!r}") from None
        if rate <= 0:
            raise ValueError(f"poisson rate must be > 0, got {rate}")
        return rate
    raise ValueError(
        f"arrival must be 'burst' or 'poisson:<rate>', got {arrival!r}")


def record_trace(n: int, vocab: int, *, prompt_len: int, max_new: int,
                 seed: int = 1, arrival: str = "burst") -> List[Dict]:
    """Deterministic arrival trace: each request carries a prompt and an
    arrival tick (scheduler rounds since t0). Every backend replays the
    SAME trace, so latency/throughput deltas are attributable to the cache
    policy alone.

    ``arrival="burst"`` scatters all arrivals uniformly over the first
    ``n`` ticks (closed burst); ``arrival="poisson:<rate>"`` draws
    exponential inter-arrival gaps with mean ``1/rate`` ticks — an
    open-loop Poisson process, the traffic model TTFT tail percentiles
    are meaningful under."""
    rate = poisson_rate(arrival)
    key = jax.random.PRNGKey(seed)
    out = []
    t = 0.0
    for i in range(n):
        key, kp, ka = jax.random.split(key, 3)
        prompt = jax.random.randint(kp, (prompt_len,), 0, vocab - 8).tolist()
        if rate is None:
            tick = int(jax.random.randint(ka, (), 0, max(1, n)))
        else:
            t += float(jax.random.exponential(ka)) / rate
            tick = int(t)
        out.append({"arrival_tick": tick, "prompt": prompt,
                    "max_new": max_new})
    out.sort(key=lambda r: r["arrival_tick"])
    return out


def replay(eng, trace: List[Dict], *, chunk: int = CHUNK) -> Orchestrator:
    """Replay a recorded trace: submit each request at its arrival tick,
    tick the orchestrator until drained."""
    orch = Orchestrator(eng, sched=SchedulerConfig(chunk_tokens=chunk))
    pending = list(trace)
    tick = 0
    while pending or not orch.queue.all_done():
        while pending and pending[0]["arrival_tick"] <= tick:
            r = pending.pop(0)
            orch.submit(r["prompt"], max_new=r["max_new"])
        orch.tick()
        tick += 1
        if tick > 100_000:
            raise RuntimeError("trace replay did not drain")
    orch.telemetry.stop()
    return orch


def _backend_record(s: Dict) -> Dict:
    return {
        "requests": s["requests"],
        "requests_per_s": s["requests_per_s"],
        "tokens_per_s": s["tokens_per_s"],
        "ttft_mean_s": s["ttft_mean_s"],
        "ttft_p50_s": s["ttft_p50_s"],
        "ttft_p90_s": s["ttft_p90_s"],
        "ttft_p99_s": s["ttft_p99_s"],
        "tpot_mean_s": s["tpot_mean_s"],
        "tpot_p50_s": s["tpot_p50_s"],
        "tpot_p90_s": s["tpot_p90_s"],
        "mean_admission": s["mean_admission"],
        "mean_admission_decode": s["mean_admission_decode"],
        "pool_utilization": s["pool_util_mean"],
        "pool_pages_peak": s["pool_pages_peak"],
        "kv_tokens_peak": s["kv_tokens_peak"],
        "kv_bytes_peak": s["kv_bytes_peak"],
        "kv_bytes_per_shard_peak": s["kv_bytes_per_shard_peak"],
        "decode_steps": s["counters"]["decode_steps"],
        "prefill_chunks": s["counters"]["prefill_chunks"],
    }


def run(backends: Optional[Sequence[str]] = None, smoke: bool = False,
        arrival: str = "burst", mesh: Optional[str] = None):
    names = tuple(backends) if backends else ("wgkv", "dense")
    for n in names:
        if n not in BACKEND_NAMES:
            raise ValueError(f"unknown backend {n!r}; known: {BACKEND_NAMES}")
    poisson_rate(arrival)       # validate both before any model work:
    dev_mesh = build_mesh(mesh)  # missing devices must fail fast, not after
    n_req, plen, mnew = ((SMOKE["n_requests"], SMOKE["prompt_len"],
                          SMOKE["max_new"]) if smoke
                         else (N_REQUESTS, PROMPT_LEN, MAX_NEW))
    # the distilled bench substrate (pretrained teacher + trained write
    # gates): with random-init gates every token passes tau and the memory
    # A/B axis degenerates to 1.0
    cfg, params = trained_model()
    trace = record_trace(n_req, cfg.vocab_size, prompt_len=plen,
                         max_new=mnew, seed=1, arrival=arrival)
    warmup = record_trace(SLOTS, cfg.vocab_size, prompt_len=plen,
                          max_new=2, seed=99)
    record: Dict = {
        "trace": {"requests": n_req, "prompt_len": plen, "max_new": mnew,
                  "arrival": arrival, "mesh": mesh,
                  "arrival_ticks": [r["arrival_tick"] for r in trace],
                  "smoke": smoke},
        "backends": {},
    }
    rows = []
    for name in names:
        eng = make_backend(name, params, cfg, slots=SLOTS, capacity=CAPACITY,
                           mesh=dev_mesh)
        paged = eng.capabilities().paged
        # the timed replay runs with the host-side paged mirror OFF so the
        # throughput/latency A/B isolates the cache policy; mirroring cost
        # is measured separately below
        if paged:
            eng.mirror = False
        # warmup: compile prefill/extend/decode shapes on the same engine
        # (the jit caches live on the engine's partials), then replay the
        # measured trace fresh
        replay(eng, warmup)
        orch = replay(eng, trace)
        s = orch.telemetry.summary()
        rec = _backend_record(s)
        if paged:
            # second replay on the warm engine with mirroring ON: physical
            # pool telemetry (pages peak / utilization), kept out of the
            # timed numbers above
            eng.mirror = True
            s2 = replay(eng, trace).telemetry.summary()
            rec["pool_utilization"] = s2["pool_util_mean"]
            rec["pool_pages_peak"] = s2["pool_pages_peak"]
        record["backends"][name] = rec
        rows += [
            (f"serving/{name}/trace", (s["wall_s"] or 0.0) * 1e6,
             f"req_per_s={s['requests_per_s']:.2f}"),
            (f"serving/{name}/ttft_mean", (s["ttft_mean_s"] or 0.0) * 1e6,
             f"p90={(s['ttft_p90_s'] or 0.0) * 1e3:.1f}ms"),
            (f"serving/{name}/tpot_mean", (s["tpot_mean_s"] or 0.0) * 1e6,
             f"tok_per_s={s['tokens_per_s']:.1f}"),
            (f"serving/{name}/memory", 0.0,
             f"kv_tokens_peak={rec['kv_tokens_peak']} "
             f"pool_pages_peak={rec['pool_pages_peak']}"),
        ]
    # comparative ratios vs the dense full-KV baseline: the paper's
    # speedup and memory-reduction claims as serving-level numbers
    dense = record["backends"].get("dense")
    if dense:
        record["ab"] = {}
        for name, r in record["backends"].items():
            if name == "dense":
                continue
            ab = {}
            if r["tokens_per_s"] and dense["tokens_per_s"]:
                ab["decode_speedup_vs_dense"] = (
                    r["tokens_per_s"] / dense["tokens_per_s"])
            if r["kv_tokens_peak"] and dense["kv_tokens_peak"]:
                ab["kv_memory_frac_of_dense"] = (
                    r["kv_tokens_peak"] / dense["kv_tokens_peak"])
            record["ab"][name] = ab
            rows.append((f"serving/ab/{name}", 0.0,
                         " ".join(f"{k}={v:.3f}" for k, v in ab.items())
                         or "n/a"))
    with open(JSON_PATH, "w") as fh:
        json.dump(record, fh, indent=2)
    rows.append(("serving/json", 0.0, JSON_PATH))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--backends", default="wgkv,dense",
                    help="comma-separated subset of " + ",".join(BACKEND_NAMES))
    ap.add_argument("--smoke", action="store_true",
                    help="tiny trace (CI/headless A/B path check)")
    ap.add_argument("--arrival", default="burst",
                    help="arrival process: burst | poisson:<rate> "
                         "(mean arrivals per scheduler tick)")
    ap.add_argument("--mesh", default=None, metavar="DxM",
                    help="data x model mesh for SPMD decode, e.g. 2x4 "
                         "(debug: XLA_FLAGS="
                         "--xla_force_host_platform_device_count=8)")
    args = ap.parse_args()
    for r in run(backends=args.backends.split(","), smoke=args.smoke,
                 arrival=args.arrival, mesh=args.mesh):
        print(",".join(str(x) for x in r))


if __name__ == "__main__":
    main()
