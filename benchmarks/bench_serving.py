"""Serving A/B benchmark: replay one recorded arrival trace through each
requested engine backend (WG-KV, dense full-KV, static admission) under
the same continuous-batching stack, and emit per-backend throughput,
TTFT/TPOT percentiles, and peak KV/paged-pool memory.

This is the paper's headline comparison (46-68% memory reduction,
1.85-2.56x decode speedup vs full-KV) recast as a regression-tracked
serving scenario: identical traffic, identical scheduler, only the cache
policy behind the ``EngineBackend`` protocol changes.

    PYTHONPATH=src python -m benchmarks.bench_serving \
        --backends wgkv,dense [--smoke] [--arrival poisson:0.5] \
        [--mesh 2x4] [--slo-tolerance 0.25] [--trace-out trace.json]

Drivers replaying every trace (the scheduler tick is always the fused
megabatch call — ONE jitted ragged device call per tick advancing every
live request: first chunks, mid-prefill extends, and decode rows
together, with in-jit sampling):

  * the **async** driver (``ServeSession``, ``dispatch_ahead=1``) — the
    production path and the source of each backend's headline metrics;
  * the **synchronous** baseline (``dispatch_ahead=0``) — recorded as
    ``sync_tokens_per_s`` with the ratio ``async_speedup_vs_sync``, so
    the overlap the two-phase surface buys is regression-tracked;
  * the **selection A/B** (paged backends): per-K engines built with
    ``selection="quest:K"`` replay the same trace, so decode-only ticks
    score global pages against the live query (incremental per-page key
    min/max metadata) and attend over only the gathered top-K pages.
    ``quest:<all pages>`` is first asserted byte-identical to the
    selection-off async streams (ascending top-K at K = P is the
    identity permutation), then K in {2, 4, 8} are timed — recorded
    under ``selection`` with ``selection_speedup`` = best timed K vs
    the selection-off async driver. Each K also decodes a
    needle-retrieval batch through the serving path
    (``needle_accuracy``): payload recall with the needles far outside
    the local window, the accuracy axis that catches a selection policy
    gathering the wrong pages.

  * the **multi-turn prefix-cache A/B**: conversations that resend a
    growing shared context each turn replay cold and then through a
    content-addressed prefix store (serving/prefix_cache.py) — cached
    streams are asserted byte-identical to cold prefill, and the record
    carries ``prefix.hit_rate`` plus TTFT-on-hit vs the in-run miss and
    cold-matched p50s (the splice-instead-of-re-prefill win).

Greedy token streams from all drivers are asserted byte-identical
before any timing is trusted. Warmup replays run first per engine and
their wall time is recorded as ``compile_time_s``, so the steady-state
numbers above never pay jit compilation.

SLO regression gate: with ``--slo-tolerance T`` the run compares each
backend's p99 TTFT AND p99 TPOT against the committed
``BENCH_serving.json`` history (same trace signature) and exits nonzero
when a new p99 exceeds the old by more than ``T`` (fractional, e.g.
0.25 = +25%) — the TTFT tail alert the roadmap called for, plus the
decode-latency guard that keeps batched prefill from regressing TPOT
unnoticed.

Arrival processes: the default ``burst`` trace scatters arrivals over the
first ``n`` scheduler ticks; ``poisson:<rate>`` draws i.i.d. exponential
inter-arrival gaps (``rate`` = mean arrivals per tick), the open-loop
traffic model the TTFT tail percentiles are meaningful under.

With ``--mesh dxm`` every backend runs its jitted decode/extend SPMD over
a ("data", "model") device mesh (serving/sharded.py); on a dev box use
the debug recipe ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.

Emits CSV rows for benchmarks.run and writes ``BENCH_serving.json``
(``{"trace": ..., "backends": {name: metrics}, "ab": ratios-vs-dense}``)
so the serving trajectory is tracked across PRs. Each backend record
carries a ``phases`` tick-phase wall-time breakdown (prefill with its
extend sub-phase, dispatch with its fused/selection sub-phases, collect,
evict, memory_sample, admit, vs the measured tick total) plus
``fused_padding_frac`` — the fraction of fused slot-rows that were
padding, the fixed-shape overhead axis behind the CPU-XLA stage ratios.
``--trace-out`` additionally runs one dedicated traced replay per
backend (after the timed A/B, so timing stays tracing-free) and writes
validated Chrome-trace JSONs (repro.serving.obs).
"""
from __future__ import annotations

import argparse
import datetime
import json
import os
import sys
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from benchmarks.common import SEQ, trained_model
from repro.core.selection import PAGE_SIZE
from repro.data.synthetic import needle_task
from repro.serving.backend import BACKEND_NAMES, make_backend
from repro.serving.obs import (Tracer, validate_chrome_trace,
                               write_chrome_trace)
from repro.serving.orchestrator import SchedulerConfig, ServeSession
from repro.serving.orchestrator.telemetry import PHASE_TIME_KEYS
from repro.serving.sharded import build_mesh

N_REQUESTS = 12
PROMPT_LEN = 96
MAX_NEW = 16
SLOTS = 4
CHUNK = 32
CAPACITY = 192
DISPATCH_AHEAD = 1
SMOKE = dict(n_requests=4, prompt_len=48, max_new=4)

# multi-turn chat driver (prefix-cache A/B): every turn resends the whole
# growing conversation, so turns 2..T share an ever-longer chunk-aligned
# prefix with their predecessor — the workload the content-addressed
# prefix store exists for
MULTI_TURN = dict(convs=4, turns=3, user_tokens=16)
SMOKE_MULTI_TURN = dict(convs=2, turns=2, user_tokens=8)

# decode-time page-selection A/B: timed K sweep (smoke trims the sweep;
# the K = all-pages parity replay always runs on paged backends)
SELECTION_KS = (2, 4, 8)
SMOKE_SELECTION_KS = (4,)
NEEDLE_N = 16
SMOKE_NEEDLE_N = 8

JSON_PATH = os.environ.get("BENCH_SERVING_JSON", "BENCH_serving.json")

# BENCH_serving.json artifact schema; v2 added the per-backend tick-phase
# wall-time breakdown ("phases") and top-level self-description; v3 made
# the fused megabatch tick the headline driver and added compile_time_s
# and the fused phase counters; v4 retired the unfused/unbatched drivers
# (the split prefill/decode paths are gone from the scheduler) and added
# the decode-time page-selection A/B ("selection", selection_speedup,
# needle_accuracy) and fused_padding_frac; v5 added the per-backend
# multi-turn prefix-cache A/B ("prefix": hit_rate, ttft_on_hit_p50_s vs
# the miss/cold-matched p50s, tokens_reused) and the prefix_* counters
BENCH_SCHEMA_VERSION = 5

# trace fields that must match before an SLO comparison against history
# is meaningful (different traffic -> different tails, not a regression)
TRACE_SIGNATURE = ("requests", "prompt_len", "max_new", "arrival", "mesh",
                   "smoke")


def poisson_rate(arrival: str) -> Optional[float]:
    """Validate an arrival spec; returns the rate for ``poisson:<rate>``
    (mean arrivals per scheduler tick), None for ``burst``."""
    if arrival == "burst":
        return None
    if arrival.startswith("poisson:"):
        try:
            rate = float(arrival.split(":", 1)[1])
        except ValueError:
            raise ValueError(f"bad poisson rate in {arrival!r}") from None
        if rate <= 0:
            raise ValueError(f"poisson rate must be > 0, got {rate}")
        return rate
    raise ValueError(
        f"arrival must be 'burst' or 'poisson:<rate>', got {arrival!r}")


def record_trace(n: int, vocab: int, *, prompt_len: int, max_new: int,
                 seed: int = 1, arrival: str = "burst") -> List[Dict]:
    """Deterministic arrival trace: each request carries a prompt and an
    arrival tick (scheduler rounds since t0). Every backend replays the
    SAME trace, so latency/throughput deltas are attributable to the cache
    policy alone.

    ``arrival="burst"`` scatters all arrivals uniformly over the first
    ``n`` ticks (closed burst); ``arrival="poisson:<rate>"`` draws
    exponential inter-arrival gaps with mean ``1/rate`` ticks — an
    open-loop Poisson process, the traffic model TTFT tail percentiles
    are meaningful under."""
    rate = poisson_rate(arrival)
    key = jax.random.PRNGKey(seed)
    out = []
    t = 0.0
    for i in range(n):
        key, kp, ka = jax.random.split(key, 3)
        prompt = jax.random.randint(kp, (prompt_len,), 0, vocab - 8).tolist()
        if rate is None:
            tick = int(jax.random.randint(ka, (), 0, max(1, n)))
        else:
            t += float(jax.random.exponential(ka)) / rate
            tick = int(t)
        out.append({"arrival_tick": tick, "prompt": prompt,
                    "max_new": max_new})
    out.sort(key=lambda r: r["arrival_tick"])
    return out


def replay(eng, trace: List[Dict], *, chunk: int = CHUNK,
           dispatch_ahead: int = DISPATCH_AHEAD,
           tracer: Optional[Tracer] = None
           ) -> Tuple[ServeSession, List[List[int]]]:
    """Replay a recorded trace through a ServeSession: submit each
    request at its arrival tick, tick until drained. Returns the closed
    session and each request's token stream (submission order). With
    ``tracer`` the replay records lifecycle/phase spans (the timed A/B
    replays run without one, so the timed numbers stay tracing-free)."""
    sess = ServeSession(eng, sched=SchedulerConfig(
        chunk_tokens=chunk, dispatch_ahead=dispatch_ahead),
        tracer=tracer)
    handles = []
    pending = list(trace)
    tick = 0
    while pending or not sess.orchestrator.queue.all_done():
        while pending and pending[0]["arrival_tick"] <= tick:
            r = pending.pop(0)
            handles.append(sess.submit(r["prompt"], max_new=r["max_new"]))
        sess.tick()
        tick += 1
        if tick > 100_000:
            raise RuntimeError("trace replay did not drain")
    sess.close()
    return sess, [h.tokens() for h in handles]


def multi_turn_replay(eng, *, convs: int, turns: int, user_tokens: int,
                      plen: int, mnew: int, vocab: int, seed: int = 5,
                      prefix_cache=None):
    """Multi-turn chat driver: ``convs`` conversations served for
    ``turns`` rounds; each round's prompt is the previous prompt plus the
    model's output plus fresh user tokens, so rounds 2..T resend a
    growing shared context. One ServeSession per round (the engine and
    the prefix store persist across rounds — exactly how a frontend
    would hold them). Returns per-(conv, turn) token streams and the
    completed request records per turn, rid-sorted so cold and cached
    replays align request-for-request."""
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, vocab - 8, size=plen).tolist()
               for _ in range(convs)]
    streams = [[] for _ in range(convs)]
    turn_recs = []
    for _ in range(turns):
        sess = ServeSession(eng, sched=SchedulerConfig(
            chunk_tokens=CHUNK, dispatch_ahead=DISPATCH_AHEAD),
            prefix_cache=prefix_cache)
        hs = [sess.submit(p, max_new=mnew) for p in prompts]
        sess.run()
        sess.close()
        turn_recs.append(sorted(sess.telemetry.records,
                                key=lambda r: r.rid))
        for c, h in enumerate(hs):
            out = h.tokens()
            streams[c].append(out)
            prompts[c] = prompts[c] + out + rng.integers(
                0, vocab - 8, size=user_tokens).tolist()
    return streams, turn_recs


def _prefix_ab(eng, *, convs: int, turns: int, user_tokens: int,
               plen: int, mnew: int, vocab: int) -> Dict:
    """Prefix-cache A/B on one warm engine: the multi-turn trace replayed
    cold (no store), then with the store — greedy streams must be
    byte-identical (a hit splices the SAME post-admission state the cold
    run recomputes), and TTFT-on-hit is compared against both the
    in-run misses and the cold replay's matched requests."""
    from repro.serving.prefix_cache import PrefixCache
    kw = dict(convs=convs, turns=turns, user_tokens=user_tokens,
              plen=plen, mnew=mnew, vocab=vocab)
    cold_streams, cold_recs = multi_turn_replay(eng, **kw)
    pc = PrefixCache(quantum=CHUNK, free_fn=eng.release_prefix)
    warm_streams, warm_recs = multi_turn_replay(eng, prefix_cache=pc, **kw)
    if warm_streams != cold_streams:
        raise AssertionError(
            "prefix-cache replay diverged from cold prefill on the same "
            "multi-turn trace")
    flat_warm = [r for recs in warm_recs for r in recs]
    flat_cold = [r for recs in cold_recs for r in recs]
    hit_ttfts = [r.ttft for r in flat_warm
                 if r.prefix_hit and r.ttft is not None]
    miss_ttfts = [r.ttft for r in flat_warm
                  if not r.prefix_hit and r.ttft is not None]
    # cold TTFTs of the SAME (conv, turn) requests that hit when cached:
    # identical prompts, identical scheduler — the isolated splice win
    cold_matched = [c.ttft for w, c in zip(flat_warm, flat_cold)
                    if w.prefix_hit and c.ttft is not None]
    out = {
        "convs": convs, "turns": turns, "user_tokens": user_tokens,
        "hit_rate": pc.hits / max(pc.hits + pc.misses, 1),
        "hits": pc.hits, "misses": pc.misses,
        "inserts": pc.inserts, "evictions": pc.evictions,
        "bytes": pc.bytes_used,
        "tokens_reused": float(sum(r.prefix_tokens for r in flat_warm)),
        "ttft_on_hit_p50_s": (float(np.percentile(hit_ttfts, 50))
                              if hit_ttfts else None),
        "ttft_on_miss_p50_s": (float(np.percentile(miss_ttfts, 50))
                               if miss_ttfts else None),
        "ttft_cold_matched_p50_s": (float(np.percentile(cold_matched, 50))
                                    if cold_matched else None),
    }
    if hit_ttfts and cold_matched:
        out["ttft_hit_speedup_vs_cold"] = (
            float(np.percentile(cold_matched, 50))
            / float(np.percentile(hit_ttfts, 50)))
    pc.clear()
    return out


def needle_serving_accuracy(eng, vocab: int, *, n: int = NEEDLE_N,
                            seed: int = 777) -> float:
    """Needle payload recall THROUGH the serving decode path: prefill
    each needle prompt up to its final query marker, greedy-decode the
    payload span, and score it against the planted answer. The needles
    live in the first 55% of the sequence — always in global pages, far
    outside the local window — so under ``selection="quest:K"`` this
    measures whether query-aware top-K page selection gathers the pages
    the retrieval actually needs (an accuracy axis ``tokens_per_s``
    cannot see)."""
    b = needle_task(jax.random.PRNGKey(seed), n, SEQ, vocab, payload=2)
    qpos = int(b["query_pos"])
    toks = np.asarray(b["tokens"])
    sess = ServeSession(eng, sched=SchedulerConfig(
        chunk_tokens=CHUNK, dispatch_ahead=DISPATCH_AHEAD))
    hs = [sess.submit(toks[i, :qpos + 1].tolist(), max_new=2)
          for i in range(n)]
    sess.run()
    sess.close()
    pred = np.array([h.tokens() for h in hs])
    return float((pred == np.asarray(b["answer"])).mean())


def _prefill_tok_rate(s: Dict) -> Optional[float]:
    """Prompt-ingest throughput of one replay: prefill tokens over the
    wall time spent advancing them (not the whole replay —
    decode-heavy traces would drown the prefill signal). The fused tick
    has no separate prefill stage; its prefill share of the fused
    call's wall is apportioned by the engine
    (``fused_prefill_time_s``/``fused_prefill_tokens``)."""
    c = s["counters"]
    t = c.get("fused_prefill_time_s")
    return c.get("fused_prefill_tokens", 0.0) / t if t else None


def _phase_breakdown(s: Dict) -> Dict:
    """Tick-phase wall-time decomposition of one replay (seconds), from
    the orchestrator's always-on phase counters: the disjoint per-tick
    stages (``phase_sum_s`` = their sum, <= the measured ``tick_time_s``
    total — the rest is scheduler/stream/telemetry glue) plus the fused
    megabatch call's wall (inside ``dispatch_time_s``), its prefill-row
    apportionment, and the wall of the decode-only dispatches that ran
    the top-K selection variant (``selection_time_s``, a subset of
    ``fused_time_s``)."""
    c = s["counters"]
    out = {k: float(c.get(k, 0.0)) for k in PHASE_TIME_KEYS}
    out["extend_time_s"] = float(c.get("extend_time_s", 0.0))
    out["fused_time_s"] = float(c.get("fused_time_s", 0.0))
    out["fused_prefill_time_s"] = float(c.get("fused_prefill_time_s", 0.0))
    out["selection_time_s"] = float(c.get("selection_time_s", 0.0))
    out["tick_time_s"] = float(c.get("tick_time_s", 0.0))
    out["phase_sum_s"] = sum(float(c.get(k, 0.0)) for k in PHASE_TIME_KEYS)
    return out


def _backend_record(s: Dict) -> Dict:
    return {
        "requests": s["requests"],
        "requests_per_s": s["requests_per_s"],
        "tokens_per_s": s["tokens_per_s"],
        "ttft_mean_s": s["ttft_mean_s"],
        "ttft_p50_s": s["ttft_p50_s"],
        "ttft_p90_s": s["ttft_p90_s"],
        "ttft_p99_s": s["ttft_p99_s"],
        "tpot_mean_s": s["tpot_mean_s"],
        "tpot_p50_s": s["tpot_p50_s"],
        "tpot_p90_s": s["tpot_p90_s"],
        "tpot_p99_s": s["tpot_p99_s"],
        "mean_admission": s["mean_admission"],
        "mean_admission_decode": s["mean_admission_decode"],
        "fused_padding_frac": s["fused_padding_frac"],
        "pool_utilization": s["pool_util_mean"],
        "pool_pages_peak": s["pool_pages_peak"],
        "kv_tokens_peak": s["kv_tokens_peak"],
        "kv_bytes_peak": s["kv_bytes_peak"],
        "kv_bytes_per_shard_peak": s["kv_bytes_per_shard_peak"],
        "decode_steps": s["counters"]["decode_steps"],
        "prefill_chunks": s["counters"]["prefill_chunks"],
        "prefill_batches": s["counters"]["prefill_batches"],
        # where the best async replay's tick wall time went, per stage
        "phases": _phase_breakdown(s),
        # prefill_tokens_per_s is filled in by run() from the best stage
        # rate across the interleaved replays, not this single summary
    }


def check_slo(prev: Optional[Dict], record: Dict,
              tolerance: float) -> List[str]:
    """Compare per-backend p99 TTFT and p99 TPOT against the committed
    history (TPOT so batched prefill cannot regress decode latency
    unnoticed — coalesced prefill work shares ticks with decode).

    Returns human-readable violations (empty = pass). History with a
    different trace signature is skipped: changed traffic is not a
    regression."""
    if not prev:
        return []
    pt, nt = prev.get("trace", {}), record["trace"]
    if any(pt.get(k) != nt.get(k) for k in TRACE_SIGNATURE):
        print(f"slo: history trace signature differs "
              f"({ {k: pt.get(k) for k in TRACE_SIGNATURE} } vs "
              f"{ {k: nt.get(k) for k in TRACE_SIGNATURE} }); skipping",
              file=sys.stderr)
        return []
    out = []
    for name, rec in record["backends"].items():
        for metric, label in (("ttft_p99_s", "p99 TTFT"),
                              ("tpot_p99_s", "p99 TPOT")):
            old = prev.get("backends", {}).get(name, {}).get(metric)
            new = rec.get(metric)
            if old is None or new is None:
                continue
            if new > old * (1.0 + tolerance):
                out.append(
                    f"{name}: {label} {new * 1e3:.1f}ms > "
                    f"{old * 1e3:.1f}ms * (1 + {tolerance:g}) from history")
    return out


def _trace_path(base: str, name: str) -> str:
    """Per-backend trace artifact path: trace.json -> trace.wgkv.json."""
    stem, ext = os.path.splitext(base)
    return f"{stem}.{name}{ext or '.json'}"


def _selection_ab(name: str, params, cfg, dev_mesh, trace, warmup,
                  async_toks, base_tok_rate, *, ks: Sequence[int],
                  needle_n: int) -> Dict:
    """Decode-time page-selection A/B on one paged backend: a fresh
    engine per ``quest:K`` spec (selection is a jit-time option — each
    engine compiles its own decode-only variant), the K = all-pages
    engine asserted byte-identical to the selection-off streams first,
    then the timed K sweep with serving-path needle accuracy."""
    k_all = CAPACITY // PAGE_SIZE
    sel_eng = make_backend(name, params, cfg, slots=SLOTS,
                           capacity=CAPACITY, mesh=dev_mesh,
                           selection=f"quest:{k_all}")
    sel_eng.mirror = False
    replay(sel_eng, warmup)
    _, all_toks = replay(sel_eng, trace)
    # selection must change WHICH pages are attended, never the result
    # when it selects all of them: ascending top-K at K = P is the
    # identity permutation, so the streams are byte-identical
    if all_toks != async_toks:
        raise AssertionError(
            f"{name}: quest:{k_all} (= all pages) diverged from the "
            f"selection-off async driver on the same trace")
    out: Dict = {"parity_k": k_all, "per_k": {}}
    for k in ks:
        eng = make_backend(name, params, cfg, slots=SLOTS,
                           capacity=CAPACITY, mesh=dev_mesh,
                           selection=f"quest:{k}")
        eng.mirror = False
        t0 = time.perf_counter()
        replay(eng, warmup)
        compile_time_s = time.perf_counter() - t0
        best = None
        for _ in range(2):
            summ = replay(eng, trace)[0].telemetry.summary()
            if best is None or ((summ["tokens_per_s"] or 0.0)
                                > (best["tokens_per_s"] or 0.0)):
                best = summ
        c = best["counters"]
        out["per_k"][f"quest:{k}"] = {
            "tokens_per_s": best["tokens_per_s"],
            "tpot_p50_s": best["tpot_p50_s"],
            "selected_pages": float(c.get("selected_pages", 0.0)),
            "selection_time_s": float(c.get("selection_time_s", 0.0)),
            "fused_padding_frac": best["fused_padding_frac"],
            "compile_time_s": compile_time_s,
            "needle_accuracy": needle_serving_accuracy(
                eng, cfg.vocab_size, n=needle_n),
        }
    rates = {k: v["tokens_per_s"] for k, v in out["per_k"].items()
             if v["tokens_per_s"]}
    if rates and base_tok_rate:
        kbest = max(rates, key=rates.get)
        out["best_k"] = kbest
        out["selection_speedup"] = rates[kbest] / base_tok_rate
    return out


def run(backends: Optional[Sequence[str]] = None, smoke: bool = False,
        arrival: str = "burst", mesh: Optional[str] = None,
        trace_out: Optional[str] = None):
    names = tuple(backends) if backends else ("wgkv", "dense")
    for n in names:
        if n not in BACKEND_NAMES:
            raise ValueError(f"unknown backend {n!r}; known: {BACKEND_NAMES}")
    poisson_rate(arrival)       # validate both before any model work:
    dev_mesh = build_mesh(mesh)  # missing devices must fail fast, not after
    n_req, plen, mnew = ((SMOKE["n_requests"], SMOKE["prompt_len"],
                          SMOKE["max_new"]) if smoke
                         else (N_REQUESTS, PROMPT_LEN, MAX_NEW))
    sel_ks = SMOKE_SELECTION_KS if smoke else SELECTION_KS
    needle_n = SMOKE_NEEDLE_N if smoke else NEEDLE_N
    mt_kw = SMOKE_MULTI_TURN if smoke else MULTI_TURN
    # the distilled bench substrate (pretrained teacher + trained write
    # gates): with random-init gates every token passes tau and the memory
    # A/B axis degenerates to 1.0
    cfg, params = trained_model()
    trace = record_trace(n_req, cfg.vocab_size, prompt_len=plen,
                         max_new=mnew, seed=1, arrival=arrival)
    warmup = record_trace(SLOTS, cfg.vocab_size, prompt_len=plen,
                          max_new=2, seed=99)
    record: Dict = {
        "schema_version": BENCH_SCHEMA_VERSION,
        "generated_at": datetime.datetime.now(
            datetime.timezone.utc).isoformat(),
        "trace": {"requests": n_req, "prompt_len": plen, "max_new": mnew,
                  "arrival": arrival, "mesh": mesh,
                  "arrival_ticks": [r["arrival_tick"] for r in trace],
                  "dispatch_ahead": DISPATCH_AHEAD, "smoke": smoke},
        "backends": {},
    }
    rows = []
    for name in names:
        eng = make_backend(name, params, cfg, slots=SLOTS, capacity=CAPACITY,
                           mesh=dev_mesh)
        paged = eng.capabilities().paged
        # the timed replays run with the host-side paged mirror OFF so the
        # throughput/latency A/B isolates the cache policy; mirroring cost
        # is measured separately below
        if paged:
            eng.mirror = False
        # warmup: compile the fused tick's shapes on the same engine (the
        # jit caches live on the engine's partials) — (slots, chunk) for
        # mixed dispatches and (slots, 1) for decode-only top-ups — then
        # replay the measured trace fresh per driver. The warmup wall is
        # recorded as compile_time_s so steady-state numbers never pay
        # jit compilation. Timed replays are INTERLEAVED (sync, async,
        # sync, ...) and each driver keeps its best, so a shared-box
        # noise burst lands on every driver instead of silently skewing
        # a ratio.
        t0 = time.perf_counter()
        replay(eng, warmup)
        compile_time_s = time.perf_counter() - t0
        drivers = {
            "sync": dict(dispatch_ahead=0),
            "async": dict(dispatch_ahead=DISPATCH_AHEAD),
        }
        best: Dict[str, Tuple] = {}
        best_prefill: Dict[str, float] = {}
        for _ in range(3):
            for dname, kw in drivers.items():
                sess, toks = replay(eng, trace, **kw)
                summ = sess.telemetry.summary()
                if dname not in best or ((summ["tokens_per_s"] or 0.0)
                                         > (best[dname][0]["tokens_per_s"]
                                            or 0.0)):
                    best[dname] = (summ, toks)
                best_prefill[dname] = max(best_prefill.get(dname, 0.0),
                                          _prefill_tok_rate(summ) or 0.0)
        s_sync, sync_toks = best["sync"]
        s, async_toks = best["async"]
        # no driver may change WHAT is served, only how the work is
        # scheduled on the device: greedy streams are byte-identical by
        # construction, checked before any timing is trusted
        if async_toks != sync_toks:
            raise AssertionError(
                f"{name}: async dispatch/collect driver diverged from the "
                f"synchronous baseline on the same trace")
        rec = _backend_record(s)
        rec["compile_time_s"] = compile_time_s
        rec["sync_tokens_per_s"] = s_sync["tokens_per_s"]
        rec["sync_ttft_p99_s"] = s_sync["ttft_p99_s"]
        if s["tokens_per_s"] and s_sync["tokens_per_s"]:
            rec["async_speedup_vs_sync"] = (
                s["tokens_per_s"] / s_sync["tokens_per_s"])
        # the async driver's BEST prefill-stage rate across the
        # interleaved replays (the fused call's prefill-row
        # apportionment), so the stage rate is the driver's achievable
        # rate instead of whichever replay won on total tokens_per_s
        rec["prefill_tokens_per_s"] = best_prefill["async"] or None
        if paged:
            # decode-time page selection A/B: parity at K = all pages,
            # timed K sweep, serving-path needle accuracy (the engines
            # are per-K — the selection spec is a jit-time option)
            sel = _selection_ab(name, params, cfg, dev_mesh, trace,
                                warmup, async_toks, s["tokens_per_s"],
                                ks=sel_ks, needle_n=needle_n)
            sel["needle_accuracy_off"] = needle_serving_accuracy(
                eng, cfg.vocab_size, n=needle_n)
            rec["selection"] = sel
            if "selection_speedup" in sel:
                rec["selection_speedup"] = sel["selection_speedup"]
        if trace_out:
            # dedicated traced replay on the warm engine, AFTER the timed
            # A/B (spans cover the production async driver; the timed
            # numbers above stay tracing-free). The artifact is validated
            # here, not just written — an instrumentation regression that
            # empties a span family should fail the bench, not ship a
            # hollow trace.
            tracer = Tracer()
            replay(eng, trace, tracer=tracer)
            tpath = _trace_path(trace_out, name)
            obj = write_chrome_trace(
                tracer, tpath,
                meta={"backend": name, "arrival": arrival,
                      "requests": n_req, "smoke": smoke})
            errs = validate_chrome_trace(obj)
            if errs:
                raise AssertionError(
                    f"{name}: invalid trace artifact {tpath}: {errs[:3]}")
            rows.append((f"serving/{name}/trace_out", 0.0,
                         f"{tpath} events={len(obj['traceEvents'])}"))
        if paged:
            # extra replay on the warm engine with mirroring ON: physical
            # pool telemetry (pages peak / utilization), kept out of the
            # timed numbers above
            eng.mirror = True
            s2 = replay(eng, trace)[0].telemetry.summary()
            rec["pool_utilization"] = s2["pool_util_mean"]
            rec["pool_pages_peak"] = s2["pool_pages_peak"]
            eng.mirror = False
        # multi-turn prefix-cache A/B on the warm engine: hit-rate and
        # the TTFT win of splicing a stored shared-context prefix vs
        # re-prefilling it (streams asserted byte-identical inside)
        rec["prefix"] = _prefix_ab(eng, plen=plen, mnew=mnew,
                                   vocab=cfg.vocab_size, **mt_kw)
        record["backends"][name] = rec
        rows += [
            (f"serving/{name}/trace", (s["wall_s"] or 0.0) * 1e6,
             f"req_per_s={s['requests_per_s']:.2f}"),
            (f"serving/{name}/ttft_mean", (s["ttft_mean_s"] or 0.0) * 1e6,
             f"p90={(s['ttft_p90_s'] or 0.0) * 1e3:.1f}ms"),
            (f"serving/{name}/tpot_mean", (s["tpot_mean_s"] or 0.0) * 1e6,
             f"tok_per_s={s['tokens_per_s']:.1f}"),
            (f"serving/{name}/async_vs_sync", 0.0,
             f"speedup={rec.get('async_speedup_vs_sync', 0.0):.3f}"),
            (f"serving/{name}/memory", 0.0,
             f"kv_tokens_peak={rec['kv_tokens_peak']} "
             f"pool_pages_peak={rec['pool_pages_peak']}"),
            (f"serving/{name}/phases",
             rec["phases"]["tick_time_s"] * 1e6,
             "phase_sum={phase_sum_s:.3f}s prefill={prefill_time_s:.3f}s "
             "dispatch={dispatch_time_s:.3f}s collect={collect_time_s:.3f}s "
             "padding_frac={pad:.3f}"
             .format(pad=rec["fused_padding_frac"] or 0.0,
                     **rec["phases"])),
        ]
        pfx = rec["prefix"]
        rows.append((
            f"serving/{name}/prefix",
            (pfx["ttft_on_hit_p50_s"] or 0.0) * 1e6,
            f"hit_rate={pfx['hit_rate']:.3f} "
            f"tokens_reused={pfx['tokens_reused']:.0f} "
            f"ttft_hit_p50={(pfx['ttft_on_hit_p50_s'] or 0.0) * 1e3:.1f}ms "
            f"miss_p50={(pfx['ttft_on_miss_p50_s'] or 0.0) * 1e3:.1f}ms "
            f"cold_p50={(pfx['ttft_cold_matched_p50_s'] or 0.0) * 1e3:.1f}ms"))
        if paged and "selection" in rec:
            sel = rec["selection"]
            per_k = " ".join(
                f"{k}={v['tokens_per_s'] or 0.0:.1f}tok/s"
                f"(needle={v['needle_accuracy']:.2f})"
                for k, v in sel["per_k"].items())
            rows.append((
                f"serving/{name}/selection", 0.0,
                f"speedup={sel.get('selection_speedup', 0.0):.3f} "
                f"parity_k={sel['parity_k']} {per_k} "
                f"needle_off={sel['needle_accuracy_off']:.2f}"))
    # comparative ratios vs the dense full-KV baseline: the paper's
    # speedup and memory-reduction claims as serving-level numbers
    dense = record["backends"].get("dense")
    if dense:
        record["ab"] = {}
        for name, r in record["backends"].items():
            if name == "dense":
                continue
            ab = {}
            if r["tokens_per_s"] and dense["tokens_per_s"]:
                ab["decode_speedup_vs_dense"] = (
                    r["tokens_per_s"] / dense["tokens_per_s"])
            if r["kv_tokens_peak"] and dense["kv_tokens_peak"]:
                ab["kv_memory_frac_of_dense"] = (
                    r["kv_tokens_peak"] / dense["kv_tokens_peak"])
            record["ab"][name] = ab
            rows.append((f"serving/ab/{name}", 0.0,
                         " ".join(f"{k}={v:.3f}" for k, v in ab.items())
                         or "n/a"))
    with open(JSON_PATH, "w") as fh:
        json.dump(record, fh, indent=2)
    rows.append(("serving/json", 0.0, JSON_PATH))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--backends", default="wgkv,dense",
                    help="comma-separated subset of " + ",".join(BACKEND_NAMES))
    ap.add_argument("--smoke", action="store_true",
                    help="tiny trace (CI/headless A/B path check)")
    ap.add_argument("--arrival", default="burst",
                    help="arrival process: burst | poisson:<rate> "
                         "(mean arrivals per scheduler tick)")
    ap.add_argument("--mesh", default=None, metavar="DxM",
                    help="data x model mesh for SPMD decode, e.g. 2x4 "
                         "(debug: XLA_FLAGS="
                         "--xla_force_host_platform_device_count=8)")
    ap.add_argument("--slo-tolerance", type=float, default=None,
                    metavar="FRAC",
                    help="fail (exit 1) when a backend's p99 TTFT exceeds "
                         "the committed BENCH_serving.json history by more "
                         "than this fraction (e.g. 0.25 = +25%%)")
    ap.add_argument("--trace-out", default=None, metavar="TRACE.json",
                    help="record a dedicated traced replay per backend "
                         "(after the timed A/B) and write validated "
                         "Chrome-trace JSONs, one per backend "
                         "(trace.json -> trace.wgkv.json, ...)")
    args = ap.parse_args()
    # snapshot the committed history BEFORE run() overwrites it
    prev_record = None
    if args.slo_tolerance is not None and os.path.exists(JSON_PATH):
        with open(JSON_PATH) as fh:
            prev_record = json.load(fh)
    rows = run(backends=args.backends.split(","), smoke=args.smoke,
               arrival=args.arrival, mesh=args.mesh,
               trace_out=args.trace_out)
    for r in rows:
        print(",".join(str(x) for x in r))
    if args.slo_tolerance is not None:
        with open(JSON_PATH) as fh:
            new_record = json.load(fh)
        violations = check_slo(prev_record, new_record, args.slo_tolerance)
        if violations:
            print("SLO REGRESSION:", file=sys.stderr)
            for v in violations:
                print(f"  {v}", file=sys.stderr)
            raise SystemExit(1)


if __name__ == "__main__":
    main()
