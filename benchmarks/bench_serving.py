"""Serving A/B benchmark: replay one recorded arrival trace through each
requested engine backend (WG-KV, dense full-KV, static admission) under
the same continuous-batching orchestrator, and emit per-backend
throughput, TTFT/TPOT percentiles, and peak KV/paged-pool memory.

This is the paper's headline comparison (46-68% memory reduction,
1.85-2.56x decode speedup vs full-KV) recast as a regression-tracked
serving scenario: identical traffic, identical scheduler, only the cache
policy behind the ``EngineBackend`` protocol changes.

    PYTHONPATH=src python -m benchmarks.bench_serving \
        --backends wgkv,dense [--smoke]

Emits CSV rows for benchmarks.run and writes ``BENCH_serving.json``
(``{"trace": ..., "backends": {name: metrics}, "ab": ratios-vs-dense}``)
so the serving trajectory is tracked across PRs.
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List, Optional, Sequence

import jax

from benchmarks.common import trained_model
from repro.serving.backend import BACKEND_NAMES, make_backend
from repro.serving.orchestrator import Orchestrator, SchedulerConfig

N_REQUESTS = 12
PROMPT_LEN = 96
MAX_NEW = 16
SLOTS = 4
CHUNK = 32
CAPACITY = 192
SMOKE = dict(n_requests=4, prompt_len=48, max_new=4)

JSON_PATH = os.environ.get("BENCH_SERVING_JSON", "BENCH_serving.json")


def record_trace(n: int, vocab: int, *, prompt_len: int, max_new: int,
                 seed: int = 1) -> List[Dict]:
    """Deterministic arrival trace: each request carries a prompt and an
    arrival tick (scheduler rounds since t0). Every backend replays the
    SAME trace, so latency/throughput deltas are attributable to the cache
    policy alone."""
    key = jax.random.PRNGKey(seed)
    out = []
    for i in range(n):
        key, kp, ka = jax.random.split(key, 3)
        prompt = jax.random.randint(kp, (prompt_len,), 0, vocab - 8).tolist()
        arrival = int(jax.random.randint(ka, (), 0, max(1, n)))
        out.append({"arrival_tick": arrival, "prompt": prompt,
                    "max_new": max_new})
    out.sort(key=lambda r: r["arrival_tick"])
    return out


def replay(eng, trace: List[Dict], *, chunk: int = CHUNK) -> Orchestrator:
    """Replay a recorded trace: submit each request at its arrival tick,
    tick the orchestrator until drained."""
    orch = Orchestrator(eng, sched=SchedulerConfig(chunk_tokens=chunk))
    pending = list(trace)
    tick = 0
    while pending or not orch.queue.all_done():
        while pending and pending[0]["arrival_tick"] <= tick:
            r = pending.pop(0)
            orch.submit(r["prompt"], max_new=r["max_new"])
        orch.tick()
        tick += 1
        if tick > 100_000:
            raise RuntimeError("trace replay did not drain")
    orch.telemetry.stop()
    return orch


def _backend_record(s: Dict) -> Dict:
    return {
        "requests": s["requests"],
        "requests_per_s": s["requests_per_s"],
        "tokens_per_s": s["tokens_per_s"],
        "ttft_mean_s": s["ttft_mean_s"],
        "ttft_p50_s": s["ttft_p50_s"],
        "ttft_p90_s": s["ttft_p90_s"],
        "ttft_p99_s": s["ttft_p99_s"],
        "tpot_mean_s": s["tpot_mean_s"],
        "tpot_p50_s": s["tpot_p50_s"],
        "tpot_p90_s": s["tpot_p90_s"],
        "mean_admission": s["mean_admission"],
        "mean_admission_decode": s["mean_admission_decode"],
        "pool_utilization": s["pool_util_mean"],
        "pool_pages_peak": s["pool_pages_peak"],
        "kv_tokens_peak": s["kv_tokens_peak"],
        "kv_bytes_peak": s["kv_bytes_peak"],
        "decode_steps": s["counters"]["decode_steps"],
        "prefill_chunks": s["counters"]["prefill_chunks"],
    }


def run(backends: Optional[Sequence[str]] = None, smoke: bool = False):
    names = tuple(backends) if backends else ("wgkv", "dense")
    for n in names:
        if n not in BACKEND_NAMES:
            raise ValueError(f"unknown backend {n!r}; known: {BACKEND_NAMES}")
    n_req, plen, mnew = ((SMOKE["n_requests"], SMOKE["prompt_len"],
                          SMOKE["max_new"]) if smoke
                         else (N_REQUESTS, PROMPT_LEN, MAX_NEW))
    # the distilled bench substrate (pretrained teacher + trained write
    # gates): with random-init gates every token passes tau and the memory
    # A/B axis degenerates to 1.0
    cfg, params = trained_model()
    trace = record_trace(n_req, cfg.vocab_size, prompt_len=plen,
                         max_new=mnew, seed=1)
    warmup = record_trace(SLOTS, cfg.vocab_size, prompt_len=plen,
                          max_new=2, seed=99)
    record: Dict = {
        "trace": {"requests": n_req, "prompt_len": plen, "max_new": mnew,
                  "arrival_ticks": [r["arrival_tick"] for r in trace],
                  "smoke": smoke},
        "backends": {},
    }
    rows = []
    for name in names:
        eng = make_backend(name, params, cfg, slots=SLOTS, capacity=CAPACITY)
        paged = eng.capabilities().paged
        # the timed replay runs with the host-side paged mirror OFF so the
        # throughput/latency A/B isolates the cache policy; mirroring cost
        # is measured separately below
        if paged:
            eng.mirror = False
        # warmup: compile prefill/extend/decode shapes on the same engine
        # (the jit caches live on the engine's partials), then replay the
        # measured trace fresh
        replay(eng, warmup)
        orch = replay(eng, trace)
        s = orch.telemetry.summary()
        rec = _backend_record(s)
        if paged:
            # second replay on the warm engine with mirroring ON: physical
            # pool telemetry (pages peak / utilization), kept out of the
            # timed numbers above
            eng.mirror = True
            s2 = replay(eng, trace).telemetry.summary()
            rec["pool_utilization"] = s2["pool_util_mean"]
            rec["pool_pages_peak"] = s2["pool_pages_peak"]
        record["backends"][name] = rec
        rows += [
            (f"serving/{name}/trace", (s["wall_s"] or 0.0) * 1e6,
             f"req_per_s={s['requests_per_s']:.2f}"),
            (f"serving/{name}/ttft_mean", (s["ttft_mean_s"] or 0.0) * 1e6,
             f"p90={(s['ttft_p90_s'] or 0.0) * 1e3:.1f}ms"),
            (f"serving/{name}/tpot_mean", (s["tpot_mean_s"] or 0.0) * 1e6,
             f"tok_per_s={s['tokens_per_s']:.1f}"),
            (f"serving/{name}/memory", 0.0,
             f"kv_tokens_peak={rec['kv_tokens_peak']} "
             f"pool_pages_peak={rec['pool_pages_peak']}"),
        ]
    # comparative ratios vs the dense full-KV baseline: the paper's
    # speedup and memory-reduction claims as serving-level numbers
    dense = record["backends"].get("dense")
    if dense:
        record["ab"] = {}
        for name, r in record["backends"].items():
            if name == "dense":
                continue
            ab = {}
            if r["tokens_per_s"] and dense["tokens_per_s"]:
                ab["decode_speedup_vs_dense"] = (
                    r["tokens_per_s"] / dense["tokens_per_s"])
            if r["kv_tokens_peak"] and dense["kv_tokens_peak"]:
                ab["kv_memory_frac_of_dense"] = (
                    r["kv_tokens_peak"] / dense["kv_tokens_peak"])
            record["ab"][name] = ab
            rows.append((f"serving/ab/{name}", 0.0,
                         " ".join(f"{k}={v:.3f}" for k, v in ab.items())
                         or "n/a"))
    with open(JSON_PATH, "w") as fh:
        json.dump(record, fh, indent=2)
    rows.append(("serving/json", 0.0, JSON_PATH))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--backends", default="wgkv,dense",
                    help="comma-separated subset of " + ",".join(BACKEND_NAMES))
    ap.add_argument("--smoke", action="store_true",
                    help="tiny trace (CI/headless A/B path check)")
    args = ap.parse_args()
    for r in run(backends=args.backends.split(","), smoke=args.smoke):
        print(",".join(str(x) for x in r))


if __name__ == "__main__":
    main()
