# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV rows.
#
#   PYTHONPATH=src python -m benchmarks.run [--only fig7,fig8]
#
# Modules:
#   fig1   attention-bottleneck scaling          (paper Fig. 1)
#   fig7   memory-accuracy vs static admission   (paper Fig. 7 / Fig. 14)
#   fig8   efficiency at 75% sparsity            (paper Fig. 8 / Fig. 15)
#   fig9   Quest (Selection) composability       (paper Fig. 9)
#   fig10  SnapKV (Eviction) synergy             (paper Fig. 10 / Fig. 16)
#   fig11  lambda/tau Pareto frontier            (paper Fig. 11)
#   fig12  local-cache ablation                  (paper Fig. 12)
#   fig13  input-dependent admission patterns    (paper Fig. 13)
#   roofline  dry-run derived TPU roofline table (paper Fig. 8 analogue)
#   serving   backend A/B trace replay: wgkv vs dense under one orchestrator
#             (bench_serving --backends wgkv,dense --smoke; BENCH_serving.json)
import argparse
import sys
import time
import traceback

MODULES = {
    "fig1": "benchmarks.bench_fig1_bottleneck",
    "fig7": "benchmarks.bench_fig7_memory_accuracy",
    "fig8": "benchmarks.bench_fig8_efficiency",
    "fig9": "benchmarks.bench_fig9_quest",
    "fig10": "benchmarks.bench_fig10_eviction",
    "fig11": "benchmarks.bench_fig11_pareto",
    "fig12": "benchmarks.bench_fig12_local_cache",
    "fig13": "benchmarks.bench_fig13_patterns",
    "roofline": "benchmarks.bench_roofline",
    "serving": "benchmarks.bench_serving",
}

# per-module run() kwargs: the serving A/B path runs headlessly on the
# smoke trace so every benchmark sweep exercises the multi-backend replay
MODULE_KWARGS = {
    "serving": {"backends": ("wgkv", "dense"), "smoke": True},
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of " + ",".join(MODULES))
    args = ap.parse_args()
    names = list(MODULES) if not args.only else args.only.split(",")
    print("name,us_per_call,derived")
    failures = 0
    for name in names:
        import importlib

        t0 = time.time()
        try:
            mod = importlib.import_module(MODULES[name])
            rows = mod.run(**MODULE_KWARGS.get(name, {}))
            for r, us, derived in rows:
                print(f"{r},{us:.1f},{derived}", flush=True)
            print(f"{name}/_wall_s,{(time.time() - t0) * 1e6:.0f},module_total",
                  flush=True)
        except Exception:
            failures += 1
            print(f"{name}/_error,0,{traceback.format_exc(limit=2)!r}",
                  flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
