"""Fig. 10 / Fig. 16 — composability with post-write Eviction (SnapKV)
under a hard memory bound, on the needle-retrieval task decoded through
the serve path (early context needed at the end — the reasoning-trace
proxy).

Quadrant reproduced:
  * Eviction only ("write-then-throw"): everything is admitted, the cache
    fills with noise, evictions fire repeatedly and can discard the needle.
  * Admission only, aggressive: zero evictions but the gate may starve the
    model of useful context.
  * Admission + Eviction at moderate tau: few triggers, accuracy held.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import SEQ, VOCAB, trained_model
from repro.data.synthetic import needle_task
from repro.models import inference as I


def _run_policy(cfg, params, *, tau, hard_budget, n=16, seed=91):
    c2 = cfg.replace(wgkv=dataclasses.replace(cfg.wgkv, tau=tau))
    b = needle_task(jax.random.PRNGKey(seed), n, SEQ, VOCAB, payload=2)
    toks = b["tokens"]
    qpos = int(b["query_pos"])
    npre = (qpos + 1) - (qpos + 1) % c2.wgkv.w_local
    opts = I.DecodeOptions(evict_hard_budget=hard_budget, w_obs=8)
    _, caches = I.prefill(params, c2, toks[:, :npre], budget=64, opts=opts)
    step = jax.jit(functools.partial(I.decode_step, cfg=c2, opts=opts))
    trig = 0.0
    preds = []
    for t in range(npre, qpos + 3):
        logits, caches, st = step(params, token=toks[:, t], caches=caches)
        trig += float(st["evict_triggers"])
        if t >= qpos:
            preds.append(np.asarray(jnp.argmax(logits, -1)))
    acc = float((np.stack(preds[:2], 1) == np.asarray(b["answer"])).mean())
    node = caches["blocks"]["b0"]
    dc = node["self"] if isinstance(node, dict) else node
    mem = float(np.asarray(dc.gcnt, np.float32).mean())
    return acc, trig, mem


def run():
    cfg, params = trained_model()
    rows = []
    budget = 24  # hard per-head global bound (tokens)
    acc, trig, mem = _run_policy(cfg, params, tau=-1.0, hard_budget=budget)
    rows.append(("fig10/snapkv_only", 0.0,
                 f"acc={acc:.3f},evictions={trig:.0f},gmem={mem:.1f}"))
    acc, trig, mem = _run_policy(cfg, params, tau=0.95, hard_budget=budget)
    rows.append(("fig10/wgkv_aggressive_only", 0.0,
                 f"acc={acc:.3f},evictions={trig:.0f},gmem={mem:.1f}"))
    acc, trig, mem = _run_policy(cfg, params, tau=0.1, hard_budget=budget)
    rows.append(("fig10/wgkv+snapkv", 0.0,
                 f"acc={acc:.3f},evictions={trig:.0f},gmem={mem:.1f}"))
    acc, trig, mem = _run_policy(cfg, params, tau=0.1, hard_budget=10_000)
    rows.append(("fig10/unbounded_ref", 0.0,
                 f"acc={acc:.3f},evictions={trig:.0f},gmem={mem:.1f}"))
    return rows
