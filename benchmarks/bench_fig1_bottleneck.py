"""Fig. 1 — attention dominates long-context inference.

Measures (a) prefill latency split attention vs. non-attention as seq
grows, (b) decode latency vs. resident cache size. CPU wall-clock on the
tiny bench model; the quadratic-vs-linear scaling trend is the claim.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import bench_cfg, timeit
from repro.models import inference as I
from repro.models import transformer as T


def run():
    cfg = bench_cfg()
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    rows = []
    prev = None
    for s in (256, 512, 1024, 2048):
        toks = jax.random.randint(jax.random.PRNGKey(1), (1, s), 0,
                                  cfg.vocab_size)

        full = jax.jit(lambda p, t: T.forward(p, cfg, t, mode="teacher").logits)
        t_full = timeit(full, params, toks)
        # "non-attention" estimate: same model with attention ablated to a
        # window-1 mask is still O(S^2) in jnp; instead time the FFN+embed
        # path by a model with 0-length attention: approximate with
        # window=1 local attention (scores still computed) is wrong — use
        # per-token FLOP-proportional estimate via a 1-layer MLP-only pass:
        mlponly = jax.jit(lambda p, t: _mlp_only(p, cfg, t))
        t_mlp = timeit(mlponly, params, toks)
        frac = max(0.0, 1.0 - t_mlp / t_full)
        rows.append((f"fig1/prefill_s{s}", t_full, f"attn_frac={frac:.2f}"))
        if prev is not None:
            rows.append((f"fig1/prefill_scaling_s{s}", t_full,
                         f"x{t_full / prev:.2f}_vs_half_seq"))
        prev = t_full
    # decode: latency vs cache length (memory-bound trend)
    for s in (512, 2048):
        caches = _dense_caches(cfg, params, s)
        tok = jnp.zeros((1,), jnp.int32)
        step = jax.jit(lambda p, t, c: I.decode_step(p, cfg, t, c)[0])
        t_dec = timeit(step, params, tok, caches)
        rows.append((f"fig1/decode_cache{s}", t_dec, f"cache_tokens={s}"))
    return rows


def _mlp_only(params, cfg, toks):
    from repro.models import layers as L

    x = L.embed(params["embed"], toks, jnp.float32)

    def body(xc, bp):
        b0 = bp["b0"]
        xc = xc + L.swiglu(b0["mlp"], L.rmsnorm(b0["ln2"], xc))
        return xc, None

    x, _ = jax.lax.scan(body, x, params["blocks"])
    return L.unembed(params["embed"], x)


def _dense_caches(cfg, params, s):
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, s), 0,
                              cfg.vocab_size)
    _, caches = I.prefill(params, cfg, toks, use_wgkv=False, max_len=s + 16)
    return caches
