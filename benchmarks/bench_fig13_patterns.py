"""Fig. 13 (Appendix H) — input-dependent admission patterns.

Per-(layer, head) normalized cache size on two different tasks (uniform
zipf stream vs structured copy task). Input dependence = the per-head
admission profile changes with the task (low cross-task correlation /
different mean sparsity), unlike any static policy."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import SEQ, VOCAB, trained_model
from repro.data.synthetic import copy_task, token_stream
from repro.models import transformer as T


def _per_head_sizes(cfg, params, toks):
    out = T.forward(params, cfg, toks, mode="gated")
    adm = (out.gates >= cfg.wgkv.tau).mean(axis=(1, 3))  # [L_attn, H]
    return np.asarray(adm)


def run():
    cfg, params = trained_model()
    key = jax.random.PRNGKey(3)
    stream = token_stream(key, 8, SEQ, VOCAB)
    copy = copy_task(key, 8, 24, SEQ - 26, VOCAB)["tokens"]
    a = _per_head_sizes(cfg, params, stream)
    b = _per_head_sizes(cfg, params, copy)
    corr = float(np.corrcoef(a.ravel(), b.ravel())[0, 1])
    rows = [
        ("fig13/stream_mean_admission", 0.0, f"{a.mean():.3f}"),
        ("fig13/copy_mean_admission", 0.0, f"{b.mean():.3f}"),
        ("fig13/head_variance_stream", 0.0, f"{a.std():.3f}"),
        ("fig13/head_variance_copy", 0.0, f"{b.std():.3f}"),
        ("fig13/cross_task_head_correlation", 0.0, f"{corr:.3f}"),
        ("fig13/task_delta_mean_abs", 0.0, f"{np.abs(a - b).mean():.3f}"),
    ]
    return rows
