"""Fig. 11 (Appendix F) — lambda / tau Pareto frontier: distillation loss
vs normalized KV cache size. Sweeping tau on gates distilled at two
lambdas traces the frontier; tau≈0.1 should sit near the knee."""
from __future__ import annotations

import dataclasses
import functools

import jax

from benchmarks.common import (SEQ, VOCAB, bench_cfg, _distill,
                               cache_size_at, trained_model)
from repro.core.losses import distill_loss
from repro.data.synthetic import needle_task
from repro.models import transformer as T


@functools.lru_cache(maxsize=4)
def _model_at_lambda(lam: float):
    cfg = bench_cfg(lam=lam)
    _, base = trained_model()  # reuse the pre-trained teacher backbone
    params, _ = _distill(cfg, base, lam, steps=120)
    return cfg, params


def _val_loss(cfg, params, tau, n=8, seed=999):
    c2 = cfg.replace(wgkv=dataclasses.replace(cfg.wgkv, tau=tau))
    b = needle_task(jax.random.PRNGKey(seed), n, SEQ, VOCAB, payload=2)
    teach = T.forward(params, c2, b["tokens"], mode="teacher")
    hard = T.forward(params, c2, b["tokens"], mode="hard")
    return float(distill_loss(hard.hidden, teach.hidden))


def run():
    rows = []
    for lam in (0.05, 0.15, 0.4):
        cfg, params = _model_at_lambda(lam)
        for tau in (0.05, 0.1, 0.3, 0.7):
            loss = _val_loss(cfg, params, tau)
            size = cache_size_at(cfg, params, tau)
            rows.append((f"fig11/lam{lam}_tau{tau}", 0.0,
                         f"cache={size:.3f},distill_loss={loss:.4f}"))
    return rows
