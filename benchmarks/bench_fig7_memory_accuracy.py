"""Fig. 7 — memory-accuracy trade-off on long-context retrieval.

WG-KV (learned admission, tau sweep over the distilled gate) vs. the two
static admission baselines from the paper: Local Attention (sink + window,
window sweep) and DuoAttention (per-head retrieval/streaming split, ratio
sweep). Task: needle retrieval (HELMET recall proxy).

Expected qualitative reproduction: WG-KV holds accuracy into the
low-memory regime; Local Attention collapses once the needle leaves the
window; DuoAttention sits between.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (SEQ, VOCAB, W_LOCAL, needle_accuracy,
                               trained_model)
from repro.core.baselines import (duo_attention_gates,
                                  identify_retrieval_heads,
                                  local_attention_gates)
from repro.data.synthetic import needle_task
from repro.models import transformer as T


def _acc_with_override(cfg, params, override, n=32, seed=777):
    b = needle_task(jax.random.PRNGKey(seed), n, SEQ, VOCAB, payload=2)
    out = T.forward(params, cfg, b["tokens"], mode="hard",
                    gate_override=override)
    qpos = int(b["query_pos"])
    pred = jnp.argmax(out.logits[:, qpos:qpos + 2], -1)
    return float((np.asarray(pred) == np.asarray(b["answer"])).mean())


def run():
    cfg, params = trained_model()
    rows = []
    import dataclasses

    from benchmarks.common import cache_size_at

    # --- WG-KV: sweep binarization threshold tau ------------------------
    for tau in (0.02, 0.1, 0.3, 0.6, 0.9):
        c2 = cfg.replace(wgkv=dataclasses.replace(cfg.wgkv, tau=tau))
        acc = needle_accuracy(c2, params, mode="hard")
        size = cache_size_at(cfg, params, tau)
        rows.append((f"fig7/wgkv_tau{tau}", 0.0,
                     f"cache={size:.3f},acc={acc:.3f}"))
    # --- Local Attention: sweep window ----------------------------------
    b = 32
    for window in (24, 48, 96):
        ov = local_attention_gates(b, cfg.n_kv_heads, SEQ, sink=2)
        c2 = cfg.replace(wgkv=dataclasses.replace(cfg.wgkv, w_local=window))
        acc = _acc_with_override(c2, params, ov, n=b)
        rows.append((f"fig7/local_w{window}", 0.0,
                     f"cache={(window + 2) / SEQ:.3f},acc={acc:.3f}"))
    # --- DuoAttention: sweep retrieval-head ratio ------------------------
    # profile heads with the learned gate on calibration data
    calib = needle_task(jax.random.PRNGKey(5), 8, SEQ, VOCAB, payload=2)
    gout = T.forward(params, cfg, calib["tokens"], mode="gated")
    per_layer_head = gout.gates.mean(axis=(1, 3))  # [L_attn, H]
    flat = per_layer_head.reshape(-1)
    for ratio in (0.25, 0.5, 0.75):
        overrides = []
        for li in range(gout.gates.shape[0]):
            flags = identify_retrieval_heads(gout.gates[li], ratio)
            overrides.append(duo_attention_gates(b, flags, SEQ, sink=2))
        ov = jnp.stack(overrides)  # [L, B, H, S]
        acc = _acc_with_override(cfg, params, ov, n=b)
        size = ratio + (1 - ratio) * (W_LOCAL + 2) / SEQ
        rows.append((f"fig7/duo_r{ratio}", 0.0,
                     f"cache={size:.3f},acc={acc:.3f}"))
    return rows
