"""Fig. 9 — composability with read-time Selection (Quest).

"Quest only" (selection over the full admitted cache, frac=1.0) vs
"WG-KV + Quest" (selection over the admission-compressed cache). The
paper's claim: the curves overlap — tokens WG-KV drops are ones Quest
would not have selected anyway. We measure needle accuracy and decode
logit fidelity vs the unrestricted decode, as a function of page budget.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import SEQ, VOCAB, trained_model
from repro.data.synthetic import needle_task
from repro.models import inference as I


def _decode_acc(cfg, params, opts, n=16, seed=881):
    """Prefill up to the query, decode the 2 payload tokens."""
    import functools

    b = needle_task(jax.random.PRNGKey(seed), n, SEQ, VOCAB, payload=2)
    toks = b["tokens"]
    qpos = int(b["query_pos"])
    npre = (qpos + 1) - (qpos + 1) % cfg.wgkv.w_local
    _, caches = I.prefill(params, cfg, toks[:, :npre],
                          budget=cfg.wgkv.global_budget(SEQ), opts=opts)
    step = jax.jit(functools.partial(I.decode_step, cfg=cfg, opts=opts))
    preds = []
    for t in range(npre, qpos + 3):
        logits, caches, _ = step(params, token=toks[:, t], caches=caches)
        if t >= qpos:
            preds.append(np.asarray(jnp.argmax(logits, -1)))
    acc = (np.stack(preds[:2], 1) == np.asarray(b["answer"])).mean()
    return float(acc)


def run():
    cfg, params = trained_model()
    rows = []
    for label, frac in (("quest_only", 1.0), ("wgkv+quest", 0.5)):
        # fracs chosen so the global budget stays 16-token page-aligned
        c2 = cfg.replace(wgkv=dataclasses.replace(
            cfg.wgkv, global_budget_frac=frac,
            tau=0.1 if frac < 1.0 else -1.0))  # tau=-1 => admit all
        base = _decode_acc(c2, params, I.DecodeOptions())
        for pages in (1, 2, 4, 8):
            acc = _decode_acc(c2, params, I.DecodeOptions(quest_pages=pages))
            rows.append((f"fig9/{label}_pages{pages}", 0.0,
                         f"acc={acc:.3f},noselect_acc={base:.3f}"))
    return rows
