"""Table/roofline summary — reads the dry-run + roofline artifacts (written
by repro.launch.dryrun / repro.roofline.run_all on the production mesh) and
prints the per-(arch x shape) terms. This is the TPU-v5e analogue of the
paper's Fig. 8 H200 wall-clock table (DESIGN.md §6)."""
from __future__ import annotations

import json
import os

ART = os.path.join(os.path.dirname(__file__), "artifacts")


def run():
    rows = []
    rf = os.path.join(ART, "roofline.json")
    if os.path.exists(rf):
        with open(rf) as f:
            recs = json.load(f)
        for r in sorted(recs, key=lambda x: (x["arch"], x["shape"])):
            if "error" in r:
                rows.append((f"roofline/{r['arch']}/{r['shape']}", 0.0,
                             f"error={str(r['error'])[:40]}"))
                continue
            rows.append((
                f"roofline/{r['arch']}/{r['shape']}", 0.0,
                f"bottleneck={r['bottleneck']},c={r['compute_s']:.4g}s,"
                f"m={r['memory_s']:.4g}s,x={r['collective_s']:.4g}s,"
                f"useful={r['useful_ratio']:.2f}"))
    dr = os.path.join(ART, "dryrun.json")
    if os.path.exists(dr):
        with open(dr) as f:
            recs = json.load(f)
        full = [r for r in recs if r.get("n_repeats_override") is None]
        ok = sum(1 for r in full if "error" not in r and not r.get("skipped"))
        skip = sum(1 for r in full if r.get("skipped"))
        err = sum(1 for r in full if "error" in r)
        rows.append(("dryrun/summary", 0.0,
                     f"ok={ok},documented_skips={skip},errors={err}"))
    if not rows:
        rows.append(("roofline/missing", 0.0,
                     "run repro.launch.dryrun + repro.roofline.run_all first"))
    return rows
