"""Fig. 8 / Fig. 15 — system efficiency at 75% sparsity vs full attention.

Paper methodology (Appendix I.3): run the full forward INCLUDING the
Write-Gate MLP, but override admission decisions with a randomized mask at
the exact target sparsity; time prefill end-to-end and decode per-step.
On CPU we measure the jitted budgeted-vertical-slash prefill and
dual-cache decode against the dense baselines, plus cache-byte accounting
(the memory claim) and the Pallas-kernel-level speed ratio.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import bench_cfg, timeit
from repro.models import inference as I
from repro.models import transformer as T

SPARSITY = 0.75


def _rand_gates(key, b, h, s, sparsity):
    return (jax.random.uniform(key, (b, h, s)) > sparsity).astype(jnp.float32)


def run():
    rows = []
    key = jax.random.PRNGKey(0)
    for s in (1024, 2048, 4096):
        cfg = bench_cfg(w_local=64, global_budget_frac=1 - SPARSITY)
        params = T.init_model(key, cfg)
        toks = jax.random.randint(key, (1, s), 0, cfg.vocab_size)
        budget = int(s * (1 - SPARSITY))
        gates = _rand_gates(key, 1, cfg.n_kv_heads, s, SPARSITY)

        # ---- prefill: full dense vs budgeted vertical-slash -------------
        pf_full = jax.jit(lambda p, t: I.prefill(
            p, cfg, t, use_wgkv=False, max_len=s + 8)[0].logits)
        pf_wgkv = jax.jit(lambda p, t: I.prefill(
            p, cfg, t, use_wgkv=True, budget=budget)[0].logits)
        t_full = timeit(pf_full, params, toks, iters=3)
        t_wgkv = timeit(pf_wgkv, params, toks, iters=3)
        rows.append((f"fig8/prefill_full_s{s}", t_full, ""))
        rows.append((f"fig8/prefill_wgkv_s{s}", t_wgkv,
                     f"speedup={t_full / t_wgkv:.2f}x"))

        # ---- decode: dense cache vs dual cache ---------------------------
        _, dense_c = I.prefill(params, cfg, toks, use_wgkv=False,
                               max_len=s + 8)
        _, dual_c = I.prefill(params, cfg, toks, use_wgkv=True, budget=budget)
        tok = jnp.zeros((1,), jnp.int32)
        dec = jax.jit(lambda p, t, c: I.decode_step(p, cfg, t, c)[0])
        t_dfull = timeit(dec, params, tok, dense_c, iters=5)
        t_dwg = timeit(dec, params, tok, dual_c, iters=5)
        rows.append((f"fig8/decode_full_s{s}", t_dfull, ""))
        rows.append((f"fig8/decode_wgkv_s{s}", t_dwg,
                     f"speedup={t_dfull / t_dwg:.2f}x"))

        # ---- memory: resident cache bytes --------------------------------
        def cache_bytes(c):
            tot = 0
            for leaf in jax.tree.leaves(c):
                if hasattr(leaf, "nbytes"):
                    tot += leaf.nbytes
            return tot

        mb_full = cache_bytes(dense_c)
        mb_wgkv = cache_bytes(dual_c)
        rows.append((f"fig8/cache_bytes_s{s}", 0.0,
                     f"full={mb_full},wgkv={mb_wgkv},"
                     f"reduction={1 - mb_wgkv / mb_full:.2%}"))
    # ---- kernel-level: gated_flash vs dense bias attention --------------
    from repro.kernels.ops import gated_flash_attention

    b, hq, hkv, s, hd = 1, 4, 2, 1024, 64
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (b, hq, s, hd))
    k = jax.random.normal(ks[1], (b, hkv, s, hd))
    v = jax.random.normal(ks[2], (b, hkv, s, hd))
    g = jax.nn.sigmoid(jax.random.normal(ks[3], (b, hkv, s)))
    t_kern = timeit(lambda: gated_flash_attention(q, k, v, g, w_local=64),
                    iters=3)
    rows.append(("fig8/kernel_gated_flash_s1024", t_kern,
                 "interpret-mode (TPU target)"))
    return rows
