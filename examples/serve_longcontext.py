"""Serve a long-context batch through the continuous-batching orchestrator:
dual cache + paged physical memory + chunked prefill + token streaming.

    PYTHONPATH=src python examples/serve_longcontext.py

serving
-------
The orchestrator wraps the JetStream-style engine backend
(prefill/insert/dispatch-collect) with a request queue, a batched
chunked-prefill scheduler (every in-flight prefill advances in one
ragged jitted call per tick), per-request token streams, and latency
telemetry::

    from repro.serving.engine import Engine
    from repro.serving.orchestrator import Orchestrator, SchedulerConfig

    eng = Engine(params, cfg, slots=3, capacity=512)
    orch = Orchestrator(eng, sched=SchedulerConfig(chunk_tokens=64),
                        max_pending=32)           # queue backpressure
    rid = orch.submit(prompt, max_new=24,
                      on_token=lambda rid, tok, last: ...)  # streaming
    orch.run()                                    # tick until drained
    orch.tokens(rid)                              # full decoded output
    orch.telemetry.report()                       # TTFT/TPOT/throughput/
                                                  # admission/pool-util
"""
import jax

from repro.configs import get_reduced_config
from repro.configs.base import WGKVConfig
from repro.models import transformer as T
from repro.serving.engine import Engine
from repro.serving.orchestrator import Orchestrator, SchedulerConfig

cfg = get_reduced_config("phi4-mini-3.8b").replace(
    dtype="float32",
    wgkv=WGKVConfig(enabled=True, w_local=32, tau=0.1, gate_hidden=32,
                    global_budget_frac=0.4, sink=4))
params = T.init_model(jax.random.PRNGKey(0), cfg)

eng = Engine(params, cfg, slots=3, capacity=512, pool_pages=8192,
             temperature=0.0)
orch = Orchestrator(eng, sched=SchedulerConfig(chunk_tokens=64))

key = jax.random.PRNGKey(7)
for i, plen in enumerate((320, 196, 96, 256)):  # ragged prompts
    key, k = jax.random.split(key)
    prompt = jax.random.randint(k, (plen,), 0, cfg.vocab_size - 8).tolist()
    stream_cb = (lambda r, tok, last:
                 print(f"  stream rid={r} tok={tok}"
                       + (" <eor>" if last else ""))) if plen == 96 else None
    rid = orch.submit(prompt, max_new=24, on_token=stream_cb)
    print(f"queued request {rid}: prompt_len={plen}")

step = 0
verified = None
while not orch.queue.all_done() and step < 400:
    orch.tick()
    step += 1
    if step % 8 == 0:
        live = sum(eng.live)
        print(f"tick {step:3d}: live={live} pool_pages={eng.pool.pages_in_use} "
              f"pool_util={eng.pool.utilization():.2f}")
    if verified is None and any(eng.live):
        verified = eng.verify_paged()  # check while caches are resident

print("\nresults:")
for rid, r in orch.queue.requests.items():
    print(f"  req {rid}: generated {len(r.out)} tokens, first 8 = {r.out[:8]}")
print("\ntelemetry:")
print(orch.telemetry.report())
print(f"\npaged-vs-logical verification (live batch): {verified:.2e}")
print(f"pool pages still allocated (should be 0): {eng.pool.pages_in_use}")
