"""Serve a long-context batch through the WG-KV engine: dual cache + paged
physical memory + continuous batching, with live cache statistics.

    PYTHONPATH=src python examples/serve_longcontext.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_reduced_config
from repro.configs.base import WGKVConfig
from repro.models import inference as I
from repro.models import transformer as T
from repro.serving.engine import Engine

cfg = get_reduced_config("phi4-mini-3.8b").replace(
    dtype="float32",
    wgkv=WGKVConfig(enabled=True, w_local=32, tau=0.1, gate_hidden=32,
                    global_budget_frac=0.4, sink=4))
params = T.init_model(jax.random.PRNGKey(0), cfg)

eng = Engine(params, cfg, slots=3, capacity=512, pool_pages=8192,
             temperature=0.0)
key = jax.random.PRNGKey(7)
for i, plen in enumerate((320, 196, 96, 256)):  # ragged prompts
    key, k = jax.random.split(key)
    prompt = jax.random.randint(k, (plen,), 0, cfg.vocab_size - 8).tolist()
    eng.add_request(prompt, max_new=24)
    print(f"queued request {i}: prompt_len={plen}")

step = 0
while not all(r.done for r in eng.requests.values()) and step < 200:
    emitted = eng.step()
    step += 1
    if step % 8 == 0:
        live = sum(1 for r in eng.slot_rid if r is not None)
        print(f"step {step:3d}: live={live} pool_pages={eng.pool.pages_in_use} "
              f"pool_util={eng.pool.utilization():.2f} emitted={emitted}")

print("\nresults:")
for rid, r in eng.requests.items():
    print(f"  req {rid}: generated {len(r.out)} tokens, first 8 = {r.out[:8]}")
print(f"\npaged-vs-logical verification: max deviation = {eng.verify_paged():.2e}")
print(f"pool pages still allocated (should be 0): {eng.pool.pages_in_use}")
