"""Composability demo (paper §5.4): Admission + Selection + Eviction in one
decode loop — WG-KV pre-filters writes, Quest focuses reads, SnapKV prunes
obsolete history under a hard memory bound.

    PYTHONPATH=src python examples/composability.py
"""
import functools

import jax
import jax.numpy as jnp

from repro.configs import get_reduced_config
from repro.configs.base import WGKVConfig
from repro.models import inference as I
from repro.models import transformer as T

cfg = get_reduced_config("qwen3-0.6b").replace(
    dtype="float32",
    wgkv=WGKVConfig(enabled=True, w_local=32, tau=0.1, gate_hidden=32,
                    global_budget_frac=0.5, sink=4))
params = T.init_model(jax.random.PRNGKey(0), cfg)
toks = jax.random.randint(jax.random.PRNGKey(1), (2, 512), 0, cfg.vocab_size)

CONFIGS = {
    "admission only": I.DecodeOptions(),
    "admission + Quest(select 2 pages)": I.DecodeOptions(quest_pages=2),
    "admission + SnapKV(bound 64/head)": I.DecodeOptions(evict_hard_budget=64,
                                                         w_obs=32),
    "all three": I.DecodeOptions(quest_pages=2, evict_hard_budget=64,
                                 w_obs=32),
}

for name, opts in CONFIGS.items():
    _, caches = I.prefill(params, cfg, toks[:, :256], budget=128, opts=opts)
    step = jax.jit(functools.partial(I.decode_step, cfg=cfg, opts=opts))
    tok = toks[:, 255]
    trig = 0.0
    for t in range(64):
        logits, caches, st = step(params, token=tok, caches=caches)
        tok = jnp.argmax(logits, -1)
        trig += float(st["evict_triggers"])
    dc = caches["blocks"]["b0"]
    gmean = float(jnp.asarray(dc.gcnt, jnp.float32).mean())
    print(f"{name:38s} | mean global entries/head: {gmean:6.1f} | "
          f"evictions: {trig:4.0f} | last logitmax: {float(logits.max()):.2f}")
