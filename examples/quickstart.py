"""Quickstart: WG-KV in 60 seconds on CPU.

Builds a reduced qwen3-0.6b, runs a vertical-slash prefill + dual-cache
decode, and prints what the admission policy kept.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_reduced_config
from repro.models import inference as I
from repro.models import registry as R
from repro.models import transformer as T

cfg = get_reduced_config("qwen3-0.6b").replace(dtype="float32")
print(f"arch={cfg.name}  layers={cfg.n_layers}  d={cfg.d_model}  "
      f"W_local={cfg.wgkv.w_local}  tau={cfg.wgkv.tau}")

key = jax.random.PRNGKey(0)
params = T.init_model(key, cfg)
n_backbone = R.count_params_tree(params)
n_gate = R.gate_params_tree(params)
print(f"params={n_backbone:,} (write-gate MLPs: {n_gate:,} = "
      f"{n_gate / n_backbone:.2%} — the paper's ~0.4% overhead claim)")

# ---- prefill 1024 tokens through budgeted vertical-slash attention ------
S, BUDGET = 1024, 128
toks = jax.random.randint(key, (1, S), 0, cfg.vocab_size)
out, caches = I.prefill(params, cfg, toks, budget=BUDGET)
dc = caches["blocks"]["b0"]  # first super-block's dual cache (stacked)
print(f"\nprefill {S} tokens with global budget {BUDGET}:")
print(f"  mean admission rate g>=tau : {float(out.mean_admission):.3f}")
print(f"  global-cache fill per head : {jnp.asarray(dc.gcnt)[0, 0].tolist()}")
print(f"  local ring size            : {dc.lk.shape[3]} tokens")
full = S * cfg.n_kv_heads
kept = int(dc.gcnt[0].sum()) + cfg.wgkv.w_local * cfg.n_kv_heads
print(f"  resident KV fraction       : {kept / full:.2%} of full cache")

# ---- decode 16 tokens through the dual cache (lazy promotion) -----------
tok = toks[:, -1]
for i in range(16):
    logits, caches, _ = I.decode_step(params, cfg, tok, caches)
    tok = jnp.argmax(logits, -1)
dc2 = caches["blocks"]["b0"]
print(f"\nafter 16 decode steps (lazy promotion active):")
print(f"  global-cache fill per head : {jnp.asarray(dc2.gcnt)[0, 0].tolist()}")
print(f"  ring pointer               : {int(dc2.ptr[0][0])}")
print(f"  last sampled token         : {int(tok[0])}")
print("\nOK — see examples/train_gate.py to LEARN the admission policy.")
