"""End-to-end driver: pre-train a ~100M-class model on synthetic
long-context data, then run the paper's recipe — freeze the backbone and
distill a Write-Gate admission policy — for a few hundred steps.

    PYTHONPATH=src python examples/train_gate.py            # ~100M, slow CPU
    PYTHONPATH=src python examples/train_gate.py --small    # minutes on CPU
"""
import argparse
import time

import jax

from repro.configs import get_reduced_config
from repro.configs.base import WGKVConfig
from repro.data.synthetic import DistillStream, lm_loss
from repro.launch.train import run_training
from repro.models import transformer as T
from repro.training.optimizer import adamw_init, adamw_update, cosine_schedule

ap = argparse.ArgumentParser()
ap.add_argument("--small", action="store_true",
                help="~20M params / seq 256 (finishes in minutes on CPU)")
ap.add_argument("--pretrain-steps", type=int, default=None)
ap.add_argument("--gate-steps", type=int, default=300)
ap.add_argument("--lam", type=float, default=0.1)
args = ap.parse_args()

if args.small:
    cfg = get_reduced_config("smollm-360m").replace(
        dtype="float32", d_model=256, n_repeats=2,
        wgkv=WGKVConfig(enabled=True, w_local=32, gate_hidden=32, sink=4))
    seq, batch, pre_steps = 256, 4, args.pretrain_steps or 150
else:
    # ~100M-class: smollm-360m at half depth
    cfg = get_reduced_config("smollm-360m").replace(
        dtype="float32", d_model=768, n_heads=12, n_kv_heads=4, head_dim=64,
        d_ff=2048, n_repeats=6, vocab_size=8192,
        wgkv=WGKVConfig(enabled=True, w_local=64, gate_hidden=64, sink=4))
    seq, batch, pre_steps = 512, 4, args.pretrain_steps or 200

from repro.models.registry import count_params_analytic

print(f"model: {count_params_analytic(cfg) / 1e6:.1f}M params, "
      f"{cfg.n_layers} layers, seq {seq}")

# ---- phase 1: pre-train the backbone (teacher) ---------------------------
key = jax.random.PRNGKey(0)
params = T.init_model(key, cfg)
opt = adamw_init(params)
lr = cosine_schedule(3e-3, pre_steps)


@jax.jit
def pretrain_step(params, opt, toks):
    def loss_fn(p):
        out = T.forward(p, cfg, toks, mode="teacher")
        return lm_loss(out.logits, toks)

    loss, g = jax.value_and_grad(loss_fn)(params)
    params, opt = adamw_update(g, opt, params, lr=lr)
    return params, opt, loss


stream = DistillStream(1, batch, seq, cfg.vocab_size)
t0 = time.time()
for i, b in zip(range(pre_steps), stream):
    params, opt, loss = pretrain_step(params, opt, b["tokens"])
    if i % 25 == 0:
        print(f"[pretrain] step {i:4d} lm_loss={float(loss):.3f} "
              f"({time.time() - t0:.0f}s)", flush=True)

# ---- phase 2: the paper — freeze backbone, distill the write gate --------
print("\n[gate distillation] backbone FROZEN; training Write-Gate MLPs only")
params, state, hist = run_training(
    cfg, steps=args.gate_steps, batch=batch, seq=seq, lam=args.lam,
    params=params, out="/tmp/wgkv_gates.npz")
final = hist[-1]
print(f"\nfinal: distill={final['distill']:.4f} "
      f"admission_rate={final['admission_rate@0.1']:.3f} "
      f"(cache ~{final['admission_rate@0.1'] * 100:.0f}% + local window)")
print("gates saved to /tmp/wgkv_gates.npz")
