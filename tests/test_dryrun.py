"""Dry-run machinery: HLO collective parsing + small-mesh lowering (in a
subprocess so host-device-count flags never pollute this process)."""
import json
import os
import subprocess
import sys

import pytest

from repro.roofline.hlo_parse import parse_collectives, shape_bytes

HLO_SAMPLE = """
ENTRY %main {
  %p0 = f32[512]{0} parameter(0)
  %ar = f32[512]{0} all-reduce(f32[512]{0} %p0), replica_groups=[4,16]<=[64]
  %ag = bf16[16,1024]{1,0} all-gather(bf16[2,1024]{1,0} %x), replica_groups=[8,8]<=[64]
  %rs = f32[64]{0} reduce-scatter(f32[512]{0} %y), replica_groups=[8,8]<=[64]
  %cp.1 = bf16[2,64]{1,0} collective-permute(bf16[2,64]{1,0} %z)
  %a2a = (f32[8]{0}, f32[8]{0}) all-to-all(f32[8]{0} %u, f32[8]{0} %w), replica_groups=[16,4]<=[64]
}
"""


def test_shape_bytes():
    assert shape_bytes("f32[512]") == 2048
    assert shape_bytes("bf16[2,1024]") == 4096
    assert shape_bytes("pred[8]") == 8


def test_parse_collectives_kinds():
    total, detail = parse_collectives(HLO_SAMPLE, 64)
    assert set(detail) == {"all-reduce", "all-gather", "reduce-scatter",
                           "collective-permute", "all-to-all"}
    # all-reduce: 2 * 2048 * 15/16
    assert detail["all-reduce"]["bytes"] == pytest.approx(2 * 2048 * 15 / 16)
    # all-gather: out 16*1024*2 bytes * 7/8
    assert detail["all-gather"]["bytes"] == pytest.approx(32768 * 7 / 8)
    # reduce-scatter: out 256 bytes * (8-1)
    assert detail["reduce-scatter"]["bytes"] == pytest.approx(256 * 7)
    # permute: out bytes
    assert detail["collective-permute"]["bytes"] == pytest.approx(256)
    # all-to-all tuple: 2 * 32 bytes * 3/4
    assert detail["all-to-all"]["bytes"] == pytest.approx(64 * 3 / 4)
    assert total == pytest.approx(sum(d["bytes"] for d in detail.values()))


def test_parse_ignores_async_done():
    txt = """
  %ag-s = bf16[4,8]{1,0} all-gather-start(bf16[1,8]{1,0} %x), replica_groups=[2,4]<=[8]
  %ag-d = bf16[4,8]{1,0} all-gather-done(bf16[4,8]{1,0} %ag-s)
"""
    total, detail = parse_collectives(txt, 8)
    assert detail["all-gather"]["count"] == 1


SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, jax
from repro.configs import get_config, get_shape
from repro.launch.steps import make_bundle
mesh = jax.make_mesh((2, 4), ("data", "model"))
out = {}
for arch, shp, wg in [("qwen3-0.6b", "train_4k", True),
                      ("qwen3-0.6b", "decode_32k", True),
                      ("xlstm-350m", "long_500k", False),
                      ("recurrentgemma-9b", "decode_32k", True)]:
    cfg = get_config(arch)
    bundle = make_bundle(cfg, get_shape(shp), mesh, use_wgkv=wg)
    with mesh:
        compiled = jax.jit(bundle.fn, in_shardings=bundle.in_shardings,
                           donate_argnums=bundle.donate_argnums
                           ).lower(*bundle.args).compile()
    mem = compiled.memory_analysis()
    # peak_memory_in_bytes disappeared from newer jaxlib CompiledMemoryStats;
    # fall back to the arg+temp+output sum (same fields dryrun.py records)
    peak = getattr(mem, "peak_memory_in_bytes", None)
    if peak is None:
        peak = (mem.argument_size_in_bytes + mem.temp_size_in_bytes
                + mem.output_size_in_bytes)
    out[f"{arch}/{shp}"] = peak
print(json.dumps(out))
"""


@pytest.mark.slow
def test_small_mesh_lowering_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", SUBPROC], capture_output=True,
                       text=True, env=env, timeout=1200)
    assert r.returncode == 0, r.stderr[-3000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert len(out) == 4
    assert all(v > 0 for v in out.values())
