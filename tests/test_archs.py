"""Per-arch smoke + prefill/decode/forward consistency (reduced configs)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_cfg
from repro.configs import ARCH_NAMES, get_reduced_config
from repro.models import inference as I
from repro.models import registry as R
from repro.models import transformer as T


def _nodrop(cfg):
    if cfg.moe:
        return cfg.replace(moe=dataclasses.replace(
            cfg.moe, capacity_factor=float(cfg.moe.n_experts)))
    return cfg


def _inputs(cfg, key, b, s):
    kw = {}
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    if cfg.is_encdec:
        kw["enc_embeds"] = jax.random.normal(key, (b, 32, cfg.d_model)) * 0.1
    return toks, kw


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_smoke_forward_and_train_step(name, key):
    """Deliverable (f): reduced variant, one forward + one train step on
    CPU, asserting shapes and no NaNs."""
    cfg = make_cfg(name)
    params = T.init_model(key, cfg)
    assert R.count_params_tree(params) == R.count_params_analytic(cfg)
    b, s = 2, 64
    toks, kw = _inputs(cfg, key, b, s)
    out = T.forward(params, cfg, toks, mode="teacher", **kw)
    s_out = toks.shape[1]
    assert out.logits.shape == (b, s_out, cfg.vocab_size)
    assert not bool(jnp.isnan(out.logits.astype(jnp.float32)).any())
    # one train step (gate distillation, or LM for gate-less archs)
    from repro.training import trainer as TR
    batch = dict(tokens=toks, **kw)
    if cfg.wgkv.enabled and cfg.wgkv_applicable():
        state = TR.init_train_state(params)
        state2, m = TR.train_step(state, params, cfg, batch, lr=1e-3)
        assert np.isfinite(float(m["loss"]))
        changed = any(
            not np.allclose(np.asarray(a), np.asarray(bb))
            for a, bb in zip(state.gates.values(), state2.gates.values()))
        assert changed
    else:
        state = TR.init_lm_train_state(params)
        state2, m = TR.lm_train_step(state, cfg, batch, lr=1e-3)
        assert np.isfinite(float(m["loss"]))


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_prefill_decode_matches_forward(name, key):
    """THE system invariant: budgeted prefill + dual-cache decode ==
    dense (vertical-slash-masked) full forward, per arch."""
    cfg = _nodrop(make_cfg(name))
    params = T.init_model(key, cfg)
    b, s, k_steps = 2, 64, 3
    toks, kw = _inputs(cfg, key, b, s + k_steps)
    mode = "hard" if cfg.wgkv.enabled else "teacher"
    po, caches = I.prefill(params, cfg, toks[:, :s], budget=64, **kw)
    ref = T.forward(params, cfg, toks[:, :s], mode=mode, **kw).logits[:, -1]
    np.testing.assert_allclose(np.asarray(po.logits), np.asarray(ref),
                               atol=2e-4)
    for i in range(k_steps):
        logits, caches, _ = I.decode_step(params, cfg, toks[:, s + i], caches)
        refi = T.forward(params, cfg, toks[:, :s + i + 1], mode=mode,
                         **kw).logits[:, -1]
        np.testing.assert_allclose(np.asarray(logits), np.asarray(refi),
                                   atol=2e-4)


def test_gated_mode_interpolates(key):
    """Write-gated (soft) attention must land between teacher and hard.

    Fresh gates init near "admit" (~0.73), so at tau=0.1 the hard mask is
    identical to the teacher (everything admitted — itself an invariant we
    assert). With tau above the init point the hard mask actually drops
    tokens and the soft bias must sit strictly between the two."""
    cfg = make_cfg("qwen3-0.6b")
    params = T.init_model(key, cfg)
    toks = jax.random.randint(key, (2, 48), 0, cfg.vocab_size)
    t = T.forward(params, cfg, toks, mode="teacher").hidden
    h_low = T.forward(params, cfg, toks, mode="hard").hidden
    assert float(jnp.abs(t - h_low).max()) < 1e-5  # all admitted at tau=0.1
    import dataclasses
    cfg_hi = cfg.replace(wgkv=dataclasses.replace(cfg.wgkv, tau=0.95, sink=0))
    g = T.forward(params, cfg_hi, toks, mode="gated").hidden
    h = T.forward(params, cfg_hi, toks, mode="hard").hidden
    d_tg = float(jnp.abs(t - g).mean())
    d_th = float(jnp.abs(t - h).mean())
    assert d_tg > 0 and d_th > 0
    # fresh gates sit near "admit" => soft-gated closer to teacher than hard
    assert d_tg <= d_th


def test_vlm_embeds_and_mrope(key):
    cfg = make_cfg("qwen2-vl-7b")
    params = T.init_model(key, cfg)
    b, s, n_img = 2, 64, 16
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    patches = jax.random.normal(key, (b, n_img, cfg.d_model)) * 0.1
    emb, pos3 = R.build_vlm_embeds(params, cfg, toks, patches, (4, 4))
    assert emb.shape == (b, s, cfg.d_model)
    assert pos3.shape == (3, b, s)
    # vision span uses spatial ids; text ids equal across the 3 streams
    p = np.asarray(pos3[:, 0])
    assert (p[0, :n_img] == 0).all()
    assert (p[:, n_img:] == p[0:1, n_img:]).repeat(3, 0).all()
    out = T.forward(params, cfg, embeds=emb, positions=pos3, mode="hard")
    assert not bool(jnp.isnan(out.logits.astype(jnp.float32)).any())


def test_whisper_cross_attention_budgeting(key):
    """WG-KV on the cross stream: budgeted encoder memory still decodes."""
    cfg = make_cfg("whisper-medium")
    params = T.init_model(key, cfg)
    b = 2
    enc = jax.random.normal(key, (b, 64, cfg.d_model)) * 0.1
    toks = jax.random.randint(key, (b, 32), 0, cfg.vocab_size)
    po, caches = I.prefill(params, cfg, toks, enc_embeds=enc, budget=16)
    node = caches["blocks"]["b0"]["cross"]  # stacked: [n_repeats, B, H, C, hd]
    assert node.k.shape[-1] == cfg.head_dim
    assert node.k.shape[-2] == 16  # budgeted encoder memory
    logits, caches, _ = I.decode_step(params, cfg, toks[:, -1], caches)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())
