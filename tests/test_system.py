"""End-to-end behaviour: the paper's pipeline at CPU scale.

Uses the benchmark substrate (benchmarks/common.py): a tiny model
pre-trained on needle retrieval until induction forms, then write-gates
distilled — cached on disk so tests and benchmarks share one training run.

Validates the central claims qualitatively:
  1. WG-KV at a reduced cache keeps retrieval accuracy where local
     attention fails (Fig. 7 direction);
  2. the cache is actually sparse (admission rate < 1);
  3. the production serve path (prefill + dual-cache decode) answers.
"""
import functools
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import (SEQ, VOCAB, cache_size_at, needle_accuracy,
                               trained_model)
from repro.data.synthetic import needle_task
from repro.models import inference as I
from repro.models import transformer as T


@pytest.fixture(scope="module")
def trained():
    return trained_model()


def test_teacher_learned_retrieval(trained):
    cfg, params = trained
    acc = needle_accuracy(cfg, params, mode="teacher")
    assert acc > 0.5, f"teacher failed to learn retrieval: {acc}"


def test_wgkv_keeps_needle_local_attention_loses_it(trained):
    """The paper's core claim in miniature: at a small cache, learned
    admission retains retrieval while the static local policy fails."""
    cfg, params = trained
    acc_teacher = needle_accuracy(cfg, params, mode="teacher")
    acc_hard = needle_accuracy(cfg, params, mode="hard")
    # static local-window baseline: pure sliding-window attention
    cfg_local = cfg.replace(block_pattern=("local_attn",),
                            sliding_window=cfg.wgkv.w_local)
    acc_local = needle_accuracy(cfg_local, params, mode="teacher")
    assert acc_hard > acc_teacher - 0.15, (acc_hard, acc_teacher)
    assert acc_hard > acc_local + 0.3, (acc_hard, acc_local)


def test_admission_actually_sparse(trained):
    cfg, params = trained
    size = cache_size_at(cfg, params, cfg.wgkv.tau)
    assert size < 0.9  # not admit-everything


def test_serve_path_retrieves(trained):
    """prefill + dual-cache decode (the production path) answers the
    needle query with accuracy comparable to the dense hard-mode forward."""
    cfg, params = trained
    b = needle_task(jax.random.PRNGKey(780), 8, SEQ, VOCAB, payload=2)
    toks = b["tokens"]
    qpos = int(b["query_pos"])
    npre = (qpos + 1) - (qpos + 1) % cfg.wgkv.w_local
    po, caches = I.prefill(params, cfg, toks[:, :npre], budget=64)
    step = jax.jit(functools.partial(I.decode_step, cfg=cfg))
    preds = []
    for t in range(npre, qpos + 3):
        logits, caches, _ = step(params, token=toks[:, t], caches=caches)
        if t >= qpos:
            preds.append(np.asarray(jnp.argmax(logits, -1)))
    acc = (np.stack(preds[:2], 1) == np.asarray(b["answer"])).mean()
    ref = needle_accuracy(cfg, params, mode="hard", n=8, seed=780)
    assert acc >= ref - 0.2, f"serve path {acc} vs dense hard {ref}"
