"""Admission-gated prefix cache: content-addressed shared-context reuse.

Three layers under test:

  * pool refcount/COW (serving/paged.py): ``share_stream`` pins pages by
    refcount; writes through a shared stream copy-on-write, so sharers
    never observe each other's mutations;
  * the store itself (serving/prefix_cache.py): chained chunk hashing,
    longest-prefix lookup, LRU eviction under a byte budget with
    deferred reclamation of still-referenced entries;
  * serving integration: a prefix hit splices the cached post-admission
    tree and resumes the fused scan at the suffix — streams must be
    byte-identical to cold prefill, through cancellation and concurrent
    hits included.
"""
import jax
import numpy as np
import pytest

from conftest import make_cfg
from repro.models import transformer as T
from repro.serving import paged
from repro.serving.backend import make_backend
from repro.serving.orchestrator import (Orchestrator, SchedulerConfig,
                                        ServeSession)
from repro.serving.prefix_cache import CachedPrefix, PrefixCache, chain_hashes

CHUNK = 16


# ==========================================================================
# pool: refcounted pages + copy-on-write through shared streams
# ==========================================================================
def test_pool_share_stream_refcounts():
    pool = paged.PagedKVPool(64, head_dim=4)
    src = ("pfx", 0)
    for i in range(20):                      # 2 pages (16-slot pages)
        pool.append(src, np.full(4, i), np.full(4, -i))
    used = pool.pages_in_use
    pool.share_stream(src, ("slot", 0))
    assert pool.pages_in_use == used          # no new pages allocated
    for p in pool.table(src).pages:
        assert pool.refcount(p) == 2
    # freeing one sharer decrefs; pages survive for the other
    pool.free_stream(("slot", 0))
    assert pool.pages_in_use == used
    for p in pool.table(src).pages:
        assert pool.refcount(p) == 1
    pool.free_stream(src)
    assert pool.pages_in_use == 0


def test_pool_cow_append_isolates_sharers():
    pool = paged.PagedKVPool(64, head_dim=4)
    src = ("pfx", 0)
    for i in range(20):
        pool.append(src, np.full(4, i), np.full(4, i))
    k0, _ = pool.gather(src)
    pool.share_stream(src, ("slot", 0))
    # append through the sharer lands on the shared tail page -> COW
    pool.append(("slot", 0), np.full(4, 99.0), np.full(4, 99.0))
    assert pool.table(src).pages[-1] != pool.table(("slot", 0)).pages[-1]
    k1, _ = pool.gather(src)
    np.testing.assert_array_equal(k0, k1)     # source bytes untouched
    ks, _ = pool.gather(("slot", 0))
    assert ks.shape[0] == 21 and ks[-1, 0] == 99.0
    np.testing.assert_array_equal(ks[:20], k0)


def test_pool_cow_overwrite_isolates_sharers():
    pool = paged.PagedKVPool(64, head_dim=4)
    src = ("pfx", 0)
    for i in range(20):
        pool.append(src, np.full(4, i), np.full(4, i))
    pool.share_stream(src, ("a",))
    pool.share_stream(src, ("b",))
    pool.overwrite(("a",), 3, np.full(4, 7.0), np.full(4, 7.0))
    pool.overwrite(("b",), 3, np.full(4, 8.0), np.full(4, 8.0))
    ka, _ = pool.gather(("a",))
    kb, _ = pool.gather(("b",))
    k0, _ = pool.gather(src)
    assert k0[3, 0] == 3.0 and ka[3, 0] == 7.0 and kb[3, 0] == 8.0


# ==========================================================================
# store: chained hashing, lookup, LRU + deferred eviction
# ==========================================================================
def test_chain_hashes_commit_to_whole_prefix():
    p = list(range(70))
    hs = chain_hashes(p, CHUNK)
    assert [n for n, _ in hs] == [16, 32, 48, 64]
    # same prefix -> same hash, regardless of suffix
    assert chain_hashes(p[:40], CHUNK)[-1] == hs[1]
    # a change in an EARLIER chunk flips every later boundary hash
    q = list(p)
    q[3] += 1
    assert chain_hashes(q, CHUNK)[1][1] != hs[1][1]
    # whole-prompt boundary excluded: nothing to resume with
    assert [n for n, _ in chain_hashes(list(range(32)), CHUNK)] == [16]


def _entry(key, n_tokens, n_bytes=100):
    return CachedPrefix(key=key, n_tokens=n_tokens, caches=None,
                        n_bytes=n_bytes)


def test_store_lookup_longest_and_capture_target():
    store = PrefixCache(quantum=CHUNK, budget_bytes=1 << 20)
    p = list(range(70))
    hs = dict(chain_hashes(p, CHUNK))
    assert store.lookup(p) is None and store.misses == 1
    assert store.capture_target(p) == (64, hs[64])
    store.insert(_entry(hs[16], 16))
    store.insert(_entry(hs[48], 48))
    e = store.lookup(p)
    assert e is not None and e.n_tokens == 48    # longest stored prefix
    assert e.refs == 1 and store.hits == 1
    store.release(e)
    # a prompt diverging inside chunk 2 only matches the 16-boundary
    q = p[:20] + [999] * 50
    e2 = store.lookup(q)
    assert e2 is not None and e2.n_tokens == 16
    store.release(e2)
    # 48 stored but 64 not: capture still targets the longest boundary
    assert store.capture_target(p) == (64, hs[64])
    store.insert(_entry(hs[64], 64))
    assert store.capture_target(p) is None


def test_store_lru_eviction_and_deferred_reclaim():
    freed = []
    store = PrefixCache(quantum=CHUNK, budget_bytes=250,
                        free_fn=freed.append)
    a, b, c = _entry("a", 16), _entry("b", 16), _entry("c", 16)
    store.insert(a)
    store.insert(b)
    store.insert(c)                     # 300 bytes > 250: evicts LRU head
    assert "a" not in store and freed == [a]
    assert store.evictions == 1 and store.bytes_used == 200
    # pin b (an admitted request holds it), then force its eviction
    b.refs += 1
    store.insert(_entry("d", 16))
    assert "b" not in store and freed == [a]   # deferred: still referenced
    store.release(b)
    assert freed == [a, b]              # reclaimed at the last release
    # raced duplicate insert keeps the incumbent, frees the newcomer
    dup = _entry("c", 16)
    store.insert(dup)
    assert freed == [a, b, dup] and store._entries["c"] is c
    store.clear()
    assert len(store) == 0 and c in freed and store.bytes_used == 0


# ==========================================================================
# serving integration: hit == cold bytes, cancel, concurrency, cleanup
# ==========================================================================
@pytest.fixture(scope="module")
def served():
    cfg = make_cfg("qwen3-0.6b", global_budget_frac=0.5)
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    eng = make_backend("wgkv", params, cfg, slots=2, capacity=192)
    return cfg, eng


def _prompts(cfg, shared=48, tails=(8, 8), seed=0):
    rng = np.random.default_rng(seed)
    base = rng.integers(0, cfg.vocab_size - 8, size=shared).tolist()
    return [base + rng.integers(0, cfg.vocab_size - 8, size=t).tolist()
            for t in tails]


def _serve(eng, prompts, pc=None, max_new=4, **sched_kw):
    sess = ServeSession(eng, sched=SchedulerConfig(chunk_tokens=CHUNK,
                                                   **sched_kw),
                        prefix_cache=pc)
    hs = [sess.submit(p, max_new=max_new) for p in prompts]
    sess.run()
    sess.close()
    return [h.tokens() for h in hs], sess


def test_quantum_must_match_chunk(served):
    _, eng = served
    with pytest.raises(ValueError, match="quantum"):
        Orchestrator(eng, sched=SchedulerConfig(chunk_tokens=CHUNK),
                     prefix_cache=PrefixCache(quantum=CHUNK + 1))


def test_hit_streams_cold_bytes(served):
    """Round 2 hits the store for every request and streams exactly what
    cold prefill streamed; telemetry reports the hit."""
    cfg, eng = served
    prompts = _prompts(cfg)
    cold, _ = _serve(eng, prompts)
    pc = PrefixCache(quantum=CHUNK, free_fn=eng.release_prefix)
    warm1, _ = _serve(eng, prompts, pc)
    assert warm1 == cold                       # miss round: no effect
    assert pc.misses == 2 and pc.hits == 0 and len(pc) == 1
    warm2, sess = _serve(eng, prompts, pc)
    assert warm2 == cold                       # hit round: same bytes
    assert pc.hits == 2
    s = sess.telemetry.summary()
    assert s["prefix_hit_rate"] == 1.0
    assert s["prefix_tokens_reused"] == 2 * 48
    assert s["counters"]["prefix_hit"] == 2
    assert sess.telemetry.records[0].prefix_hit
    pc.clear()
    assert eng.pool.pages_in_use == 0          # store pages all reclaimed


def test_concurrent_hits_never_share_mutable_state(served):
    """Two simultaneous hits on one entry decode divergent suffixes; the
    entry's pool bytes must be untouched and both streams cold-exact."""
    cfg, eng = served
    prompts = _prompts(cfg, tails=(8, 12), seed=1)
    cold, _ = _serve(eng, prompts)
    pc = PrefixCache(quantum=CHUNK, free_fn=eng.release_prefix)
    _serve(eng, [prompts[0]], pc)              # populate (one miss)
    (entry,) = pc._entries.values()
    before = {k: pool_k.copy() for k in entry.stream_keys
              for pool_k in [eng.pool.gather(k)[0]]}
    warm, _ = _serve(eng, prompts, pc)         # both hit the same entry
    assert pc.hits == 2
    assert warm == cold
    assert entry.refs == 0                     # pins dropped post-splice
    for k in entry.stream_keys:                # entry bytes never mutated
        np.testing.assert_array_equal(eng.pool.gather(k)[0], before[k])
    pc.clear()
    assert eng.pool.pages_in_use == 0


def test_cancel_before_splice_releases_ref(served):
    """A request admitted on a hit but cancelled before its first
    dispatch drops its store pin, so eviction can reclaim the entry."""
    cfg, eng = served
    prompts = _prompts(cfg, tails=(8, 8), seed=2)
    pc = PrefixCache(quantum=CHUNK, free_fn=eng.release_prefix)
    _serve(eng, [prompts[0]], pc)              # populate
    (entry,) = pc._entries.values()
    # max_prefill_batch=1: both admits land in one tick, only the first
    # task dispatches — the second sits admitted with its entry pinned
    orch = Orchestrator(eng, sched=SchedulerConfig(chunk_tokens=CHUNK,
                                                   max_prefill_batch=1),
                        prefix_cache=pc)
    r0 = orch.submit(prompts[0], max_new=2)
    r1 = orch.submit(prompts[1], max_new=2)
    orch.tick()
    assert entry.refs == 1                     # r0 released at dispatch
    assert orch.cancel(r1)
    assert entry.refs == 0                     # cancel released the pin
    orch.run()
    orch.telemetry.stop()
    assert len(orch.tokens(r0)) == 2
    pc.clear()
    assert eng.pool.pages_in_use == 0


def test_async_dispatch_hits_match_sync(served):
    """dispatch_ahead=1 over the store streams the same bytes (captures
    mature at FIFO collect regardless of the in-flight window)."""
    cfg, eng = served
    prompts = _prompts(cfg, tails=(8, 8), seed=3)
    cold, _ = _serve(eng, prompts)
    pc = PrefixCache(quantum=CHUNK, free_fn=eng.release_prefix)
    _serve(eng, prompts, pc, dispatch_ahead=1)
    warm, _ = _serve(eng, prompts, pc, dispatch_ahead=1)
    assert warm == cold and pc.hits == 2
    pc.clear()
    assert eng.pool.pages_in_use == 0
