"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.gate_mlp import gate_mlp
from repro.kernels.gated_flash import gated_flash
from repro.kernels.paged_decode import paged_decode, paged_decode_selected
from repro.kernels.rglru_scan import rglru_scan_pallas
from repro.kernels.vertical_slash import vertical_slash

TOL = {jnp.float32: 5e-5, jnp.bfloat16: 5e-2}


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape).astype(dtype)


@pytest.mark.parametrize("n,s,hd,w,bq,bk", [
    (2, 256, 64, 32, 64, 64),
    (1, 128, 128, 16, 128, 32),
    (3, 512, 64, 256, 128, 128),
    (1, 64, 256, 8, 32, 16),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gated_flash_sweep(n, s, hd, w, bq, bk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    q, k, v = (_rand(ks[i], (n, s, hd), dtype) for i in range(3))
    g = jax.nn.sigmoid(jax.random.normal(ks[3], (n, s))).astype(jnp.float32)
    out = gated_flash(q, k, v, g, w_local=w, bq=bq, bk=bk)
    r = ref.gated_flash_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                            v.astype(jnp.float32), g, w_local=w)
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(r),
                               atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("n,s,hd,w,c,bc", [
    (2, 256, 64, 64, 64, 32),
    (1, 512, 128, 128, 128, 128),
    (2, 384, 64, 128, 96, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_vertical_slash_sweep(n, s, hd, w, c, bc, dtype):
    ks = jax.random.split(jax.random.PRNGKey(1), 6)
    q, k, v = (_rand(ks[i], (n, s, hd), dtype) for i in range(3))
    gpos = jnp.sort(jax.random.randint(ks[3], (n, c), 0, s - w), axis=-1)
    nvalid = jax.random.randint(ks[4], (n, 1), 1, c)
    gpos = jnp.where(jnp.arange(c)[None] < nvalid, gpos,
                     jnp.iinfo(jnp.int32).max)
    bi = jnp.arange(n)[:, None]
    safe = jnp.minimum(gpos, s - 1)
    kg = jnp.where((gpos < s)[..., None], k[bi, safe], 0)
    vg = jnp.where((gpos < s)[..., None], v[bi, safe], 0)
    out = vertical_slash(q, k, v, kg, vg, gpos, w_local=w, bc=bc)
    r = ref.vertical_slash_ref(*(x.astype(jnp.float32)
                                 for x in (q, k, v, kg, vg)), gpos, w_local=w)
    assert not bool(jnp.isnan(out.astype(jnp.float32)).any())
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(r),
                               atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("n,hd,page,ptotal,mp", [
    (6, 64, 16, 32, 8), (2, 128, 16, 8, 4), (12, 64, 32, 64, 16),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_decode_sweep(n, hd, page, ptotal, mp, dtype):
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    q = _rand(ks[0], (n, hd), dtype)
    kp = _rand(ks[1], (ptotal, page, hd), dtype)
    vp = _rand(ks[2], (ptotal, page, hd), dtype)
    tbl = jax.random.randint(ks[3], (n, mp), 0, ptotal)
    lens = jax.random.randint(ks[4], (n,), 1, mp * page)
    out = paged_decode(q, kp, vp, tbl, lens)
    r = ref.paged_decode_ref(*(x.astype(jnp.float32) for x in (q, kp, vp)),
                             tbl, lens)
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(r),
                               atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("n,hd,page,ptotal,mp,kp", [
    (6, 64, 16, 32, 8, 3), (2, 128, 16, 8, 4, 2), (4, 64, 32, 64, 16, 5),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_decode_selected_sweep(n, hd, page, ptotal, mp, kp, dtype):
    """Quest-selected paged decode vs oracle: random sorted K-subsets of
    each stream's logical pages, ragged n_sel (trailing ids dropped)."""
    ks = jax.random.split(jax.random.PRNGKey(6), 7)
    q = _rand(ks[0], (n, hd), dtype)
    kpool = _rand(ks[1], (ptotal, page, hd), dtype)
    vpool = _rand(ks[2], (ptotal, page, hd), dtype)
    tbl = jax.random.randint(ks[3], (n, mp), 0, ptotal)
    lens = jax.random.randint(ks[4], (n,), 1, mp * page)
    perm = jax.random.uniform(ks[5], (n, mp)).argsort(axis=-1)[:, :kp]
    sel = jnp.sort(perm, axis=-1).astype(jnp.int32)
    nsel = jax.random.randint(ks[6], (n,), 1, kp + 1)
    out = paged_decode_selected(q, kpool, vpool, tbl, lens, sel, nsel)
    r = ref.paged_decode_selected_ref(
        *(x.astype(jnp.float32) for x in (q, kpool, vpool)),
        tbl, lens, sel, nsel)
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(r),
                               atol=TOL[dtype], rtol=TOL[dtype])


def test_paged_decode_selected_all_pages_identity():
    """K covering every page with the ascending id list is the identity
    permutation: the selected kernel reduces over the same lanes in the
    same order as the dense-page kernel, so outputs are BITWISE equal —
    the kernel-level form of the serving parity acceptance axis."""
    n, hd, page, ptotal, mp = 4, 64, 16, 16, 6
    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    q = _rand(ks[0], (n, hd), jnp.float32)
    kpool = _rand(ks[1], (ptotal, page, hd), jnp.float32)
    vpool = _rand(ks[2], (ptotal, page, hd), jnp.float32)
    tbl = jax.random.randint(ks[3], (n, mp), 0, ptotal)
    lens = jax.random.randint(ks[4], (n,), 1, mp * page)
    sel = jnp.broadcast_to(jnp.arange(mp, dtype=jnp.int32)[None], (n, mp))
    nsel = jnp.full((n,), mp, jnp.int32)
    a = paged_decode(q, kpool, vpool, tbl, lens)
    b = paged_decode_selected(q, kpool, vpool, tbl, lens, sel, nsel)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("b,s,d,bt,bd", [
    (2, 256, 256, 64, 128), (1, 128, 512, 128, 128), (3, 64, 128, 32, 64),
])
def test_rglru_scan_sweep(b, s, d, bt, bd):
    ks = jax.random.split(jax.random.PRNGKey(3), 2)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (b, s, d)))
    bb = jax.random.normal(ks[1], (b, s, d))
    out = rglru_scan_pallas(a, bb, bt=bt, bd=bd)
    r = ref.rglru_scan_ref(a, bb)
    np.testing.assert_allclose(np.asarray(out), np.asarray(r), atol=1e-5)


@pytest.mark.parametrize("h,s,f,m,bs", [
    (4, 512, 128, 64, 128), (2, 64, 64, 32, 64), (8, 256, 256, 16, 256),
])
def test_gate_mlp_sweep(h, s, f, m, bs):
    ks = jax.random.split(jax.random.PRNGKey(4), 4)
    x = jax.random.normal(ks[0], (h, s, f))
    w1 = jax.random.normal(ks[1], (h, f, m)) * 0.1
    b1 = jax.random.normal(ks[2], (h, m)) * 0.1
    w2 = jax.random.normal(ks[3], (h, m, 1)) * 0.1
    b2 = jnp.zeros((h, 1))
    out = gate_mlp(x, w1, b1, w2, b2, bs=bs)
    r = ref.gate_mlp_ref(x, w1, b1, w2, b2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(r), atol=1e-5)
    assert ((np.asarray(out) > 0) & (np.asarray(out) < 1)).all()


def test_ops_wrappers_gqa_fold():
    """Model-level wrappers: GQA head folding matches core mask semantics."""
    from repro.core import masks as M
    from repro.kernels import ops

    key = jax.random.PRNGKey(5)
    B, Hq, Hkv, S, hd, W = 2, 4, 2, 128, 64, 32
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (B, Hq, S, hd))
    k = jax.random.normal(ks[1], (B, Hkv, S, hd))
    v = jax.random.normal(ks[2], (B, Hkv, S, hd))
    g = jax.nn.sigmoid(jax.random.normal(ks[3], (B, Hkv, S)))
    out = ops.gated_flash_attention(q, k, v, g, w_local=W, bq=64, bk=64)
    bias = M.write_gate_bias(g, S, W)
    qg = q.reshape(B, Hkv, Hq // Hkv, S, hd)
    logits = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k) / jnp.sqrt(hd) + bias[:, :, None]
    r = jnp.einsum("bhgqk,bhkd->bhgqd", jax.nn.softmax(logits, -1), v)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(r.reshape(B, Hq, S, hd)), atol=5e-5)
