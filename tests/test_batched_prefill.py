"""Batched ragged prefill (Engine._extend_ragged /
prefill_extend_ragged): every mid-prefill task advances in ONE jitted
device call, with writes masked past each row's length.

Parity standard (the repo's cross-batch-size standard, as in
test_backends dense-vs-legacy): integer cache state (t, ring ptr, global
counts — i.e. WHICH tokens the gate admitted and where they live) must
be EXACTLY equal to the sequential batch-of-one driver, greedy tokens
byte-identical, float KV payloads allclose (XLA CPU matmuls are not
bit-invariant to batch size), admission accounting approx-equal. Rows
the ragged call merely pads (length 0, or a row finishing mid-batch)
must come out BITWISE identical — the mask selects the old leaves
verbatim.

Deterministic cases always run; the hypothesis property sweep (random
mixed-length batches) rides along when hypothesis is installed (CI)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_cfg
from repro.models import inference as I
from repro.models import transformer as T
from repro.serving.backend import make_backend
from repro.serving.orchestrator import Orchestrator, SchedulerConfig

try:
    import hypothesis
    import hypothesis.strategies as st
    from hypothesis import given, settings
    # each example runs full model scans on CPU: keep the fleet tiny
    hypothesis.settings.register_profile(
        "batched_prefill", settings(max_examples=5, deadline=None,
                                    derandomize=True))
    hypothesis.settings.load_profile("batched_prefill")
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

pytestmark = pytest.mark.backends

CHUNK = 16
BACKEND_NAMES = ("wgkv", "dense", "streaming_llm")


@pytest.fixture(scope="module")
def served():
    # tau=0.1 per the knife-edge note: random-init gate scores cluster at
    # 0.5, so parity across prefill drivers pins tau well away from it
    cfg = make_cfg("qwen3-0.6b", global_budget_frac=0.5)
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.fixture(scope="module")
def engines(served):
    """One engine per backend, shared across drivers and examples: task
    state lives on the PrefillTask, so prefill parity never depends on
    engine-side mutable state, and the jitted shapes compile once."""
    cfg, params = served
    cache = {}

    def get(name):
        if name not in cache:
            cache[name] = make_backend(name, params, cfg, slots=4,
                                       capacity=128, mirror_paged=False)
        return cache[name]

    return get


def _leaf_pairs(a, b):
    fa = jax.tree_util.tree_flatten_with_path(a)[0]
    fb = jax.tree_util.tree_flatten_with_path(b)[0]
    assert len(fa) == len(fb)
    for (pa, x), (_, y) in zip(fa, fb):
        yield jax.tree_util.keystr(pa), np.asarray(x), np.asarray(y)


def assert_tree_parity(a, b, *, exact: bool, atol: float = 1e-5):
    """Integer/bool leaves exactly equal; float leaves exact or allclose."""
    for path, x, y in _leaf_pairs(a, b):
        if exact or np.issubdtype(x.dtype, np.integer) or x.dtype == bool:
            np.testing.assert_array_equal(x, y, err_msg=path)
        else:
            np.testing.assert_allclose(x, y, atol=atol, rtol=0, err_msg=path)


def _extend(eng, tasks, chunk=CHUNK):
    """One coalesced ragged advance of task-local batch trees (the drive
    the offline ``prefill`` wrapper and these parity checks share)."""
    for t in tasks:
        if t.caches is None:
            t.caches = eng._fresh_task_caches()
    eng._extend_ragged(tasks, chunk)


def _make_task(eng, prompt, *, advance_chunks: int):
    task = eng.start_prefill(prompt)
    for _ in range(advance_chunks):
        if not task.done:
            _extend(eng, [task])
    return task


# ==========================================================================
# kernel level: prefill_extend_ragged masks padded rows bitwise
# ==========================================================================
def check_zero_and_short_rows(eng, take: int, seed: int):
    """A batch where one row takes ``take`` tokens and another takes 0:
    the length-0 row's caches come out BITWISE unchanged and its stats
    are zero, whatever the other rows do."""
    rng = np.random.default_rng(seed)
    t0 = _make_task(eng, list(rng.integers(0, 200, 32)), advance_chunks=1)
    t1 = _make_task(eng, list(rng.integers(0, 200, 48)), advance_chunks=1)
    batched = eng.batched_prefill_stack([t0.caches, t1.caches])
    toks = np.zeros((2, CHUNK), np.int32)
    toks[0, :take] = t0.prompt[t0.pos:t0.pos + take]
    lengths = jnp.asarray([take, 0], jnp.int32)
    logits, out, stats = eng._extend_batch(
        eng.params, (jnp.asarray(toks), lengths), batched)
    row0, row1 = eng.batched_prefill_unstack(out, 2)
    # the length-0 row is bitwise untouched, with zero logits and stats
    assert_tree_parity(row1, t1.caches, exact=True)
    np.testing.assert_array_equal(np.asarray(logits[1]), 0.0)
    assert float(stats["adm_sum_rows"][1]) == 0.0
    assert float(stats["evict_trigger_rows"][1]) == 0.0
    if take == 0:
        assert_tree_parity(row0, t0.caches, exact=True)
    else:
        # the active row advanced by exactly its length
        np.testing.assert_array_equal(np.asarray(row0["t"]),
                                      np.asarray(t0.caches["t"]) + take)


def test_ragged_kernel_zero_and_short_rows(engines):
    eng = engines("wgkv")
    for take in (0, 7, CHUNK):
        check_zero_and_short_rows(eng, take, seed=take)


# ==========================================================================
# backend level: one coalesced ragged extend == sequential batch-of-one
# extends, mixed lengths (ragged tails, short prompts, rows finishing
# mid-batch)
# ==========================================================================
def check_batch_matches_sequential(eng, prompts):
    def drive(batched):
        tasks = [eng.start_prefill(p) for p in prompts]
        ticks = 0
        while not all(t.done for t in tasks):
            live = [t for t in tasks if not t.done]
            if batched:
                _extend(eng, live)
            else:
                for t in live:
                    _extend(eng, [t])
            ticks += 1
            assert ticks < 100
        return tasks

    for a, b in zip(drive(False), drive(True)):
        assert a.pos == b.pos == len(a.prompt)
        assert_tree_parity(a.caches, b.caches, exact=False)
        np.testing.assert_allclose(np.asarray(a.last_logits),
                                   np.asarray(b.last_logits), atol=1e-4,
                                   rtol=0)
        assert a.adm_weighted == pytest.approx(b.adm_weighted, rel=1e-5)
        # greedy first token (the stream byte the scheduler emits at
        # finish_prefill) is identical
        pa = eng.finish_prefill(a)
        pb = eng.finish_prefill(b)
        assert pa.first_token == pb.first_token
        assert pa.mean_admission == pytest.approx(pb.mean_admission,
                                                  rel=1e-5)


@pytest.mark.parametrize("name", BACKEND_NAMES)
def test_batch_matches_sequential_mixed_lengths(engines, name):
    """One deterministic mixed batch per backend family: a window-aligned
    prompt, a ragged tail, a sub-window short prompt (finishes on its
    first ragged row), and a mid-size prompt — so rows finish mid-batch
    while others continue as padding-masked lanes."""
    rng = np.random.default_rng(7)
    prompts = [list(rng.integers(0, 200, n)) for n in (48, 55, 10, 33)]
    check_batch_matches_sequential(engines(name), prompts)


if HAS_HYPOTHESIS:
    @given(plens=st.lists(st.integers(2, 60), min_size=2, max_size=4),
           seed=st.integers(0, 3))
    def test_property_batch_matches_sequential(engines, plens, seed):
        """Hypothesis sweep: random mixed-length prefill batches stay
        bit-identical (integer cache state + greedy tokens) to the
        sequential driver for the learned-gate backend."""
        rng = np.random.default_rng(seed + 100)
        prompts = [list(rng.integers(0, 200, n)) for n in plens]
        check_batch_matches_sequential(engines("wgkv"), prompts)

    @given(take=st.integers(0, CHUNK), seed=st.integers(0, 3))
    def test_property_zero_row_bitwise(engines, take, seed):
        check_zero_and_short_rows(engines("wgkv"), take, seed)


# ==========================================================================
# all three backend families: the fused serving stream matches the
# offline ``prefill`` wrapper's admission view of the same prompts
# (the orchestrator-level batched-vs-per-request A/B retired with the
# per-request driver; cross-driver stream parity lives in
# test_fused_tick.py)
# ==========================================================================
@pytest.mark.parametrize("name", BACKEND_NAMES)
def test_serving_stream_admission_matches_offline(served, engines, name):
    prompts = [list(range(10, 58)), list(range(5, 60)),
               list(range(20, 30)), list(range(7, 52))]
    eng = engines(name)
    orch = Orchestrator(eng, sched=SchedulerConfig(chunk_tokens=CHUNK))
    for p in prompts:
        orch.submit(p, max_new=5)
    orch.run()
    toks = [orch.tokens(r) for r in range(len(prompts))]
    assert all(len(t) == 5 for t in toks)
    s = orch.telemetry.summary()
    assert s["counters"]["prefill_tokens"] == sum(map(len, prompts))
    # the offline wrapper (same chunk width) sees the same admission
    # mass and the same first byte each stream opened with
    for p, t in zip(prompts, toks):
        pre = eng.prefill(p, chunk_tokens=CHUNK)
        assert pre.first_token == t[0]
    offline = [eng.prefill(p, chunk_tokens=CHUNK).mean_admission
               for p in prompts]
    assert s["mean_admission"] == pytest.approx(
        sum(offline) / len(offline), rel=1e-5)


# ==========================================================================
# composition: eviction obs-tree state survives the masked batch path
# ==========================================================================
def test_batched_prefill_with_eviction_obs(served):
    """The ``obs`` tree (batch axis 2) masks correctly: batched vs
    sequential prefill agree with SnapKV eviction armed."""
    cfg, params = served
    opts = I.DecodeOptions(evict_hard_budget=48, w_obs=16)
    eng = make_backend("wgkv", params, cfg, slots=2, capacity=128,
                       opts=opts, mirror_paged=False)
    rng = np.random.default_rng(11)
    prompts = [list(rng.integers(0, 200, 48)), list(rng.integers(0, 200, 35))]
    check_batch_matches_sequential(eng, prompts)
