"""Decode-time top-K page selection (Quest) in the fused serving tick.

Parity standard: ``topk_page_ids`` returns ascending-sorted page ids, so
when K covers every page the id list is the identity permutation and the
gathered decode path reduces over the same lanes in the same order as
the full path — greedy streams must be BYTE-identical to selection off
(``selection=None``). With K < pages the gathered path must still serve
complete streams while touching fewer pages (``selected_pages``
counter), and the incremental ``pkmin``/``pkmax`` page metadata the dual
cache maintains in-jit must equal a from-scratch ``build_page_meta``
rebuild after prefill + decode + slot-churn. The 2x4-mesh variant of the
stream parity lives in test_sharded_serving.py; the kernel-level sweep
in test_kernels.py.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_cfg
from repro.core import admission as A
from repro.core.selection import PAGE_SIZE, build_page_meta
from repro.models import transformer as T
from repro.serving.backend import make_backend
from repro.serving.obs import Tracer
from repro.serving.orchestrator import SchedulerConfig, ServeSession

pytestmark = pytest.mark.backends

CAPACITY = 64
ALL_PAGES = CAPACITY // PAGE_SIZE  # quest:4 covers every page
MAX_NEW = 12

_rng = np.random.default_rng(42)
# long enough past w_local=16 that the gate populates global pages
PROMPTS = [list(_rng.integers(0, 200, 48 + 8 * i)) for i in range(4)]


@pytest.fixture(scope="module")
def served():
    # tau=0.1 keeps the threshold away from the random-init gate-score
    # cluster at 0.5 (knife-edge note), so both decode paths admit the
    # same token set and byte-parity is meaningful
    cfg = make_cfg("qwen3-0.6b")
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _serve(params, cfg, selection, backend="wgkv"):
    eng = make_backend(backend, params, cfg, slots=2, capacity=CAPACITY,
                       temperature=0.0, seed=0, selection=selection)
    tracer = Tracer(capacity=1 << 14)
    sess = ServeSession(eng, sched=SchedulerConfig(chunk_tokens=16,
                                                   dispatch_ahead=1),
                        tracer=tracer)
    handles = [sess.submit(p, max_new=MAX_NEW) for p in PROMPTS]
    sess.run()
    streams = [tuple(h.tokens()) for h in handles]
    counters = dict(sess.orchestrator.telemetry.counters)
    sess.close()
    spans = [s.name for s in tracer.spans]
    return streams, counters, spans, eng.capabilities()


@pytest.fixture(scope="module")
def runs(served):
    """Off / K-covers-all / partial-K serves of the same workload, shared
    across the assertions below (each serve compiles the fused step)."""
    cfg, params = served
    return {sel: _serve(params, cfg, sel)
            for sel in (None, f"quest:{ALL_PAGES}", "quest:2")}


# ==========================================================================
# byte parity: selection with K covering every page == selection off
# ==========================================================================
def test_stream_parity_off_vs_all_pages(runs):
    base, _, spans0, cap0 = runs[None]
    sel_all, c_all, spans_all, cap_all = runs[f"quest:{ALL_PAGES}"]
    assert cap0.selection is None
    assert cap_all.selection == f"quest:{ALL_PAGES}"
    assert all(len(s) == MAX_NEW for s in base)
    assert base == sel_all
    # the gathered path actually ran (counters + trace span), and the
    # off path never did
    assert c_all["selected_pages"] > 0 and c_all["selection_time_s"] > 0
    assert "selection" in spans_all
    assert "selection" not in spans0


def test_stream_parity_static_backend(served):
    """The static-admission backend family inherits the same selection
    surface: off vs K-all byte-identical there too."""
    cfg, params = served
    base, _, _, _ = _serve(params, cfg, None, backend="streaming_llm")
    sel, c, _, cap = _serve(params, cfg, f"quest:{ALL_PAGES}",
                            backend="streaming_llm")
    assert cap.selection == f"quest:{ALL_PAGES}"
    assert c["selected_pages"] > 0
    assert base == sel


# ==========================================================================
# partial K: streams complete, fewer pages gathered
# ==========================================================================
def test_partial_k_serves_with_fewer_pages(runs):
    _, c0, _, _ = runs[None]
    _, c_all, _, _ = runs[f"quest:{ALL_PAGES}"]
    sel2, c2, spans2, cap2 = runs["quest:2"]
    assert cap2.selection == "quest:2"
    assert all(len(s) == MAX_NEW for s in sel2)
    assert c0.get("selected_pages", 0) == 0
    assert 0 < c2["selected_pages"] < c_all["selected_pages"]
    assert c2["selection_time_s"] > 0
    assert "selection" in spans2


def test_dense_rejects_selection(served):
    cfg, params = served
    with pytest.raises(ValueError, match="selection"):
        make_backend("dense", params, cfg, slots=2, capacity=CAPACITY,
                     selection="quest:2")


# ==========================================================================
# incremental page metadata == from-scratch rebuild after churn
# ==========================================================================
def _assert_meta_matches_rebuild(eng):
    """Every dual-cache leaf's incrementally-maintained pkmin/pkmax equals
    build_page_meta over the live global entries — bitwise (min/max are
    exact, and both paths fold exactly the valid lanes)."""
    checked = 0
    for lkey, dc in eng._iter_dual(eng.caches):
        c = dc.gk.shape[2]
        valid = jnp.arange(c)[None, None] < dc.gcnt[..., None]
        meta = build_page_meta(dc.gk, valid)
        np.testing.assert_array_equal(
            np.asarray(dc.pkmin), np.asarray(meta.kmin), err_msg=str(lkey))
        np.testing.assert_array_equal(
            np.asarray(dc.pkmax), np.asarray(meta.kmax), err_msg=str(lkey))
        checked += 1
    assert checked > 0


def test_incremental_meta_matches_rebuild(served):
    cfg, params = served
    eng = make_backend("wgkv", params, cfg, slots=2, capacity=CAPACITY,
                       temperature=0.0, seed=0)
    eng.insert(eng.prefill(PROMPTS[0], emit_first=True), 0)
    eng.insert(eng.prefill(PROMPTS[1], emit_first=True), 1)
    for _ in range(8):
        eng.collect(eng.step_batch([]))
    _assert_meta_matches_rebuild(eng)
    # slot churn: retire row 0 and splice a fresh request in, then decode
    # past a page boundary — the boundary-reset in the incremental update
    # must stop the retired occupant's metadata widening the bounds
    eng.free_slot(0)
    eng.insert(eng.prefill(PROMPTS[2], emit_first=True), 0)
    for _ in range(8):
        eng.collect(eng.step_batch([]))
    _assert_meta_matches_rebuild(eng)
    # at least one stream actually promoted past the ring into global
    assert any(int(np.asarray(dc.gcnt).max()) > 0
               for _, dc in eng._iter_dual(eng.caches))


# ==========================================================================
# knife-edge tau guard (the parity footgun behind the tau=0.1 convention)
# ==========================================================================
def test_tau_guard_warns_on_knife_edge():
    g = jnp.asarray([0.40, 0.5004, 0.60])
    with pytest.warns(RuntimeWarning, match="knife-edge"):
        m = A.check_tau_margin(g, 0.5)
    assert m == pytest.approx(4e-4, rel=1e-3)
    # a tau clear of the score cluster passes silently and reports margin
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        m2 = A.check_tau_margin(g, 0.1)
    assert m2 == pytest.approx(0.30, rel=1e-5)
