"""Serving engine + paged memory integration."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_cfg
from repro.models import inference as I
from repro.models import transformer as T
from repro.serving import paged
from repro.serving.engine import Engine


@pytest.fixture(scope="module")
def served():
    cfg = make_cfg("qwen3-0.6b", global_budget_frac=0.5)
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_pool_allocator_basics():
    pool = paged.PagedKVPool(16, head_dim=4)
    key = (0, 0, 0, "global")
    for i in range(40):
        pool.append(key, np.full(4, i), np.full(4, -i))
    t = pool.table(key)
    assert t.length == 40
    assert len(t.pages) == 3  # ceil(40/16)
    k, v = pool.gather(key)
    assert (k[:, 0] == np.arange(40)).all()
    used = pool.pages_in_use
    pool.free_stream(key)
    assert pool.pages_in_use == used - 3


def test_pool_exhaustion():
    pool = paged.PagedKVPool(3, head_dim=4)  # page 0 reserved => 2 usable
    key = (0,)
    with pytest.raises(paged.PoolExhausted):
        for i in range(100):
            pool.append(key, np.zeros(4), np.zeros(4))


def test_pool_fragmentation_metric():
    pool = paged.PagedKVPool(64, head_dim=4)
    pool.append((1,), np.zeros(4), np.zeros(4))  # 1 token on a 16-slot page
    assert pool.utilization() == pytest.approx(1 / 16)


def test_engine_end_to_end(served):
    cfg, params = served
    eng = Engine(params, cfg, slots=2, capacity=128, pool_pages=4096)
    rids = [eng.add_request(list(range(10 + i, 60 + i)), max_new=6)
            for i in range(3)]
    eng.run(max_steps=40)
    assert all(eng.requests[r].done for r in rids)
    assert all(len(eng.requests[r].out) == 6 for r in rids)
    assert eng.pool.pages_in_use == 0  # everything freed


def test_engine_paged_mirror_exact(served):
    """Physical pool bytes == logical dual cache, and the paged_decode
    kernel over the pool matches an oracle computed from the logical view."""
    cfg, params = served
    eng = Engine(params, cfg, slots=2, capacity=128, pool_pages=4096)
    eng.add_request(list(range(5, 55)), max_new=30)
    eng.add_request(list(range(100, 170)), max_new=30)
    for _ in range(10):
        eng.step()
    assert eng.verify_paged() < 2e-3


def test_engine_matches_raw_decode(served):
    """Engine output tokens == direct prefill+decode greedy rollout.

    The first token comes from the prefill's own last-position logits
    (the retired convention re-fed ``prompt[-1]``, double-writing its KV
    at position n); later tokens from the decode loop."""
    cfg, params = served
    prompt = list(range(20, 68))  # 48 tokens = 3 x w_local
    eng = Engine(params, cfg, slots=1, capacity=128, mirror_paged=False)
    rid = eng.add_request(prompt, max_new=5)
    eng.run(max_steps=10)
    got = eng.requests[rid].out
    toks = jnp.asarray(prompt, jnp.int32)[None]
    po, caches = I.prefill(params, cfg, toks,
                           budget=cfg.wgkv.global_budget(128), max_len=128)
    cur = int(jnp.argmax(po.logits[0]))
    want = [cur]
    for _ in range(4):
        logits, caches, _ = I.decode_step(
            params, cfg, jnp.asarray([cur], jnp.int32), caches)
        cur = int(jnp.argmax(logits[0]))
        want.append(cur)
    assert got == want


def test_engine_with_composition(served):
    cfg, params = served
    opts = I.DecodeOptions(quest_pages=2, evict_hard_budget=48, w_obs=16)
    eng = Engine(params, cfg, slots=2, capacity=128, opts=opts,
                 mirror_paged=False)
    eng.add_request(list(range(0, 80)), max_new=8)
    eng.run(max_steps=20)
    assert all(r.done for r in eng.requests.values())
