"""Fused megabatch tick (Engine.step_batch / scheduler fused driver):
ONE jitted ragged device call per tick advances every live row of the
persistent batched cache tree — first-chunk opens (empty-template
splices, no batch-1 open path), mid-prefill extends, and piggybacked
length-1 decode rows with in-jit sampling — while dead rows stay
bit-identical padding.

Parity standard (the repo's cross-driver standard, as in
test_batched_prefill): greedy token streams byte-identical to a
sequential engine-level reference drive (task-local ``_extend_ragged``
chunks + decode-only ``step_batch([])`` dispatches) and across
dispatch depths, admission accounting approx-equal, padding rows
BITWISE untouched. tau=0.1 per the knife-edge note (random-init gate
scores cluster at 0.5)."""
import jax
import numpy as np
import pytest

from conftest import make_cfg
from repro.analysis import CompileSentinel, SyncSentinel, SyncViolation
from repro.launch.specs import extract_slot_caches
from repro.models import transformer as T
from repro.serving.backend import FusedStep, make_backend
from repro.serving.obs import LANE_TICK, Tracer
from repro.serving.orchestrator import Orchestrator, SchedulerConfig

pytestmark = pytest.mark.backends

CHUNK = 16
BACKEND_NAMES = ("wgkv", "dense", "streaming_llm")


@pytest.fixture(scope="module")
def served():
    cfg = make_cfg("qwen3-0.6b", global_budget_frac=0.5)
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _engine(served, name="wgkv"):
    cfg, params = served
    return make_backend(name, params, cfg, slots=4, capacity=128,
                        mirror_paged=False)


def _bitwise_equal(a, b):
    for (pa, x), (_, y) in zip(
            jax.tree_util.tree_flatten_with_path(a)[0],
            jax.tree_util.tree_flatten_with_path(b)[0]):
        np.testing.assert_array_equal(
            np.asarray(x), np.asarray(y),
            err_msg=jax.tree_util.keystr(pa))


# ==========================================================================
# engine level: one fused call mixing every row role matches the unfused
# split-path ops token for token
# ==========================================================================
def test_fused_mixed_roles_single_call(served):
    """A single ``step_batch`` call carrying a FIRST-CHUNK row (opened as
    an empty-template splice, scanned from position 0), a MID-EXTEND row,
    a length-0 dead padding row, and decode rows — every emitted token
    identical to a sequential reference drive of the same prompts
    (task-local ``_extend_ragged`` chunks, ``finish_prefill`` /
    ``insert``, decode-only ``step_batch([])`` dispatches)."""
    rng = np.random.default_rng(3)
    pa = list(rng.integers(0, 200, 20))   # slot 1: first chunk in step 3
    pb = list(rng.integers(0, 200, 30))   # slot 0: mid-extend in step 3
    pc = list(rng.integers(0, 200, 12))   # slot 2: live decode row
    eng = _engine(served)

    # step 1: open+finish C in one fused call -> slot 2 goes live
    c = eng.start_prefill(pc)
    c.slot = 2
    s1 = eng.step_batch([c], CHUNK)
    out1 = eng.collect(s1)
    assert c.done and s1.finishing == (True,) and s1.decode_rows == ()
    assert set(out1) == {2}

    # step 2: open B's first chunk; C piggybacks as a decode row
    b = eng.start_prefill(pb)
    b.slot = 0
    s2 = eng.step_batch([b], CHUNK)
    assert s2.decode_rows == (2,) and s2.takes == (CHUNK,)
    out2 = eng.collect(s2)
    assert set(out2) == {2}

    # step 3 — THE mixed call: A first-chunk (slot 1), B extend (slot 0,
    # finishes), slot 3 dead padding, slot 2 decode; slot 3 bitwise
    # untouched by the masked scan
    row3_before = jax.device_get(extract_slot_caches(eng.caches, 3))
    a = eng.start_prefill(pa)
    a.slot = 1
    s3 = eng.step_batch([a, b], CHUNK)
    assert s3.decode_rows == (2,)
    assert s3.takes == (CHUNK, len(pb) - CHUNK)
    assert s3.finishing == (False, True)
    out3 = eng.collect(s3)
    assert set(out3) == {0, 2}          # B's first token + C's decode
    _bitwise_equal(extract_slot_caches(eng.caches, 3), row3_before)

    # step 4: A finishes; B and C decode alongside
    s4 = eng.step_batch([a], CHUNK)
    assert s4.decode_rows == (0, 2) and s4.finishing == (True,)
    out4 = eng.collect(s4)
    assert set(out4) == {0, 1, 2}       # A's first token + two decodes

    # ---- sequential reference drive of the same prompts: task-local
    # ragged chunks + decode-only fused dispatches ----
    ref = _engine(served)

    def chunks(task, n=1):
        task.caches = ref._fresh_task_caches()
        for _ in range(n):
            ref._extend_ragged([task], CHUNK)
        return ref.finish_prefill(task)

    tc = ref.start_prefill(pc)
    fc = chunks(tc)
    ref.insert(fc, 2)
    assert fc.first_token == out1[2]
    assert tc.adm_weighted == pytest.approx(c.adm_weighted, rel=1e-5)
    # C's decode tokens across fused steps 2-4
    dec1 = ref.collect(ref.step_batch([]))
    assert dec1[2] == out2[2]
    tb = ref.start_prefill(pb)
    fb = chunks(tb, 2)
    assert fb.first_token == out3[0]
    assert tb.adm_weighted == pytest.approx(b.adm_weighted, rel=1e-5)
    dec2 = ref.collect(ref.step_batch([]))
    assert dec2[2] == out3[2]
    ref.insert(fb, 0)
    dec3 = ref.collect(ref.step_batch([]))
    assert dec3[0] == out4[0] and dec3[2] == out4[2]
    ta = ref.start_prefill(pa)
    fa = chunks(ta, 2)
    assert fa.first_token == out4[1]
    assert ta.adm_weighted == pytest.approx(a.adm_weighted, rel=1e-5)


def test_fused_freed_row_reopens_clean(served):
    """free_slot drops residency: the next task on that slot gets a
    fresh empty-template splice, and its stream matches a never-used
    slot's (no state leaks across requests)."""
    rng = np.random.default_rng(9)
    prompt = list(rng.integers(0, 200, 20))
    eng = _engine(served)
    filler = eng.start_prefill(list(rng.integers(0, 200, 28)))
    filler.slot = 1
    while not filler.done:
        eng.collect(eng.step_batch([filler], CHUNK, decode=False))
    first = eng.start_prefill(prompt)
    first.slot = 1

    def drive(task):
        toks = []
        while not task.done:
            out = eng.collect(eng.step_batch([task], CHUNK, decode=False))
            toks += sorted(out.items())
        for _ in range(3):
            toks += sorted(eng.collect(eng.step_batch([])).items())
        eng.free_slot(task.slot)
        return toks

    # dirty slot 1 (filler ran there), then reuse it for the same prompt
    eng.free_slot(1)
    assert not eng._resident[1]
    t1 = drive(first)
    again = eng.start_prefill(prompt)
    again.slot = 1
    t2 = drive(again)
    assert t1 == t2


# ==========================================================================
# orchestrator level: the always-fused driver streams byte-identical
# across dispatch depths, all backend families
# ==========================================================================
@pytest.mark.parametrize("name", BACKEND_NAMES)
def test_stream_parity_async_vs_sync(served, name):
    prompts = [list(range(10, 58)), list(range(5, 60)),
               list(range(20, 30)), list(range(7, 52))]

    def serve(depth):
        orch = Orchestrator(_engine(served, name), sched=SchedulerConfig(
            chunk_tokens=CHUNK, dispatch_ahead=depth))
        for p in prompts:
            orch.submit(p, max_new=5)
        orch.run()
        return ([orch.tokens(r) for r in range(len(prompts))],
                orch.telemetry.summary())

    toks_a, s_a = serve(1)
    toks_s, s_s = serve(0)
    assert toks_a == toks_s
    assert all(len(t) == 5 for t in toks_a)
    ca, cs = s_a["counters"], s_s["counters"]
    assert ca["fused_steps"] > 0 and cs["fused_steps"] > 0
    # chunk/token accounting keeps its meaning across dispatch depths
    assert ca["prefill_chunks"] == cs["prefill_chunks"]
    assert ca["prefill_tokens"] == cs["prefill_tokens"]
    # every prefill token rides the fused tick; the split stage is gone
    assert ca["fused_prefill_tokens"] == ca["prefill_tokens"]
    assert ca["prefill_time_s"] == 0.0
    assert s_a["mean_admission"] == pytest.approx(s_s["mean_admission"],
                                                  rel=1e-5)


# ==========================================================================
# phase accounting + tracing under the fused driver
# ==========================================================================
def test_fused_phase_accounting_and_trace(served):
    tracer = Tracer()
    orch = Orchestrator(_engine(served), sched=SchedulerConfig(
        chunk_tokens=CHUNK, dispatch_ahead=1), tracer=tracer)
    for p in ([list(range(10, 58)), list(range(5, 41))]):
        orch.submit(p, max_new=4)
    orch.run()
    ph = orch.telemetry.phase_times()
    assert ph["tick_time_s"] > 0.0
    assert ph["phase_sum_s"] <= ph["tick_time_s"] + 1e-12
    # the fused call's wall is apportioned, never invented: the prefill
    # share is bounded by the fused total, and the old batch-1 open
    # stage is gone entirely (open_time_s retired with it)
    assert ph["fused_time_s"] > 0.0
    assert 0.0 < ph["fused_prefill_time_s"] <= ph["fused_time_s"]
    assert ph["prefill_time_s"] == 0.0
    assert "open_time_s" not in ph
    # dispatch_time_s carries the fused dispatch spans
    assert ph["dispatch_time_s"] > 0.0
    tick_names = {s.name for s in tracer.spans if s.lane == (LANE_TICK, 0)}
    assert "fused_step" in tick_names
    # with selection off, no decode-only dispatch runs the sel variant
    assert "selection" not in {s.name for s in tracer.spans}
    assert any(s.name == "fused_open" for s in tracer.spans)
    # request-lane lifecycle survives the fused path (chunk spans carry
    # fused=True, insert instants mark the prefill->decode flip)
    assert any(s.name.startswith("prefill[chunk") and s.args.get("fused")
               for s in tracer.spans)
    assert any(s.name == "insert" and s.args.get("fused")
               for s in tracer.spans)


def test_fused_step_is_single_device_call_kind(served):
    """Exactly two compiled fused shapes per engine — (slots, chunk) and
    (slots, 1) — however rows mix roles across a whole serve; the whole
    replay runs under both runtime sentinels, so the PR 7 shape-count
    claim AND the PR 4/8 sync discipline (no host pull between dispatch
    and collect outside sanctioned engine methods) are executable."""
    eng = _engine(served)
    orch = Orchestrator(eng, sched=SchedulerConfig(
        chunk_tokens=CHUNK, dispatch_ahead=1))
    with CompileSentinel(eng) as cs, SyncSentinel(eng) as ss:
        for n in (48, 55, 10, 33):
            orch.submit(list(range(2, 2 + n)), max_new=4)
        orch.run()
        counts = cs.check()             # raises if over the declared budget
    assert counts["fused_step"] == 2    # (slots, chunk) + (slots, 1)
    assert counts.get("fused_step_sel", 0) == 0   # selection off
    assert counts["extend_batch"] == 0  # legacy sync path never compiled
    assert ss.syncs_in_collect > 0      # collect() did the pulling
    fused = eng._fused
    sizes = getattr(fused, "_cache_size", None)
    if sizes is not None:               # plain jax.jit exposes the count
        assert fused._cache_size() <= 2
    assert isinstance(orch.telemetry.counters["fused_steps"], float)
    assert orch.telemetry.counters["fused_steps"] > 0


def test_sync_sentinel_trips_on_naked_sync(served):
    """The sentinel is not a no-op: a host pull between dispatch and
    collect raises SyncViolation, and a sync inside step_batch itself
    (dispatch must never block) raises too."""
    eng = _engine(served)
    t = eng.start_prefill(list(range(2, 30)))
    t.slot = 0
    with pytest.raises(SyncViolation):
        with SyncSentinel(eng):
            step = eng.step_batch([t], CHUNK)
            jax.device_get(step.tokens)          # naked pre-collect pull
    # device_get must be restored even after the raise
    assert jax.device_get.__module__ != "repro.analysis.sentinels"
    eng.collect(step)                            # settle for hygiene


def test_compile_sentinel_over_selection_replay(served):
    """Full fused serve with decode-time selection on: the third declared
    shape ((slots, 1) selection variant) lands and the budget holds."""
    cfg, params = served
    eng = make_backend("wgkv", params, cfg, slots=4, capacity=128,
                       mirror_paged=False, selection="quest:2")
    orch = Orchestrator(eng, sched=SchedulerConfig(
        chunk_tokens=CHUNK, dispatch_ahead=1))
    with CompileSentinel(eng) as cs:
        for n in (48, 10):
            orch.submit(list(range(2, 2 + n)), max_new=6)
        orch.run()
        counts = cs.check()
    assert counts["fused_step_sel"] == 1
    assert counts["fused_step"] <= 2
