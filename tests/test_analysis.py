"""repro.analysis: the jaxlint passes, baseline, CLI, and runtime sentinels.

Three layers of coverage:

  * pass-level fixtures: for each JL code a true-positive snippet, an
    annotated (suppressed) variant, and a clean variant — run in-process
    through ``ModuleContext.parse`` + ``run_passes``.
  * baseline + CLI: fingerprint round-trip (line-number drift tolerant,
    count-capped) and the documented exit codes (0 clean / 1 new
    findings / 2 bad arguments-or-baseline-or-syntax).
  * seeded regressions over the REAL tree: a scratch copy of src/ lints
    clean against the committed baseline, then each of five seeded
    hot-path regressions (one per JL001-JL005) flips the CLI to exit 1 —
    the acceptance check that every pass bites on the code it guards.

The runtime sentinels get unit tests against a fake engine here; full
serve-replay coverage lives in test_fused_tick.py / test_sharded_serving.py.
"""
import os
import shutil
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

from repro.analysis.contracts import hot_path, parse_annotations
from repro.analysis.findings import (Finding, load_baseline, write_baseline)
from repro.analysis.lint import lint_paths
from repro.analysis.lint import main as lint_main
from repro.analysis.passes import ALL_CODES, ModuleContext, run_passes
from repro.analysis.sentinels import (CompileBudgetExceeded, CompileSentinel,
                                      SyncSentinel, SyncViolation)

REPO = Path(__file__).resolve().parent.parent


def findings_for(snippet, path="pkg/mod.py", select=None):
    ctx = ModuleContext.parse(path, textwrap.dedent(snippet))
    return run_passes(ctx, select)


def codes(findings):
    return sorted(f.code for f in findings)


# ==========================================================================
# JL000 — annotation hygiene (malformed directives are findings, not noise)
# ==========================================================================
def test_jl000_malformed_annotations():
    fs = findings_for(
        """
        x = 1  # jaxlint: allow-sync
        y = 2  # jaxlint: frobnicate
        # jaxlint: shapes(not a decl!)
        z = 3
        """,
        select=["JL000"],
    )
    assert codes(fs) == ["JL000", "JL000", "JL000"]
    msgs = " ".join(f.message for f in fs)
    assert "require a reason" in msgs and "unknown directive" in msgs
    assert "unparseable shapes" in msgs


def test_jl000_docstring_mentions_are_not_annotations():
    # the annotation parser reads tokenize COMMENT tokens, not raw lines:
    # documentation that *quotes* a directive must not trip JL000
    fs = findings_for(
        '''
        def doc():
            """Write `# jaxlint: allow-sync` or # jaxlint: shapes(broken."""
            return 1
        ''',
    )
    assert fs == []


# ==========================================================================
# JL001 — host sync in hot path
# ==========================================================================
JL001_TP = """
    import jax
    import jax.numpy as jnp
    import numpy as np

    def tick():  # jaxlint: hot-path
        x = jnp.zeros((4,))
        got = jax.device_get(x)
        f = float(jnp.sum(x))
        h = np.asarray(x)
        i = x.item()
        return got, f, h, i
"""


def test_jl001_flags_every_sync_construct():
    fs = findings_for(JL001_TP, select=["JL001"])
    assert codes(fs) == ["JL001"] * 4
    msgs = [f.message for f in fs]
    assert any("jax.device_get" in m for m in msgs)
    assert any("float() of device value" in m for m in msgs)
    assert any("np.asarray of device value" in m for m in msgs)
    assert any(".item()" in m for m in msgs)


def test_jl001_decorator_marks_hot():
    fs = findings_for(
        """
        import jax
        from repro.analysis import hot_path

        @hot_path
        def tick():
            return jax.device_get(jnp.zeros(3))
        """,
        select=["JL001"],
    )
    assert codes(fs) == ["JL001"]


def test_jl001_allow_sync_suppresses():
    fs = findings_for(
        """
        import jax

        def tick():  # jaxlint: hot-path
            got = jax.device_get(x)  # jaxlint: allow-sync(designated sync point)
            return got
        """,
        select=["JL001"],
    )
    assert fs == []


def test_jl001_clean_host_math_and_cold_functions():
    fs = findings_for(
        """
        import jax
        import jax.numpy as jnp
        import numpy as np

        def tick(toks):  # jaxlint: hot-path
            n = np.zeros((3,))
            f = float(n.sum())                # host array: no device sync
            b = float(toks * jnp.dtype("float32").itemsize)   # metadata only
            return f + b

        def cold():                           # not hot: syncs are its job
            x = jnp.zeros((4,))
            return jax.device_get(x)
        """,
        select=["JL001"],
    )
    assert fs == []


# ==========================================================================
# JL002 — concat in sharded code paths
# ==========================================================================
def test_jl002_module_scope_and_suppression():
    # serving/engine.py is a sharded-path module: module-wide scope
    tp = findings_for(
        """
        import jax.numpy as jnp

        def splice(a, b):
            return jnp.concatenate([a, b])
        """,
        path="repro/serving/engine.py",
        select=["JL002"],
    )
    assert codes(tp) == ["JL002"]
    assert "splice helpers" in tp[0].message

    ok = findings_for(
        """
        import jax.numpy as jnp

        def rope(a, b):
            return jnp.concatenate([a, b], axis=-1)  # jaxlint: allow-concat(feature axis)
        """,
        path="repro/serving/engine.py",
        select=["JL002"],
    )
    assert ok == []


def test_jl002_marker_scope_outside_listed_modules():
    snippet = """
        import jax.numpy as jnp

        def gather(parts):  # jaxlint: sharded-path
            return jnp.stack(parts)

        def host_side(parts):
            return jnp.stack(parts)
    """
    fs = findings_for(snippet, path="pkg/util.py", select=["JL002"])
    assert len(fs) == 1 and fs[0].code == "JL002"   # only the marked def


# ==========================================================================
# JL003 — unmasked cache writes in masked scan bodies
# ==========================================================================
JL003_CLEAN = """
    import jax
    import jax.numpy as jnp

    def body(carry, xs):  # jaxlint: masked-scan-body
        old, pos = carry
        logits, new, st = decode_step(xs, old)
        merged = jax.tree_util.tree_map_with_path(keep, new, old)
        trig = jnp.where(active, st, 0.0)
        return (merged, pos), trig
"""


def test_jl003_masked_select_is_clean():
    assert findings_for(JL003_CLEAN, select=["JL003"]) == []


def test_jl003_raw_cache_escape_flagged():
    fs = findings_for(
        JL003_CLEAN.replace(
            "merged = jax.tree_util.tree_map_with_path(keep, new, old)",
            "merged = new",
        ),
        select=["JL003"],
    )
    assert codes(fs) == ["JL003"]
    assert "'merged'" in fs[0].message


def test_jl003_at_write_needs_mask():
    tp = findings_for(
        """
        def body(carry, xs):  # jaxlint: masked-scan-body
            buf = carry
            buf = buf.at[0].set(xs)
            return None
        """,
        select=["JL003"],
    )
    assert codes(tp) == ["JL003"] and ".at[...]" in tp[0].message

    ok = findings_for(
        """
        import jax.numpy as jnp

        def body(carry, xs):  # jaxlint: masked-scan-body
            buf = carry
            buf = buf.at[0].set(jnp.where(m, xs, buf[0]))
            return None
        """,
        select=["JL003"],
    )
    assert ok == []


def test_jl003_suppression():
    fs = findings_for(
        JL003_CLEAN.replace(
            "merged = jax.tree_util.tree_map_with_path(keep, new, old)",
            "merged = new",
        ).replace(
            "return (merged, pos), trig",
            "return (merged, pos), trig  # jaxlint: allow-unmasked-write(test scaffolding)",
        ),
        select=["JL003"],
    )
    assert fs == []


# ==========================================================================
# JL004 — tracer leaks in jitted functions
# ==========================================================================
def test_jl004_decorator_and_call_forms():
    fs = findings_for(
        """
        import jax

        @jax.jit
        def f(x):
            if x > 0:
                return x
            return -x

        def g(y):
            while y > 0:
                y = y - 1
            return y

        jfn = jax.jit(g)
        """,
        select=["JL004"],
    )
    assert codes(fs) == ["JL004", "JL004"]
    assert any("'f'" in f.message and "if" in f.message for f in fs)
    assert any("'g'" in f.message and "while" in f.message for f in fs)


def test_jl004_static_args_and_shape_reads_are_clean():
    fs = findings_for(
        """
        import functools
        import jax

        @functools.partial(jax.jit, static_argnames=("n",))
        def g(x, n):
            if n > 0:                 # static: concretized at trace time
                x = x + 1
            if x.shape[0] > 2:        # shape metadata: host-known
                x = x * 2
            assert x is not None      # identity compare: fine
            return x
        """,
        select=["JL004"],
    )
    assert fs == []


def test_jl004_suppression():
    fs = findings_for(
        """
        import jax

        @jax.jit
        def f(x):
            if x > 0:  # jaxlint: allow-tracer-branch(scalar weak-type scaffold)
                return x
            return -x
        """,
        select=["JL004"],
    )
    assert fs == []


# ==========================================================================
# JL005 — undeclared compiled shapes in the tick path
# ==========================================================================
def test_jl005_tick_path_jit_needs_decl():
    tp = findings_for(
        """
        import jax
        fn = jax.jit(lambda x: x)
        """,
        path="repro/serving/sharded.py",
        select=["JL005"],
    )
    assert codes(tp) == ["JL005"]
    assert "COMPILE_SHAPE_BUDGETS" in tp[0].message

    ok_line = findings_for(
        """
        import jax
        # jaxlint: shapes(helper=1)
        fn = jax.jit(lambda x: x)
        """,
        path="repro/serving/sharded.py",
        select=["JL005"],
    )
    assert ok_line == []

    ok_def = findings_for(
        """
        import jax

        def make():  # jaxlint: shapes(helper=per-structure)
            return jax.jit(lambda x: x)
        """,
        path="repro/serving/sharded.py",
        select=["JL005"],
    )
    assert ok_def == []


def test_jl005_only_tick_path_modules():
    fs = findings_for(
        """
        import jax
        fn = jax.jit(lambda x: x)
        """,
        path="repro/models/foo.py",
        select=["JL005"],
    )
    assert fs == []


# ==========================================================================
# JL006 — dead imports
# ==========================================================================
def test_jl006_dead_and_guarded_imports():
    fs = findings_for(
        """
        import os
        from typing import List

        import jax.numpy as jnp

        try:
            import fancy
        except ImportError:
            fancy = None

        __all__ = ["exported"]
        import exported  # noqa: re-export for the package surface

        def f(x):
            return jnp.sum(x)
        """,
        select=["JL006"],
    )
    assert codes(fs) == ["JL006", "JL006"]
    texts = " ".join(f.text for f in fs)
    assert "import os" in texts and "List" in texts


def test_jl006_suppression_and_init_exemption():
    fs = findings_for(
        "import os  # jaxlint: allow-dead-import(subprocess env in doctest)\n",
        select=["JL006"],
    )
    assert fs == []
    init = findings_for("import os\n", path="pkg/__init__.py",
                        select=["JL006"])
    assert init == []


# ==========================================================================
# annotation parser + baseline round-trip
# ==========================================================================
def test_parse_annotations_surface():
    ann = parse_annotations(textwrap.dedent(
        """
        # jaxlint: hot-path
        def f():
            x = 1  # jaxlint: allow-sync(reason text)
            return x
        """
    ))
    assert ann.scope_marker("hot-path", 3)          # marker on line above def
    assert ann.suppressed("JL001", 4)               # on the line
    assert ann.suppressed("JL001", 5)               # line below the comment
    assert not ann.suppressed("JL002", 4)           # wrong code family


def test_baseline_round_trip_and_count_cap(tmp_path):
    mod = tmp_path / "m.py"
    mod.write_text("import os\nimport sys\n")
    found = lint_paths([str(mod)], select=["JL006"])
    assert len(found) == 2

    bl = tmp_path / "baseline.json"
    write_baseline(found, bl, reason="seed")
    new, accepted = load_baseline(bl).split(found)
    assert new == [] and len(accepted) == 2

    # fingerprints are (code, path, text): line drift stays accepted, but a
    # SECOND occurrence of the same text overflows the count and fails
    mod.write_text("# moved\nimport os\nimport sys\nimport os\n")
    drifted = lint_paths([str(mod)], select=["JL006"])
    assert len(drifted) == 3
    new, accepted = load_baseline(bl).split(drifted)
    assert len(accepted) == 2 and len(new) == 1
    assert new[0].text == "import os"


def test_baseline_version_check(tmp_path):
    bad = tmp_path / "b.json"
    bad.write_text('{"version": 99, "findings": []}')
    with pytest.raises(ValueError, match="version"):
        load_baseline(bad)


# ==========================================================================
# CLI exit codes: 0 clean / 1 new findings / 2 bad input
# ==========================================================================
def test_cli_exit_codes(tmp_path, capsys):
    clean = tmp_path / "clean.py"
    clean.write_text("import os\n\nprint(os.sep)\n")
    dirty = tmp_path / "dirty.py"
    dirty.write_text("import os\n")

    assert lint_main([str(clean)]) == 0
    assert "clean" in capsys.readouterr().out

    assert lint_main([str(dirty)]) == 1
    out = capsys.readouterr().out
    assert "JL006" in out and "1 new finding(s)" in out

    # baseline acceptance turns the same tree green
    bl = tmp_path / "bl.json"
    assert lint_main([str(dirty), "--write-baseline", str(bl),
                      "--reason", "known"]) == 0
    capsys.readouterr()
    assert lint_main([str(dirty), "--baseline", str(bl)]) == 0
    assert "accepted by baseline" in capsys.readouterr().out

    # exit 2: unknown code, missing path, unreadable baseline, syntax error
    assert lint_main([str(clean), "--select", "JL999"]) == 2
    assert lint_main([str(tmp_path / "nope.py")]) == 2
    broken = tmp_path / "broken.json"
    broken.write_text("{not json")
    assert lint_main([str(dirty), "--baseline", str(broken)]) == 2
    bad_py = tmp_path / "bad.py"
    bad_py.write_text("def (:\n")
    assert lint_main([str(bad_py)]) == 2
    capsys.readouterr()


# ==========================================================================
# seeded regressions over the real tree: the acceptance check that each
# pass bites on the exact code it guards (scratch copy, subprocess CLI)
# ==========================================================================
@pytest.fixture(scope="module")
def scratch_tree(tmp_path_factory):
    root = tmp_path_factory.mktemp("lint_tree")
    shutil.copytree(REPO / "src" / "repro", root / "src" / "repro",
                    ignore=shutil.ignore_patterns("__pycache__"))
    (root / "analysis").mkdir()
    shutil.copy(REPO / "analysis" / "baseline.json",
                root / "analysis" / "baseline.json")
    return root


def run_lint_cli(cwd):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis.lint", "src",
         "--baseline", "analysis/baseline.json"],
        capture_output=True, text=True, env=env, cwd=cwd, timeout=120)


def test_repo_tree_lints_clean_with_committed_baseline():
    r = run_lint_cli(REPO)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "clean" in r.stdout


SEEDED = [
    ("JL001", "src/repro/serving/engine.py",
     '        self.stats["fused_slot_rows"] += float(self.slots)',
     '        self.stats["fused_slot_rows"] += float(self.slots)\n'
     '        _dbg = float(jnp.sum(self._tok_dev))'),
    ("JL002", "src/repro/serving/engine.py",
     '        self.stats["fused_slot_rows"] += float(self.slots)',
     '        self.stats["fused_slot_rows"] += float(self.slots)\n'
     '        _cat = jnp.concatenate([jnp.zeros((1,)), jnp.zeros((1,))])'),
    ("JL003", "src/repro/models/inference.py",
     "        merged = jax.tree_util.tree_map_with_path(keep, new, old)",
     "        merged = new"),
    ("JL004", "src/repro/serving/sharded.py",
     "            sampled = sample(key[0], last_logits, "
     "temperature=temperature)",
     "            sampled = sample(key[0], last_logits, "
     "temperature=temperature)\n"
     "            if lengths[0] > 0:\n"
     "                sampled = sampled"),
    ("JL005", "src/repro/serving/sharded.py",
     "            ent = self._fn_cache.get(key)",
     "            ent = self._fn_cache.get(key)\n"
     "            _unbudgeted = jax.jit(lambda q: q)"),
]


@pytest.mark.parametrize("code,rel,old,new", SEEDED,
                         ids=[s[0] for s in SEEDED])
def test_seeded_regression_fails_lint(scratch_tree, code, rel, old, new):
    target = scratch_tree / rel
    original = target.read_text()
    assert old in original, f"mutation anchor vanished from {rel}"
    try:
        target.write_text(original.replace(old, new, 1))
        r = run_lint_cli(scratch_tree)
        assert r.returncode == 1, r.stdout + r.stderr
        assert code in r.stdout, r.stdout
    finally:
        target.write_text(original)
    # restored tree is green again (mutations don't leak across params)
    r = run_lint_cli(scratch_tree)
    assert r.returncode == 0, r.stdout + r.stderr


# ==========================================================================
# runtime sentinels (unit level; full-replay coverage in test_fused_tick)
# ==========================================================================
jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402


class _FakeEngine:
    COMPILE_SHAPE_BUDGETS = {"fused_step": 2}

    def __init__(self, shapes=2):
        self.shapes = shapes

    def compiled_shape_counts(self):
        return {"fused_step": self.shapes}

    def step_batch(self, tasks, chunk=16):
        return object()

    def collect(self, step):
        return jax.device_get(jnp.zeros((1,)))

    def memory_snapshot(self):
        return {"x": float(jax.device_get(jnp.ones(())))}


def test_compile_sentinel_within_and_over_budget():
    with CompileSentinel(_FakeEngine(2)) as cs:
        assert cs.check() == {"fused_step": 2}
    with pytest.raises(CompileBudgetExceeded, match="recompile stall"):
        with CompileSentinel(_FakeEngine(3)):
            pass
    # explicit budgets override the engine declaration
    with CompileSentinel(_FakeEngine(3), budgets={"fused_step": 5}):
        pass
    with pytest.raises(ValueError, match="no shape budgets"):
        CompileSentinel(object())


def test_sync_sentinel_contract():
    eng = _FakeEngine()
    orig = jax.device_get
    with SyncSentinel(eng) as ss:
        jax.device_get(jnp.zeros(1))        # nothing in flight: fine
        step = eng.step_batch([])
        with pytest.raises(SyncViolation, match="collect"):
            jax.device_get(jnp.zeros(1))    # naked sync mid-flight
        eng.memory_snapshot()               # sanctioned frame: fine
        eng.collect(step)
        jax.device_get(jnp.zeros(1))        # collected: fine again
    assert ss.syncs_in_collect >= 2         # collect + memory_snapshot pulls
    assert jax.device_get is orig           # patch removed
    assert "collect" not in vars(eng) and "step_batch" not in vars(eng)


def test_sync_sentinel_dispatch_must_not_block():
    class _BadDispatch(_FakeEngine):
        def step_batch(self, tasks, chunk=16):
            return jax.device_get(jnp.zeros(1))   # sync inside dispatch

    eng = _BadDispatch()
    orig = jax.device_get
    with pytest.raises(SyncViolation):
        with SyncSentinel(eng):
            eng.step_batch([])
    assert jax.device_get is orig           # restored even on unwind
    assert "step_batch" not in vars(eng)


def test_hot_path_decorator_is_transparent():
    @hot_path
    def f(x):
        return x + 1

    assert f.__jaxlint_hot_path__ is True
    assert f(1) == 2
