"""Mesh-sharded serving (serving/sharded.py + sharding/rules.py).

Three layers of coverage:

  * spec-level: ``cache_shardings`` on DualCache trees — odd KV-head
    counts (phi3 10 KV heads, smollm 5) must fall back to replication on
    "model" under the (2,4) debug mesh, ``seq_shard=True`` must put the
    global token axis on "data", and ``param_shardings`` must never split
    ``head_dim`` across "model" (whole-head column parallelism only).
    These run on a single device via AbstractMesh.
  * end-to-end parity (subprocess, sets its own XLA_FLAGS): greedy
    tokens from the wgkv and dense backends under a (2,4) host-device
    mesh must exactly match the unsharded backends on the same arrival
    trace.
  * in-process mesh tests (skipped unless >= 8 devices; CI provides
    them): sharded capabilities/memory_snapshot surface.
"""
import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.specs import build_decode_caches
from repro.sharding import rules

pytestmark = pytest.mark.sharded

MESH_SHAPE = (2, 4)
N_DEVICES = MESH_SHAPE[0] * MESH_SHAPE[1]

needs_mesh = pytest.mark.skipif(
    len(jax.devices()) < N_DEVICES,
    reason=f"needs >= {N_DEVICES} devices (XLA_FLAGS="
           f"--xla_force_host_platform_device_count={N_DEVICES})")


def spec_mesh():
    """(2,4) data x model mesh for SPEC computation only: the real debug
    mesh when enough devices exist, else an AbstractMesh with the same
    axis map (rules.py only reads axis_names / shape)."""
    if len(jax.devices()) >= N_DEVICES:
        from repro.launch.mesh import make_debug_mesh
        return make_debug_mesh(MESH_SHAPE)
    return jax.sharding.AbstractMesh(
        (("data", MESH_SHAPE[0]), ("model", MESH_SHAPE[1])))


def dual_cache_specs(cfg, *, batch=4, capacity=4096, seq_shard=False):
    """{path: PartitionSpec} for every DualCache gk/gv leaf of a decode
    cache tree (built under eval_shape: full-size configs, no memory)."""
    structs = jax.eval_shape(
        lambda: build_decode_caches(cfg, batch, capacity, use_wgkv=True))
    sh = rules.cache_shardings(structs, spec_mesh(), cfg,
                               seq_shard=seq_shard)
    flat = jax.tree_util.tree_flatten_with_path(sh)[0]
    out = {}
    for path, ns in flat:
        keys = rules._path_keys(path)
        if keys[-1] in ("gk", "gv"):
            out[keys] = ns.spec
    return out


# ==========================================================================
# cache_shardings: odd head counts fall back to replication on "model"
# ==========================================================================
@pytest.mark.parametrize("arch,kv_heads,want_model", [
    ("phi3-medium-14b", 10, None),     # 10 % 4 != 0 -> replicate
    ("smollm-360m", 5, None),          # 5 % 4 != 0 -> replicate
    ("qwen3-0.6b", 8, "model"),        # 8 % 4 == 0 -> shard KV heads
])
def test_dual_cache_head_axis(arch, kv_heads, want_model):
    cfg = get_config(arch)
    assert cfg.n_kv_heads == kv_heads
    specs = dual_cache_specs(cfg)
    assert specs, "no DualCache gk/gv leaves found"
    for keys, spec in specs.items():
        # stacked block leaves: [n_repeats, B, H, C, hd] -> head axis at 2
        assert spec[0] is None, (keys, spec)
        assert spec[2] == want_model, (keys, spec)
        assert spec[4] is None, (keys, spec)    # head_dim never sharded

def test_dual_cache_batch_axis_over_data():
    specs = dual_cache_specs(get_config("qwen3-0.6b"), batch=4)
    for keys, spec in specs.items():
        assert spec[1] == ("data",) or spec[1] == "data", (keys, spec)


def test_seq_shard_puts_global_tokens_on_data():
    """batch=1 long-context decode: the global token axis shards over
    "data" (context parallelism) instead of the (indivisible) batch."""
    cfg = get_config("phi3-medium-14b")
    specs = dual_cache_specs(cfg, batch=1, capacity=4096, seq_shard=True)
    for keys, spec in specs.items():
        assert spec[1] is None, (keys, spec)       # batch=1: not sharded
        assert spec[2] is None, (keys, spec)       # 10 heads: replicated
        assert spec[3] == "data", (keys, spec)     # token axis -> data


def test_param_shardings_never_split_head_dim():
    """w_q/w_k/w_v column parallelism is whole-head only: an arch whose
    KV-head count does not divide "model" must not shard the projection
    out-dim (phi3: 10 KV heads on model=4, though 10*128 divides 4)."""
    cfg = get_config("phi3-medium-14b")
    mesh = spec_mesh()
    hd = cfg.head_dim
    kv_out = cfg.n_kv_heads * hd
    spec = rules._param_spec(("blocks", "b0", "attn", "w_k"),
                             (1, cfg.d_model, kv_out), mesh, cfg)
    assert kv_out % mesh.shape["model"] == 0      # flattened dim DOES divide
    assert spec[2] is None, spec                   # ...but heads do not
    # q heads (40) divide model=4 -> column-parallel stays
    q_spec = rules._param_spec(("blocks", "b0", "attn", "w_q"),
                               (1, cfg.d_model, cfg.n_heads * hd), mesh, cfg)
    assert q_spec[2] == "model", q_spec


# ==========================================================================
# mesh construction from CLI specs
# ==========================================================================
def test_build_mesh_validation():
    from repro.serving.sharded import build_mesh, parse_mesh_shape

    assert build_mesh(None) is None
    assert parse_mesh_shape("2X4") == (2, 4)
    for bad in ("2x", "x4", "0x4", "2x4x2", "axb"):
        with pytest.raises(ValueError):
            parse_mesh_shape(bad)
    if len(jax.devices()) < 64:
        with pytest.raises(RuntimeError, match="devices"):
            build_mesh("8x8")


# ==========================================================================
# end-to-end parity: sharded == unsharded greedy tokens (subprocess owns
# its XLA_FLAGS, so this runs under the plain single-device tier-1 suite)
# ==========================================================================
PARITY_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
jax.config.update("jax_enable_x64", False)
from repro.configs import get_reduced_config
from repro.configs.base import WGKVConfig
from repro.models import transformer as T
from repro.analysis import CompileSentinel, SyncSentinel
from repro.serving.backend import make_backend
from repro.serving.orchestrator import Orchestrator, SchedulerConfig
from repro.serving.sharded import build_mesh

cfg = get_reduced_config("qwen3-0.6b").replace(dtype="float32")
cfg = cfg.replace(wgkv=WGKVConfig(enabled=True, w_local=16, tau=0.1,
                                  gate_hidden=32, global_budget_frac=1.0,
                                  sink=4))
cfg = cfg.replace(sliding_window=min(cfg.sliding_window, 32))
params = T.init_model(jax.random.PRNGKey(0), cfg)
mesh = build_mesh("2x4")
prompts = [list(range(7 + i, 39 + i)) for i in range(3)]

engines = {}  # engines are reusable: jit caches amortize across drivers

def serve(name, m, depth, selection=None):
    key = (name, m is not None, selection)
    if key not in engines:
        engines[key] = make_backend(name, params, cfg, slots=2, capacity=128,
                                    mirror_paged=False, mesh=m,
                                    selection=selection)
    eng = engines[key]
    orch = Orchestrator(eng, sched=SchedulerConfig(
        chunk_tokens=16, dispatch_ahead=depth))
    for p in prompts:
        orch.submit(p, max_new=4)
    # every parity drive runs under both contract sentinels: the shape
    # budget and the no-sync-between-dispatch-and-collect discipline must
    # hold on the mesh exactly as they do unsharded
    with CompileSentinel(eng) as cs, SyncSentinel(eng) as ss:
        orch.run()
        counts = cs.check()
    return {"tokens": [orch.tokens(r) for r in range(len(prompts))],
            "sharded": eng.capabilities().sharded,
            "devices": eng.memory_snapshot().get("mesh_devices"),
            "compiled": counts, "collect_syncs": ss.syncs_in_collect}

out = {}
for name in ("wgkv", "dense"):
    out[name] = {"mesh": serve(name, mesh, 0), "flat": serve(name, None, 0),
                 "mesh_async": serve(name, mesh, 1)}
    if name == "wgkv":   # dense has no page metadata to select against
        # capacity 128 = 8 pages: quest:8 selects every page, so the
        # gathered decode path must stream byte-identical, sharded too
        out[name]["mesh_sel_all"] = serve(name, mesh, 1,
                                          selection="quest:8")
        out[name]["flat_sel_all"] = serve(name, None, 1,
                                          selection="quest:8")

# prefix-cache round on the mesh: the same prompts served twice through a
# shared store — round 2 admits every request off a cached prefix (the
# splice re-enters the memoized sharded insert path, so the cached tree
# lands under the mesh sharding) and must stream the cold bytes
from repro.serving.prefix_cache import PrefixCache
eng = engines[("wgkv", True, None)]
pc = PrefixCache(quantum=16, free_fn=eng.release_prefix)
rounds = []
for _ in range(2):
    orch = Orchestrator(eng, sched=SchedulerConfig(chunk_tokens=16),
                        prefix_cache=pc)
    for p in prompts:
        orch.submit(p, max_new=4)
    orch.run()
    rounds.append([orch.tokens(r) for r in range(len(prompts))])
out["prefix_mesh"] = {"rounds": rounds, "hits": pc.hits,
                      "misses": pc.misses}
print("RESULT" + json.dumps(out))
"""


def _run_subproc(code, timeout=900):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=timeout,
                       cwd=os.path.join(os.path.dirname(__file__), ".."))
    assert r.returncode == 0, r.stderr[-3000:]
    line = [ln for ln in r.stdout.splitlines() if ln.startswith("RESULT")][-1]
    return json.loads(line[len("RESULT"):])


@pytest.mark.slow
def test_sharded_parity_vs_unsharded():
    out = _run_subproc(PARITY_SUBPROC)
    for name in ("wgkv", "dense"):
        mesh_run, flat_run = out[name]["mesh"], out[name]["flat"]
        assert mesh_run["sharded"] is True
        assert flat_run["sharded"] is False
        assert mesh_run["devices"] == 8.0
        assert flat_run["devices"] is None
        assert mesh_run["tokens"] == flat_run["tokens"], name
        assert all(len(t) == 4 for t in mesh_run["tokens"])
        # sentinel evidence rides back: the fused shape budget held on the
        # mesh (CompileSentinel.check() raised otherwise -> nonzero exit)
        # and collect() accounted at least one sanctioned host pull
        assert mesh_run["compiled"]["fused_step"] <= 2, name
        assert mesh_run["collect_syncs"] > 0, name
        # the async dispatch/collect driver on the mesh streams the same
        # bytes: the on-device sampled-token feed survives SPMD placement
        assert out[name]["mesh_async"]["tokens"] == flat_run["tokens"], name
    # gathered top-K page selection at K = all resident pages streams
    # byte-identical to the full decode path — on the mesh AND unsharded
    # (ascending-sorted top-K at K = P is the identity permutation)
    assert out["wgkv"]["mesh_sel_all"]["tokens"] == \
        out["wgkv"]["mesh"]["tokens"]
    assert out["wgkv"]["flat_sel_all"]["tokens"] == \
        out["wgkv"]["flat"]["tokens"]
    # prefix-cache round on the mesh: round 1 misses and captures, round 2
    # hits for every request — and both rounds stream the cold bytes
    pfx = out["prefix_mesh"]
    assert pfx["misses"] == 3 and pfx["hits"] == 3
    assert pfx["rounds"][0] == out["wgkv"]["mesh"]["tokens"]
    assert pfx["rounds"][1] == out["wgkv"]["mesh"]["tokens"]


# ==========================================================================
# sharded A/B smoke: bench_serving --mesh completes with per-backend
# metrics (needs the cached bench substrate; trains it on first run)
# ==========================================================================
@pytest.mark.slow
def test_bench_serving_smoke_mesh(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    json_path = str(tmp_path / "BENCH_serving.json")
    env["BENCH_SERVING_JSON"] = json_path
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_serving",
         "--backends", "wgkv,dense", "--smoke", "--mesh", "2x4"],
        capture_output=True, text=True, env=env, timeout=1200,
        cwd=os.path.join(os.path.dirname(__file__), ".."))
    assert r.returncode == 0, r.stderr[-3000:]
    rec = json.load(open(json_path))
    assert rec["trace"]["mesh"] == "2x4"
    for name in ("wgkv", "dense"):
        m = rec["backends"][name]
        assert m["requests"] == 4
        assert m["ttft_p50_s"] is not None and m["ttft_p99_s"] is not None
        assert m["kv_bytes_per_shard_peak"] is not None
        assert m["kv_bytes_per_shard_peak"] <= m["kv_bytes_peak"]
        # async driver metrics ride along (sync baseline + speedup ratio)
        assert m["sync_tokens_per_s"] is not None
        assert m["async_speedup_vs_sync"] > 0
    # the selection A/B rides the mesh smoke too (paged backends only):
    # all-pages parity ran, the timed K sweep carries needle accuracy
    sel = rec["backends"]["wgkv"]["selection"]
    assert sel["parity_k"] == 12
    for v in sel["per_k"].values():
        assert v["needle_accuracy"] is not None
    assert "selection" not in rec["backends"]["dense"]
    assert "ab" in rec and "wgkv" in rec["ab"]


# ==========================================================================
# in-process mesh tests (run under CI's 8 host devices)
# ==========================================================================
@needs_mesh
def test_sharded_memory_snapshot_and_free():
    from conftest import make_cfg
    from repro.launch.mesh import make_debug_mesh
    from repro.models import transformer as T
    from repro.serving.backend import make_backend

    cfg = make_cfg("qwen3-0.6b")
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    eng = make_backend("wgkv", params, cfg, slots=2, capacity=128,
                       mirror_paged=False, mesh=make_debug_mesh(MESH_SHAPE))
    prefix = eng.prefill(list(range(32)))
    eng.insert(prefix, 0)
    snap = eng.memory_snapshot()
    assert snap["mesh_devices"] == float(N_DEVICES)
    assert 0 < snap["kv_bytes_per_shard"] <= snap["kv_bytes"]
    out = eng.collect(eng.step_batch([]))
    assert set(out) == {0}
    eng.free_slot(0)
    assert eng.last_token[0] == 0
