"""Selection (Quest) + Eviction (SnapKV) composition with Admission."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_cfg
from repro.core import selection as SEL
from repro.core.dual_cache import init_dual_cache, lazy_promote_and_write
from repro.core.eviction import (evict_global, init_obs, maybe_evict,
                                 push_query, snap_scores)
from repro.models import inference as I
from repro.models import transformer as T


# ==========================================================================
# Quest selection
# ==========================================================================
def test_page_meta_bounds(key):
    k = jax.random.normal(key, (1, 2, 64, 8))
    valid = jnp.ones((1, 2, 64), bool)
    meta = SEL.build_page_meta(k, valid)
    kn = np.asarray(k).reshape(1, 2, 4, 16, 8)
    np.testing.assert_allclose(np.asarray(meta.kmin), kn.min(3), atol=1e-6)
    np.testing.assert_allclose(np.asarray(meta.kmax), kn.max(3), atol=1e-6)


def test_quest_upper_bound_property(key):
    """ub(page) >= actual q.k for every key in the page (the Quest bound)."""
    ks = jax.random.split(key, 2)
    k = jax.random.normal(ks[0], (1, 1, 64, 16))
    q = jax.random.normal(ks[1], (1, 2, 16))  # 2 q heads, 1 kv head
    meta = SEL.build_page_meta(k, jnp.ones((1, 1, 64), bool))
    ub = SEL.page_upper_bound(q, meta)  # [1,1,4] (mean over group)
    scores = jnp.einsum("bgd,bhkd->bghk", q[:, :], k[:, 0:1])  # per q head
    page_scores = scores.reshape(1, 2, 1, 4, 16).max(-1).mean(1)
    assert (np.asarray(ub) >= np.asarray(page_scores)[:, 0] - 1e-4).all()


def test_quest_selection_improves_with_budget(key):
    """Attention out with selected pages -> full attention as budget grows."""
    cfg = make_cfg("qwen3-0.6b", global_budget_frac=1.0)
    params = T.init_model(key, cfg)
    toks = jax.random.randint(key, (1, 96), 0, cfg.vocab_size)
    _, caches0 = I.prefill(params, cfg, toks[:, :64], budget=64)
    full_logits, _, _ = I.decode_step(params, cfg, toks[:, 64], caches0)
    errs = []
    for pages in (1, 2, 4):
        opts = I.DecodeOptions(quest_pages=pages)
        lg, _, _ = I.decode_step(params, cfg, toks[:, 64], caches0, opts=opts)
        errs.append(float(jnp.abs(lg - full_logits).max()))
    assert errs[-1] <= errs[0] + 1e-5
    assert errs[-1] == min(errs)


# ==========================================================================
# SnapKV eviction
# ==========================================================================
def _filled_cache(key, b=1, h=2, hd=8, w=4, budget=16, steps=20, tau=0.0):
    cache = init_dual_cache(b, h, hd, w_local=w, budget=budget)
    ks = jax.random.normal(key, (steps, b, h, hd))
    for t in range(steps):
        g = jnp.ones((b, h))  # admit everything
        cache = lazy_promote_and_write(cache, ks[t], ks[t], g, tau=0.5)
    return cache


def test_evict_keeps_top_scored(key):
    cache = _filled_cache(key)
    c = cache.budget
    scores = jnp.arange(c, dtype=jnp.float32)[None, None].repeat(2, 1)
    gvalid = jnp.arange(c)[None, None] < cache.gcnt[..., None]
    scores = jnp.where(gvalid, scores, -jnp.inf)
    before = int(cache.gcnt[0, 0])
    ev = evict_global(cache, scores, evict_frac=0.25)
    after = int(ev.gcnt[0, 0])
    n_ev = max(int(before * 0.25), 1)
    assert after == before - n_ev
    # lowest-scored (earliest slots here) were dropped; order preserved
    kept_pos = np.asarray(ev.gpos[0, 0])[:after]
    orig_pos = np.asarray(cache.gpos[0, 0])[:before]
    assert kept_pos.tolist() == orig_pos[n_ev:].tolist()


def test_maybe_evict_trigger_threshold(key):
    cache = _filled_cache(key)
    obs = init_obs(1, 4, 8, w_obs=8)
    obs = push_query(obs, jax.random.normal(key, (1, 4, 8)))
    cnt = int(cache.gcnt[0, 0])
    c2, trig = maybe_evict(cache, obs, hard_budget=cnt + 5)
    assert not bool(np.asarray(trig).any())
    assert int(c2.gcnt[0, 0]) == cnt
    c3, trig = maybe_evict(cache, obs, hard_budget=cnt)
    assert bool(np.asarray(trig).all())
    assert int(c3.gcnt[0, 0]) < cnt


def test_snap_scores_prefer_attended(key):
    """Keys similar to observed queries score higher."""
    hd = 8
    q = jnp.ones((1, 2, hd)) / np.sqrt(hd)
    obs = init_obs(1, 2, hd, w_obs=4)
    for _ in range(3):
        obs = push_query(obs, q)
    k = jnp.concatenate([
        jnp.ones((1, 1, 4, hd)),           # aligned with queries
        -jnp.ones((1, 1, 4, hd)),          # anti-aligned
    ], axis=2)
    valid = jnp.ones((1, 1, 8), bool)
    s = np.asarray(snap_scores(obs, k, valid, w_pool=1))
    assert s[0, 0, :4].min() > s[0, 0, 4:].max()


def test_admission_reduces_eviction_pressure(key):
    """Paper Fig. 2b: with admission, fewer promotions reach the global
    cache, so a hard budget triggers eviction less often."""
    cfg = make_cfg("qwen3-0.6b", global_budget_frac=0.5)
    params = T.init_model(key, cfg)
    toks = jax.random.randint(key, (1, 200), 0, cfg.vocab_size)
    budget = 48

    def run(tau_override):
        import dataclasses
        import functools

        cfg2 = cfg if tau_override is None else cfg.replace(
            wgkv=dataclasses.replace(cfg.wgkv, tau=tau_override))
        opts = I.DecodeOptions(evict_hard_budget=budget, w_obs=16)
        _, caches = I.prefill(params, cfg2, toks[:, :64], budget=64,
                              opts=opts)
        step = jax.jit(functools.partial(I.decode_step, cfg=cfg2, opts=opts))
        trig = 0.0
        for t in range(64, 144):
            _, caches, st = step(params, token=toks[:, t], caches=caches)
            trig += float(st["evict_triggers"])
        return trig

    trig_admit_all = run(0.0)       # admission off (everything promoted)
    trig_gated = run(0.9)           # aggressive admission filter
    assert trig_gated <= trig_admit_all
