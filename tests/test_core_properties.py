"""Hypothesis property tests on the WG-KV core invariants."""
import pytest

hypothesis = pytest.importorskip("hypothesis")

import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.core import masks as M
from repro.core.admission import normalized_cache_size, select_global
from repro.core.dual_cache import (cache_kv_for_attention, init_dual_cache,
                                   lazy_promote_and_write, prefill_populate)

hypothesis.settings.register_profile(
    "ci", settings(max_examples=25, deadline=None))
hypothesis.settings.load_profile("ci")


# ==========================================================================
# masks (paper §3.2)
# ==========================================================================
@given(st.integers(2, 24), st.integers(1, 12), st.integers(0, 3))
def test_gate_one_recovers_full_attention(s, w, seed):
    """g == 1 => write-gated bias == plain causal mask (zero bias)."""
    g = jnp.ones((1, 1, s))
    bias = M.write_gate_bias(g, s, w, eps=0.0)
    causal = M.causal_mask(s, s)
    assert np.allclose(np.where(causal, np.asarray(bias[0, 0]), 0.0), 0.0)
    assert np.all(np.asarray(bias[0, 0])[~np.asarray(causal)] <= M.NEG_INF)


@given(st.integers(2, 24), st.integers(1, 12))
def test_gate_zero_recovers_local_attention(s, w):
    """g == 0 => only the local window survives the softmax."""
    g = jnp.zeros((1, 1, s))
    bias = M.write_gate_bias(g, s, w, eps=1e-9)
    local = M.local_window_mask(s, s, w)
    b = np.asarray(bias[0, 0])
    assert np.allclose(b[np.asarray(local)], 0.0)
    outside = np.asarray(M.causal_mask(s, s) & ~local)
    if outside.any():
        assert (b[outside] < -15).all()


@given(st.integers(4, 16), st.integers(1, 8), st.integers(0, 5))
def test_log_space_equals_multiplicative(s, w, seed):
    """softmax(qk + log m) == (exp(qk) * m) / sum — the paper's log-space
    transformation is exact."""
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    logits = jax.random.normal(k1, (s, s))
    g = jax.nn.sigmoid(jax.random.normal(k2, (s,)))
    causal = M.causal_mask(s, s)
    local = M.local_window_mask(s, s, w)
    m = jnp.where(local, 1.0, g[None, :]) * causal
    # multiplicative form
    e = jnp.exp(logits) * m
    ref = e / e.sum(-1, keepdims=True)
    # log-space form
    bias = M.write_gate_bias(g[None, None], s, w, eps=0.0)[0, 0]
    out = jax.nn.softmax(logits + bias, -1)
    assert np.allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


@given(st.integers(4, 32), st.integers(1, 8), st.floats(0.0, 1.0))
def test_vertical_slash_mask_structure(s, w, tau):
    g = jax.random.uniform(jax.random.PRNGKey(0), (1, 1, s))
    mask = np.asarray(M.vertical_slash_mask(g, tau, s, w)[0, 0])
    gn = np.asarray(g[0, 0])
    for i in range(s):
        for j in range(s):
            expect = (j <= i) and ((i - j < w) or (gn[j] >= tau))
            assert mask[i, j] == expect


# ==========================================================================
# admission (budgeted selection)
# ==========================================================================
@given(st.integers(8, 64), st.integers(1, 16), st.integers(0, 4),
       st.integers(0, 6))
def test_select_global_invariants(s, budget, sink, seed):
    g = jax.random.uniform(jax.random.PRNGKey(seed), (2, 3, s))
    sel = select_global(g, budget=budget, tau=0.5, sink=sink)
    idx, valid, count = map(np.asarray, sel)
    assert (count <= budget).all()
    assert (count == valid.sum(-1)).all()
    # valid indices are sorted ascending and admissible
    gn = np.asarray(g)
    for b in range(2):
        for h in range(3):
            ids = idx[b, h][valid[b, h]]
            assert (np.diff(ids) > 0).all() if len(ids) > 1 else True
            for j in ids:
                assert gn[b, h, j] >= 0.5 or j < sink
            # budget permitting, every sink is selected
            if sink and count[b, h] < budget:
                assert set(range(min(sink, s))) <= set(ids.tolist())


@given(st.integers(8, 48), st.integers(2, 8))
def test_exclusion_window(s, w):
    g = jnp.ones((1, 1, s))
    sel = select_global(g, budget=s, tau=0.1, exclude_from=s - w)
    ids = np.asarray(sel.idx[0, 0])[np.asarray(sel.valid[0, 0])]
    assert (ids < s - w).all()


@given(st.floats(0.05, 0.95), st.floats(0.05, 0.95))
def test_cache_size_monotone_in_tau(t1, t2):
    """Normalized cache size is monotone non-increasing in tau."""
    g = jax.random.uniform(jax.random.PRNGKey(1), (1, 2, 64))
    lo, hi = min(t1, t2), max(t1, t2)
    s_lo = np.asarray(normalized_cache_size(g, lo, 8))
    s_hi = np.asarray(normalized_cache_size(g, hi, 8))
    assert (s_hi <= s_lo + 1e-6).all()


# ==========================================================================
# dual cache + lazy promotion (paper §4.3, Fig. 6d)
# ==========================================================================
@given(st.integers(2, 6), st.integers(4, 12), st.integers(3, 30),
       st.integers(0, 4))
def test_ring_and_promotion_invariants(w, budget, steps, seed):
    key = jax.random.PRNGKey(seed)
    b, h, hd = 1, 2, 4
    cache = init_dual_cache(b, h, hd, w_local=w, budget=budget)
    tau = 0.5
    gs = jax.random.uniform(key, (steps, b, h))
    for t in range(steps):
        k = jnp.full((b, h, hd), float(t))
        cache = lazy_promote_and_write(cache, k, k, gs[t], tau=tau)
    # ring holds exactly the last min(steps, w) tokens
    lpos = np.asarray(cache.lpos[0])
    expect_ring = set(range(max(0, steps - w), steps))
    assert set(lpos[lpos >= 0].tolist()) == expect_ring
    # promoted tokens: exited ring AND g >= tau (up to budget, in order)
    gn = np.asarray(gs)[:, 0]
    for hh in range(h):
        exited = [t for t in range(max(0, steps - w)) if gn[t, hh] >= tau]
        cnt = int(cache.gcnt[0, hh])
        kept = exited[:budget]
        assert cnt == len(kept)
        assert np.asarray(cache.gpos[0, hh])[:cnt].tolist() == kept
        assert int(cache.overflow[0, hh]) == len(exited) - len(kept)
        # promoted K values carry the right token payload
        gk = np.asarray(cache.gk[0, hh])[:cnt]
        assert np.allclose(gk[:, 0], kept)
    # attention view marks exactly (gcnt + ring) entries valid
    _, _, valid = cache_kv_for_attention(cache)
    v = np.asarray(valid[0])
    for hh in range(h):
        assert v[hh].sum() == int(cache.gcnt[0, hh]) + min(steps, w)


@given(st.integers(1, 3))
def test_prefill_populate_matches_streaming(seed):
    """Prefilling S tokens == streaming them one-by-one through the ring."""
    key = jax.random.PRNGKey(seed)
    b, h, hd, w, budget, s = 1, 2, 4, 4, 8, 12
    tau, sink = 0.5, 1
    ks = jax.random.normal(key, (b, h, s, hd))
    vs = jax.random.normal(jax.random.fold_in(key, 1), (b, h, s, hd))
    g = jax.random.uniform(jax.random.fold_in(key, 2), (b, h, s))
    g = g.at[:, :, :sink].set(1.0)  # sinks admitted in both paths
    c1 = init_dual_cache(b, h, hd, w_local=w, budget=budget)
    c1 = prefill_populate(c1, ks, vs, g, tau=tau, sink=sink)
    c2 = init_dual_cache(b, h, hd, w_local=w, budget=budget)
    for t in range(s):
        c2 = lazy_promote_and_write(c2, ks[:, :, t], vs[:, :, t],
                                    g[:, :, t], tau=tau)
    assert np.array_equal(np.asarray(c1.gcnt), np.asarray(c2.gcnt))
    assert np.array_equal(np.asarray(c1.gpos), np.asarray(c2.gpos))
    assert np.allclose(np.asarray(c1.gk), np.asarray(c2.gk), atol=1e-6)
    assert np.array_equal(np.asarray(c1.lpos), np.asarray(c2.lpos))
    assert np.allclose(np.asarray(c1.lk), np.asarray(c2.lk), atol=1e-6)
    assert int(c1.ptr[0]) == int(c2.ptr[0])
