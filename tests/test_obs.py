"""Serving observability (repro.serving.obs): tracer ring buffer +
disabled-path overhead, metrics registry windows, Telemetry edge cases,
deterministic-clock phase accounting, and Chrome-trace export validity.

Everything here runs host-only against a fake EngineBackend — no model,
no device work — so the tick-loop instrumentation and export contracts
are pinned cheaply; tests/test_serving.py covers the real engines end to
end (including ``--trace-out`` through bench_serving in CI).
"""
import json
import time
from typing import Dict

import pytest

from repro.serving.backend import (BackendCapabilities, FusedStep,
                                   PrefillTask)
from repro.serving.obs import (CAT_ENGINE, CAT_REQUEST, LANE_REQ, LANE_TICK,
                               NULL_TRACER, MetricsRegistry, Tracer,
                               chrome_trace, chrome_trace_events,
                               validate_chrome_trace, write_chrome_trace)
from repro.serving.obs.export import main as validate_cli
from repro.serving.orchestrator.scheduler import Orchestrator, SchedulerConfig
from repro.serving.orchestrator.telemetry import (PHASE_TIME_KEYS,
                                                  TELEMETRY_SCHEMA_VERSION,
                                                  Telemetry)


class FakeClock:
    """Deterministic strictly-increasing clock (1 ms per read)."""

    def __init__(self, step: float = 1e-3):
        self.t = 0.0
        self.step = step

    def __call__(self) -> float:
        self.t += self.step
        return self.t


class FakeEngine:
    """Host-only EngineBackend: the fused megabatch tick as pure
    bookkeeping (one ``step_batch`` per tick advancing prompt chunks
    teacher-forced and marking decode rows; ``collect`` delivers one
    token per finishing/decoding row)."""
    eos = None

    def __init__(self, slots: int = 2):
        self.slots = slots
        self.live = [False] * slots
        self.stats = {"steps": 0, "evict_triggers": 0.0,
                      "decode_adm_sum": 0.0, "extend_time_s": 0.0,
                      "extend_tokens": 0.0, "fused_steps": 0.0,
                      "fused_time_s": 0.0, "fused_prefill_time_s": 0.0,
                      "fused_prefill_tokens": 0.0, "fused_slot_rows": 0.0,
                      "fused_active_rows": 0.0, "selected_pages": 0.0,
                      "selection_time_s": 0.0}
        self.tracer = NULL_TRACER
        self._n = 0

    def capabilities(self):
        return BackendCapabilities(name="fake", gated=False, paged=False)

    def memory_snapshot(self) -> Dict[str, float]:
        return {"kv_tokens": float(sum(self.live) * 10), "kv_bytes": 64.0}

    def start_prefill(self, prompt):
        return PrefillTask(prompt=list(prompt))

    def step_batch(self, tasks, chunk=None, decode=True):
        decode_rows = tuple(s for s in range(self.slots)
                            if decode and self.live[s])
        takes, fins = [], []
        for t in tasks:
            take = (len(t.prompt) - t.pos if chunk is None
                    else min(len(t.prompt) - t.pos, chunk))
            t.pos += take
            t.adm_weighted += 0.5 * take
            takes.append(take)
            fins.append(t.done)
            if t.done:          # row resident + live; first token at collect
                self.live[t.slot] = True
            self.stats["fused_prefill_tokens"] += take
            self.stats["fused_prefill_time_s"] += 1e-5
        if not tasks and not decode_rows:
            return None
        self.stats["fused_steps"] += 1
        self.stats["fused_time_s"] += 1e-4
        self.stats["fused_slot_rows"] += float(self.slots)
        self.stats["fused_active_rows"] += float(len(tasks)
                                                 + len(decode_rows))
        return FusedStep(tokens=None, stats=None, before=None, after=None,
                         live=tuple(self.live), gen=(0,) * self.slots,
                         tasks=tuple(tasks), takes=tuple(takes),
                         fulls=tuple(tk == chunk for tk in takes),
                         finishing=tuple(fins), decode_rows=decode_rows,
                         had_prefill=bool(tasks))

    def collect(self, step):
        self.stats["steps"] += 1
        self.stats["decode_adm_sum"] += 0.5
        self._n += 1
        out = {}
        for t, fin in zip(step.tasks, step.finishing):
            if fin and self.live[t.slot]:
                out[t.slot] = 100 + self._n
        for s in step.decode_rows:
            if step.live[s] and self.live[s]:
                out[s] = 100 + self._n
        return out

    def free_slot(self, slot):
        self.live[slot] = False


def _serve(n_req=3, prompt_len=10, max_new=5, **orch_kw):
    clk = FakeClock()
    orch_kw.setdefault("clock", clk)
    eng = FakeEngine()
    orch = Orchestrator(eng, sched=SchedulerConfig(chunk_tokens=4,
                                                   dispatch_ahead=1),
                        **orch_kw)
    rids = [orch.submit(list(range(prompt_len)), max_new=max_new)
            for _ in range(n_req)]
    orch.run()
    return orch, rids


# ==========================================================================
# tracer: ring buffer, disabled no-op path, span recording
# ==========================================================================
def test_tracer_ring_buffer_bounds_memory():
    tr = Tracer(capacity=8, clock=FakeClock())
    for i in range(20):
        tr.add(f"s{i}", float(i), float(i) + 0.5)
    assert len(tr.spans) == 8
    assert tr.emitted == 20
    assert tr.dropped == 12
    # the ring keeps the NEWEST spans (oldest fall off)
    assert [s.name for s in tr.spans] == [f"s{i}" for i in range(12, 20)]
    got = tr.drain()
    assert len(got) == 8 and not tr.spans


def test_tracer_span_context_manager_records():
    clk = FakeClock()
    tr = Tracer(clock=clk)
    with tr.span("phase", cat=CAT_ENGINE, lane=(LANE_TICK, 0), tick=3):
        pass
    with tr.span("life", cat=CAT_REQUEST, lane=(LANE_REQ, 7)):
        pass
    tr.instant("finish", cat=CAT_REQUEST, lane=(LANE_REQ, 7), rid=7)
    assert len(tr.spans) == 3
    s0, s1, s2 = tr.spans
    assert s0.name == "phase" and s0.args == {"tick": 3} and s0.t1 > s0.t0
    assert s1.lane == (LANE_REQ, 7)
    assert s2.t0 == s2.t1            # instant
    assert tr.span("x").__class__.__name__ == "_SpanCm"


def test_null_tracer_is_noop_and_shared():
    calls = []
    tr = Tracer(capacity=4, clock=lambda: calls.append(1) or 0.0,
                enabled=False)
    cm1 = tr.span("a", tick=1)
    cm2 = tr.span("b")
    assert cm1 is cm2                # one shared pre-allocated no-op cm
    with cm1:
        pass
    tr.add("c", 0.0, 1.0)
    tr.instant("d")
    assert not calls                 # disabled path never touches the clock
    assert len(tr.spans) == 0 and tr.emitted == 0
    assert NULL_TRACER.enabled is False


def test_disabled_tracer_overhead_is_noop_cheap():
    """The acceptance bar: with tracing off, instrumented call sites cost
    a branch — bounded here as < 3x the cost of a bare function call, so
    a regression that makes the disabled path allocate or read the clock
    fails loudly."""
    tr = Tracer(capacity=1, enabled=False)
    n = 50_000

    # the baseline pays the SAME argument-passing cost as the call site
    # (a no-arg `bare()` makes the 3x bound a knife edge on slow boxes:
    # kwargs packing alone costs ~3x a bare no-arg call)
    def bare(name, t0, t1, cat=None, lane=None):
        pass

    t0 = time.perf_counter()
    for _ in range(n):
        bare("x", 0.0, 1.0, cat=CAT_ENGINE, lane=(LANE_TICK, 0))
    t_bare = time.perf_counter() - t0
    t0 = time.perf_counter()
    for _ in range(n):
        tr.add("x", 0.0, 1.0, cat=CAT_ENGINE, lane=(LANE_TICK, 0))
    t_add = time.perf_counter() - t0
    assert t_add < max(t_bare, 1e-4) * 3.0, (t_add, t_bare)


# ==========================================================================
# metrics registry: counters / gauges / rolling-window histograms
# ==========================================================================
def test_registry_counter_rate_and_windows():
    clk = FakeClock(step=0.0)        # manual time control
    reg = MetricsRegistry(clock=lambda: clk.t, window_s=10.0)
    c = reg.counter("tok")
    c.mark(0.0)
    c.inc(50)
    clk.t = 5.0
    assert c.rate(clk.t, 10.0) == pytest.approx(10.0)
    h = reg.histogram("lat")
    for i, t in enumerate([1.0, 2.0, 11.0, 12.0]):
        h.observe(float(i), now=t)
    # at t=13 the 10s window holds only the observations at t=11, 12
    st = h.window_stats(13.0)
    assert st["count"] == 2.0
    assert st["p50"] == pytest.approx(2.5)
    assert h.count == 4 and h.min == 0.0 and h.max == 3.0   # cumulative
    snap = reg.snapshot()
    assert snap["counters"]["tok"] == 50.0
    assert snap["histograms"]["lat"]["count"] == 4.0


def test_registry_get_or_create_is_stable():
    reg = MetricsRegistry(clock=lambda: 0.0)
    assert reg.counter("a") is reg.counter("a")
    assert reg.gauge("g") is reg.gauge("g")
    assert reg.histogram("h") is reg.histogram("h")
    reg.gauge("g").set(3)
    assert reg.gauge("g").value == 3.0


# ==========================================================================
# telemetry edge cases (satellite: empty records, unseen keys, schema)
# ==========================================================================
def test_telemetry_empty_summary_and_report():
    """summary()/report() on a telemetry with zero recorded requests must
    not divide by zero or KeyError — every latency field is None and the
    report renders placeholders."""
    t = Telemetry(clock=FakeClock())
    s = t.summary()
    assert s["requests"] == 0
    assert s["ttft_p99_s"] is None and s["tpot_p99_s"] is None
    assert s["tokens_per_s"] is None or s["tokens_per_s"] == 0.0
    r = t.report()
    assert "requests=0" in r and "p99=-" in r
    assert "tick phases:" in r


def test_telemetry_bump_unseen_key_creates_counter():
    t = Telemetry(clock=FakeClock())
    assert "brand_new" not in t.counters
    t.bump("brand_new")
    t.bump("brand_new", 2.5)
    assert t.counters["brand_new"] == 3.5
    # dict-contract of the CounterView facade
    assert t.counters.get("missing") is None
    with pytest.raises(KeyError):
        t.counters["missing"]
    d = dict(t.counters)
    assert d["brand_new"] == 3.5


def test_telemetry_schema_version_and_generated_at():
    t = Telemetry(clock=FakeClock())
    s = t.summary()
    assert s["schema_version"] == TELEMETRY_SCHEMA_VERSION
    # ISO-8601 with explicit UTC offset
    assert "T" in s["generated_at"] and "+00:00" in s["generated_at"]


def test_telemetry_tpot_p99_in_report():
    """Satellite bugfix: the TPOT line must render the same p99 tail the
    SLO gate checks."""
    clk = FakeClock()
    t = Telemetry(clock=clk)
    for rid in range(5):
        t.record_request(rid=rid, prompt_len=8, n_out=4, ttft=0.010,
                         tpot=0.002 * (rid + 1), e2e=0.05,
                         mean_admission=0.5)
    tpot_line = [ln for ln in t.report().splitlines()
                 if ln.startswith("TPOT")][0]
    assert "p99=" in tpot_line
    assert f"{t.summary()['tpot_p99_s'] * 1e3:.2f}ms" in tpot_line


# ==========================================================================
# deterministic-clock orchestrator accounting + request lifecycle spans
# ==========================================================================
def test_phase_times_sum_within_tick_wall():
    """Satellite: with orchestrator and tracer on one deterministic
    clock, the disjoint phase durations must sum to <= the accumulated
    tick wall time (no double-counted phase)."""
    clk = FakeClock()
    tr = Tracer(clock=clk)
    orch, _ = _serve(clock=clk, tracer=tr)
    ph = orch.telemetry.phase_times()
    assert ph["tick_time_s"] > 0.0
    assert ph["phase_sum_s"] <= ph["tick_time_s"] + 1e-12
    assert ph["phase_sum_s"] == pytest.approx(
        sum(ph[k] for k in PHASE_TIME_KEYS))
    # every disjoint phase that ran is represented (prefill_time_s stays
    # 0 — prompt chunks ride the fused dispatch, not a separate stage)
    for k in ("dispatch_time_s", "collect_time_s",
              "evict_time_s", "memory_sample_time_s", "admit_time_s"):
        assert ph[k] > 0.0, k
    assert ph["prefill_time_s"] == 0.0


def test_request_lifecycle_spans_complete():
    clk = FakeClock()
    tr = Tracer(clock=clk)
    orch, rids = _serve(clock=clk, tracer=tr)
    by_rid = {rid: [s for s in tr.spans if s.lane == (LANE_REQ, rid)]
              for rid in rids}
    for rid, spans in by_rid.items():
        names = [s.name for s in spans]
        assert "queued" in names
        assert any(n.startswith("prefill[chunk ") for n in names)
        assert "insert" in names and "decode" in names
        assert "finish" in names
        # lifecycle ordering: queued ends before decode begins
        queued = next(s for s in spans if s.name == "queued")
        decode = next(s for s in spans if s.name == "decode")
        assert queued.t1 <= decode.t0
    # engine-lane phases landed too (the fused tick's span vocabulary)
    tick_names = {s.name for s in tr.spans if s.lane == (LANE_TICK, 0)}
    assert {"memory_sample", "admit", "fused_step",
            "collect", "evict"} <= tick_names


def test_cancel_emits_terminal_instant():
    clk = FakeClock()
    tr = Tracer(clock=clk)
    eng = FakeEngine()
    orch = Orchestrator(eng, sched=SchedulerConfig(chunk_tokens=4,
                                                   dispatch_ahead=1),
                        clock=clk, tracer=tr)
    rid = orch.submit(list(range(10)), max_new=50)
    for _ in range(6):
        orch.tick()
    assert orch.cancel(rid)
    marks = [s for s in tr.spans
             if s.lane == (LANE_REQ, rid) and s.t0 == s.t1]
    assert any(s.name == "cancelled" for s in marks)


def test_live_metrics_line_cuts_on_interval():
    lines = []
    clk = FakeClock()
    _serve(n_req=4, max_new=8, clock=clk, metrics_interval_s=0.02,
           on_metrics=lines.append)
    assert lines, "no live metrics line was cut"
    assert all(ln.startswith("[metrics +") for ln in lines)
    assert "tok/s=" in lines[0] and "ttft_p50=" in lines[0]


# ==========================================================================
# Chrome-trace export + validator
# ==========================================================================
def test_chrome_trace_export_and_validate(tmp_path):
    clk = FakeClock()
    tr = Tracer(clock=clk)
    orch, _ = _serve(clock=clk, tracer=tr)
    path = tmp_path / "trace.json"
    obj = write_chrome_trace(tr, str(path), meta={"run": "test"})
    assert validate_chrome_trace(obj) == []
    on_disk = json.loads(path.read_text())
    assert validate_chrome_trace(on_disk) == []
    assert on_disk["otherData"]["run"] == "test"
    assert on_disk["otherData"]["schema_version"] == 1
    # both span families present, timestamps rebased to 0 and in us
    evs = [e for e in on_disk["traceEvents"] if e["ph"] in ("X", "i")]
    assert any(e["cat"] == "engine" for e in evs)
    assert any(e["cat"] == "request" for e in evs)
    assert min(e["ts"] for e in evs) == 0.0
    xs = [e for e in evs if e["ph"] == "X"]
    assert all(e["dur"] >= 0 for e in xs)
    # the CLI validator agrees
    assert validate_cli([str(path)]) == 0


def test_validator_rejects_hollow_traces(tmp_path):
    assert chrome_trace_events([]) == []
    empty = {"traceEvents": [], "otherData": {}}
    errs = validate_chrome_trace(empty)
    assert any("engine" in e for e in errs)
    assert any("request" in e for e in errs)
    assert any("schema_version" in e for e in errs)
    # engine-only trace (request instrumentation fell off) is invalid
    tr = Tracer(clock=FakeClock())
    tr.add("tick", 0.0, 1.0, cat=CAT_ENGINE, lane=(LANE_TICK, 0))
    assert any("request" in e
               for e in validate_chrome_trace(chrome_trace(tr)))
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    assert validate_cli([str(bad)]) == 1
    assert validate_cli([]) == 2


def test_trace_disabled_serving_matches_enabled():
    """Tracing must observe, never steer: token streams are identical
    with the tracer on and off."""
    ref, rids = _serve()
    traced, rids2 = _serve(tracer=Tracer(clock=FakeClock()))
    assert rids == rids2
    for rid in rids:
        assert ref.tokens(rid) == traced.tokens(rid)
    # and the default orchestrator runs on the shared NULL_TRACER
    assert ref.tracer is NULL_TRACER and not ref.tracer.spans
