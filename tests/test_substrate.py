"""Substrate tests: MoE dispatch, recurrent blocks, optimizer, checkpoint,
data pipeline, sharding rules."""
import dataclasses
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_cfg
from repro.configs import ARCH_NAMES, get_config, get_reduced_config


# ==========================================================================
# MoE
# ==========================================================================
def test_moe_nodrop_equals_dense(key):
    """With capacity >= all tokens, argsort dispatch == explicit per-token
    expert mixture."""
    from repro.models import moe as MoE

    cfg = get_reduced_config("granite-moe-3b-a800m").replace(dtype="float32")
    cfg = cfg.replace(moe=dataclasses.replace(
        cfg.moe, capacity_factor=float(cfg.moe.n_experts)))
    p = MoE.init_moe(key, cfg)
    x = jax.random.normal(key, (2, 16, cfg.d_model)) * 0.5
    y, aux = MoE.moe_ffn(p, cfg, x)
    # dense reference
    logits = x @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    tw, ti = jax.lax.top_k(probs, cfg.moe.top_k)
    tw = tw / tw.sum(-1, keepdims=True)
    h = jnp.einsum("bsd,edf->bsef", x, p["w_gate"])
    u = jnp.einsum("bsd,edf->bsef", x, p["w_up"])
    yo = jnp.einsum("bsef,efd->bsed", jax.nn.silu(h) * u, p["w_down"])
    ref = jnp.zeros_like(x)
    for kk in range(cfg.moe.top_k):
        sel = jnp.take_along_axis(yo, ti[..., kk][..., None, None], 2)[..., 0, :]
        ref = ref + tw[..., kk][..., None] * sel
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=1e-4)
    assert float(aux["router_drop_frac"]) == 0.0


def test_moe_capacity_drops_counted(key):
    from repro.models import moe as MoE

    cfg = get_reduced_config("granite-moe-3b-a800m").replace(dtype="float32")
    cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=0.5))
    p = MoE.init_moe(key, cfg)
    x = jax.random.normal(key, (2, 32, cfg.d_model))
    y, aux = MoE.moe_ffn(p, cfg, x)
    assert 0.0 < float(aux["router_drop_frac"]) < 1.0
    assert float(aux["lb_loss"]) >= 1.0 - 1e-3  # >= 1 by Cauchy-Schwarz


def test_moe_groups_consistent(key):
    """Group count must not change results when routing is drop-free."""
    from repro.models import moe as MoE

    cfg = get_reduced_config("qwen3-moe-235b-a22b").replace(dtype="float32")
    cfg = cfg.replace(moe=dataclasses.replace(
        cfg.moe, capacity_factor=float(cfg.moe.n_experts)))
    p = MoE.init_moe(key, cfg)
    x = jax.random.normal(key, (4, 8, cfg.d_model))
    y1, _ = MoE.moe_ffn(p, cfg, x, groups=1)
    y2, _ = MoE.moe_ffn(p, cfg, x, groups=4)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)


# ==========================================================================
# recurrent blocks
# ==========================================================================
def test_rglru_block_vs_step(key):
    from repro.models import rglru as RG

    cfg = get_reduced_config("recurrentgemma-9b").replace(dtype="float32")
    p = RG.init_rglru(key, cfg)
    x = jax.random.normal(key, (2, 24, cfg.d_model)) * 0.5
    y_full, st_full = RG.rglru_block(p, cfg, x)
    st = RG.init_rglru_state(cfg, 2)
    outs = []
    for t in range(24):
        y, st = RG.rglru_step(p, cfg, x[:, t], st)
        outs.append(y)
    np.testing.assert_allclose(np.asarray(jnp.stack(outs, 1)),
                               np.asarray(y_full), atol=2e-5)
    np.testing.assert_allclose(np.asarray(st.h), np.asarray(st_full.h),
                               atol=2e-5)


def test_rglru_streaming_split(key):
    from repro.models import rglru as RG

    cfg = get_reduced_config("recurrentgemma-9b").replace(dtype="float32")
    p = RG.init_rglru(key, cfg)
    x = jax.random.normal(key, (1, 32, cfg.d_model))
    y_full, _ = RG.rglru_block(p, cfg, x)
    y1, st = RG.rglru_block(p, cfg, x[:, :16])
    y2, _ = RG.rglru_block(p, cfg, x[:, 16:], st)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate([y1, y2], 1)), np.asarray(y_full),
        atol=2e-5)


def test_mlstm_three_forms_agree(key):
    from repro.models import xlstm as XL

    cfg = get_reduced_config("xlstm-350m").replace(dtype="float32")
    p = XL.init_mlstm(key, cfg)
    x = jax.random.normal(key, (2, 64, cfg.d_model)) * 0.5
    y_quad, _ = XL.mlstm_block(p, cfg, x)
    y_ch, _ = XL.mlstm_block_chunkwise(p, cfg, x, chunk=16)
    st = XL.init_mlstm_state(cfg, 2)
    outs = []
    for t in range(64):
        y, st = XL.mlstm_step(p, cfg, x[:, t], st)
        outs.append(y)
    y_step = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(y_ch), np.asarray(y_quad), atol=2e-5)
    np.testing.assert_allclose(np.asarray(y_step), np.asarray(y_quad), atol=2e-5)


def test_slstm_block_vs_step(key):
    from repro.models import xlstm as XL

    cfg = get_reduced_config("xlstm-350m").replace(dtype="float32")
    p = XL.init_slstm(key, cfg)
    x = jax.random.normal(key, (2, 24, cfg.d_model)) * 0.5
    y_full, st_full = XL.slstm_block(p, cfg, x)
    st = XL.init_slstm_state(cfg, 2)
    outs = []
    for t in range(24):
        y, st = XL.slstm_step(p, cfg, x[:, t], st)
        outs.append(y)
    np.testing.assert_allclose(np.asarray(jnp.stack(outs, 1)),
                               np.asarray(y_full), atol=2e-5)


# ==========================================================================
# optimizer / checkpoint
# ==========================================================================
def test_adamw_converges_quadratic():
    from repro.training.optimizer import adamw_init, adamw_update

    p = {"x": jnp.asarray(5.0)}
    st = adamw_init(p)
    for _ in range(300):
        g = {"x": 2 * p["x"]}
        p, st = adamw_update(g, st, p, lr=0.1, weight_decay=0.0)
    assert abs(float(p["x"])) < 1e-2


def test_cosine_schedule_shape():
    from repro.training.optimizer import cosine_schedule

    lr = cosine_schedule(1e-3, 100, warmup_frac=0.1)
    assert float(lr(0)) < float(lr(10))
    assert abs(float(lr(10)) - 1e-3) < 1e-9
    assert float(lr(100)) < 1e-5


def test_checkpoint_roundtrip(key):
    from repro.models import transformer as T
    from repro.training import checkpoint as C
    from repro.training import trainer as TR

    cfg = make_cfg("smollm-360m")
    params = T.init_model(key, cfg)
    gates = TR.get_gates(params)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "g.npz")
        C.save(path, gates, meta={"arch": cfg.name})
        like = jax.tree.map(jnp.zeros_like, gates)
        back = C.restore(path, like)
        assert C.load_meta(path)["arch"] == cfg.name
    for k in gates:
        np.testing.assert_allclose(np.asarray(gates[k]), np.asarray(back[k]))


def test_trainer_freezes_backbone(key):
    """Gate-only training: backbone params receive no updates, gates do."""
    from repro.models import transformer as T
    from repro.training import trainer as TR

    cfg = make_cfg("smollm-360m")
    params = T.init_model(key, cfg)
    state = TR.init_train_state(params)
    toks = jax.random.randint(key, (2, 64), 0, cfg.vocab_size)
    state2, _ = TR.train_step(state, params, cfg, {"tokens": toks}, lr=1e-2)
    merged = TR.set_gates(params, state2.gates)
    # backbone identical
    w0 = params["blocks"]["b0"]["attn"]["w_q"]
    assert merged["blocks"]["b0"]["attn"]["w_q"] is w0
    # gates moved
    g0 = params["blocks"]["b0"]["attn"]["gate"]["w1"]
    g1 = merged["blocks"]["b0"]["attn"]["gate"]["w1"]
    assert not np.allclose(np.asarray(g0), np.asarray(g1))


def test_training_reduces_loss_and_sparsifies(key):
    from repro.launch.train import run_training

    cfg = make_cfg("smollm-360m")
    params, state, hist = run_training(cfg, steps=25, batch=2, seq=96,
                                       lam=0.3, verbose=False)
    # sparsity pressure trades a little distill loss for a much smaller
    # cache: total loss must drop, gates must sparsify, distill stays sane
    assert hist[-1]["loss"] < hist[0]["loss"]
    assert hist[-1]["mean_gate"] < 0.6  # pushed down from the ~0.73 init
    assert hist[-1]["distill"] < hist[0]["distill"] * 3


# ==========================================================================
# data pipeline
# ==========================================================================
def test_needle_task_structure(key):
    from repro.data.synthetic import needle_task

    b = needle_task(key, 4, 128, 512, payload=3)
    toks = np.asarray(b["tokens"])
    ans = np.asarray(b["answer"])
    npos = np.asarray(b["needle_pos"])
    qpos = int(b["query_pos"])
    for i in range(4):
        assert toks[i, npos[i]] == 511          # needle marker
        assert (toks[i, npos[i] + 1: npos[i] + 4] == ans[i]).all()
        assert toks[i, qpos] == 511             # query = needle marker
        assert (toks[i, qpos + 1: qpos + 4] == ans[i]).all()
    assert np.asarray(b["loss_mask"]).sum() == 4 * 3


def test_token_stream_range(key):
    from repro.data.synthetic import token_stream

    t = np.asarray(token_stream(key, 2, 256, 1000))
    assert t.min() >= 0 and t.max() < 1000 - 8


# ==========================================================================
# sharding rules
# ==========================================================================
def _check_spec_divides(shape, spec, mesh):
    for dim, ax in zip(shape, tuple(spec) + (None,) * len(shape)):
        if ax is None:
            continue
        axes = (ax,) if isinstance(ax, str) else ax
        n = 1
        for a in axes:
            n *= mesh.shape[a]
        assert dim % n == 0, (shape, spec)


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_param_shardings_divisible(name):
    import jax

    from repro.launch.steps import param_structs
    from repro.sharding import rules

    cfg = get_config(name)
    mesh = jax.sharding.Mesh(
        np.asarray(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    # use abstract mesh shape (16,16) via a fake: check divisibility logic
    # against the real production sizes by calling the spec fn directly
    from jax.sharding import PartitionSpec as P

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    pstruct = param_structs(cfg)
    flat = jax.tree_util.tree_flatten_with_path(pstruct)[0]
    for path, leaf in flat:
        keys = rules._path_keys(path)
        spec = rules._param_spec(keys, tuple(leaf.shape), FakeMesh(), cfg)
        _check_spec_divides(leaf.shape, spec, FakeMesh())


def test_pick_fallback():
    from repro.sharding import rules

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    m = FakeMesh()
    assert rules.pick(40, m, "model") is None          # 40 % 16 != 0
    assert rules.pick(48, m, "model") == "model"
    assert rules.pick(40, m, "model", ("data",)) is None
    assert rules.pick(64, m, "model", "data") == "model"
