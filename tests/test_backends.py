"""EngineBackend protocol: orchestrator is protocol-only, backend parity
(dense streamed tokens == legacy dense rollout), WG-KV-vs-dense A/B
admission under one trace, static-admission baselines, and paged-pool
allocation regressions (lazy ring pages, eviction-time reclamation)."""
import glob
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_cfg
from repro.models import inference as I
from repro.models import transformer as T
from repro.serving.backend import (BACKEND_NAMES, BackendCapabilities,
                                   EngineBackend, make_backend)
from repro.serving.orchestrator import Orchestrator, SchedulerConfig
from repro.serving.paged import PAGE_SIZE

pytestmark = pytest.mark.backends


def _generate(eng):
    """One synchronous batched decode step through the two-phase surface
    (a task-less fused dispatch — the decode-only top-up the scheduler
    issues — collected immediately)."""
    step = eng.step_batch([])
    return eng.collect(step) if step is not None else {}


@pytest.fixture(scope="module")
def served():
    # tau=0.5 gates a nonzero fraction of tokens even at random init, so
    # the WG-KV backend reports admission strictly < 1.0 in the A/B test
    cfg = make_cfg("qwen3-0.6b", global_budget_frac=0.5, tau=0.5)
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


# ==========================================================================
# protocol: orchestrator never imports a concrete engine
# ==========================================================================
def test_orchestrator_is_protocol_only():
    import repro.serving.orchestrator as O
    pkg = os.path.dirname(O.__file__)
    for path in glob.glob(os.path.join(pkg, "*.py")):
        src = open(path).read()
        for concrete in ("serving.engine", "serving.dense",
                         "serving.static_admission"):
            assert concrete not in src, \
                f"{os.path.basename(path)} imports concrete backend {concrete}"


def test_backends_satisfy_protocol(served):
    cfg, params = served
    for name in BACKEND_NAMES:
        eng = make_backend(name, params, cfg, slots=2, capacity=128)
        assert isinstance(eng, EngineBackend)
        caps = eng.capabilities()
        assert isinstance(caps, BackendCapabilities)
        assert caps.name == name
        snap = eng.memory_snapshot()
        assert "kv_tokens" in snap and "kv_bytes" in snap
    with pytest.raises(ValueError):
        make_backend("nope", params, cfg)


# ==========================================================================
# dense backend parity: streamed tokens == legacy dense rollout
# ==========================================================================
def _legacy_dense_rollout(params, cfg, prompt, max_new, capacity=128):
    """Reference full-KV greedy rollout: the first token comes from the
    prefill's own last-position logits (no re-feed of prompt[-1] — the
    retired convention double-wrote KV at position n and shifted every
    later position by one), subsequent tokens from the decode loop."""
    toks = jnp.asarray(prompt, jnp.int32)[None]
    po, caches = I.prefill(params, cfg, toks, use_wgkv=False, max_len=capacity)
    cur = int(jnp.argmax(po.logits[0]))
    out = [cur]
    for _ in range(max_new - 1):
        logits, caches, _ = I.decode_step(
            params, cfg, jnp.asarray([cur], jnp.int32), caches)
        cur = int(jnp.argmax(logits[0]))
        out.append(cur)
    return out


def test_dense_stream_matches_legacy_dense_rollout(served):
    cfg, params = served
    prompts = [list(range(10 + i, 58 + i)) for i in range(3)]
    want = [_legacy_dense_rollout(params, cfg, p, max_new=5) for p in prompts]

    eng = make_backend("dense", params, cfg, slots=2, capacity=128)
    orch = Orchestrator(eng, sched=SchedulerConfig(chunk_tokens=16))
    streamed = {}
    for p in prompts:
        orch.submit(p, max_new=5,
                    on_token=lambda r, t, last:
                    streamed.setdefault(r, []).append(t))
    orch.run()
    for rid in range(len(prompts)):
        assert orch.tokens(rid) == want[rid]
        assert streamed[rid] == want[rid]


def test_dense_capacity_overflow_fails_loudly(served):
    """Decode past the dense buffer must raise, not silently drop writes
    (JAX OOB scatter) and serve a corrupted cache — and it must raise at
    DISPATCH time, before the overflowing step is enqueued."""
    cfg, params = served
    eng = make_backend("dense", params, cfg, slots=1, capacity=40)
    with pytest.raises(AssertionError):
        eng.start_prefill(list(range(48)))  # prompt alone exceeds capacity
    prefix = eng.prefill(list(range(36)))   # t = 36 (first token is free)
    eng.insert(prefix, 0)
    with pytest.raises(RuntimeError, match="dense cache overflow"):
        for _ in range(8):
            _generate(eng)


def test_dense_chunked_prefill_matches_one_shot(served):
    cfg, params = served
    eng = make_backend("dense", params, cfg, slots=1, capacity=128)
    prompt = list(range(5, 60))  # 55 tokens: ragged (dense needs no align)
    one = eng.prefill(prompt, chunk_tokens=None)
    chunked = eng.prefill(prompt, chunk_tokens=16)
    assert one.first_token == chunked.first_token
    assert np.allclose(np.asarray(one.first_logits),
                       np.asarray(chunked.first_logits), atol=1e-4)
    assert one.mean_admission == chunked.mean_admission == 1.0


# ==========================================================================
# A/B under one trace: admission < 1.0 only for gated backends
# ==========================================================================
def _serve_trace(eng, trace):
    orch = Orchestrator(eng, sched=SchedulerConfig(chunk_tokens=16))
    for prompt, max_new in trace:
        orch.submit(prompt, max_new=max_new)
    orch.run()
    return orch.telemetry.summary()


def test_ab_admission_gated_only(served):
    cfg, params = served
    trace = [(list(range(i, 48 + i)), 4) for i in range(3)]
    s = {}
    for name in ("wgkv", "dense", "streaming_llm"):
        eng = make_backend(name, params, cfg, slots=2, capacity=128,
                           mirror_paged=False)
        s[name] = _serve_trace(eng, trace)
    # dense full-KV admits everything, exactly
    assert s["dense"]["mean_admission"] == 1.0
    assert s["dense"]["mean_admission_decode"] == 1.0
    # gated backends admit strictly less under the same trace
    assert s["wgkv"]["mean_admission"] < 1.0
    assert s["streaming_llm"]["mean_admission"] < 1.0
    # same traffic completed everywhere
    gen = [s[n]["counters"]["generated_tokens"] for n in s]
    assert gen[0] == gen[1] == gen[2] == 12
    # memory telemetry orders as the paper expects: static sinks-only
    # retains the least, dense the most
    assert (s["streaming_llm"]["kv_tokens_peak"]
            < s["wgkv"]["kv_tokens_peak"] <= s["dense"]["kv_tokens_peak"])


# ==========================================================================
# slot retirement: free_slot must zero the row's feedback token
# ==========================================================================
def test_free_slot_resets_last_token(served):
    """A retired slot keeps decoding (masked) in the batched step; its
    ``last_token`` must be zeroed on free so the dead row feeds token 0,
    not a replay of its final token — and the fused dispatch enforces
    it."""
    cfg, params = served
    eng = make_backend("wgkv", params, cfg, slots=2, capacity=128,
                       mirror_paged=False)
    eng.insert(eng.prefill(list(range(10, 58))), 0)
    eng.insert(eng.prefill(list(range(30, 78))), 1)
    assert _generate(eng).keys() == {0, 1}
    eng.free_slot(0)
    assert eng.last_token[0] == 0
    out = _generate(eng)            # row 0 dead: only slot 1 emits
    assert set(out) == {1}
    # a stale token on a dead row is exactly the bug dispatch refuses
    eng.last_token[0] = 123
    with pytest.raises(AssertionError, match="stale"):
        _generate(eng)


# ==========================================================================
# two-phase decode: pipelined dispatch/collect == synchronous, safe
# ==========================================================================
def test_dispatch_ahead_matches_synchronous(served):
    """The pipelined two-phase surface must emit exactly what the
    synchronous one-step-at-a-time driver does: dispatching step t+1
    before collecting step t (depth 2) cannot change any live row's
    greedy token."""
    cfg, params = served
    prompts = [list(range(10, 58)), list(range(30, 78))]

    def rollout(two_phase):
        eng = make_backend("wgkv", params, cfg, slots=2, capacity=128,
                           mirror_paged=False)
        for s, p in enumerate(prompts):
            eng.insert(eng.prefill(p), s)
        out = [[], []]
        if two_phase:
            inflight = [eng.step_batch([])]     # depth 2: t+1 behind t
            for _ in range(4):
                inflight.append(eng.step_batch([]))
                got = eng.collect(inflight.pop(0))
                for s, t in got.items():
                    out[s].append(t)
            got = eng.collect(inflight.pop(0))
            for s, t in got.items():
                out[s].append(t)
        else:
            for _ in range(5):
                for s, t in _generate(eng).items():
                    out[s].append(t)
        return out

    assert rollout(True) == rollout(False)


def test_collect_discards_freed_slot(served):
    """A slot freed between dispatch and collect must not deliver its
    token (generation guard): the cancelled request's output can never
    leak into a successor, and double-collect is refused."""
    cfg, params = served
    eng = make_backend("wgkv", params, cfg, slots=2, capacity=128,
                       mirror_paged=False)
    eng.insert(eng.prefill(list(range(10, 58))), 0)
    eng.insert(eng.prefill(list(range(30, 78))), 1)
    step = eng.step_batch([])
    eng.free_slot(0)                     # cancel slot 0 mid-flight
    out = eng.collect(step)
    assert set(out) == {1}               # slot 0's token discarded
    assert eng.last_token[0] == 0
    with pytest.raises(AssertionError, match="twice"):
        eng.collect(step)


# ==========================================================================
# bench arrival processes: Poisson trace generation
# ==========================================================================
def test_poisson_arrival_trace():
    from benchmarks.bench_serving import poisson_rate, record_trace

    assert poisson_rate("burst") is None
    assert poisson_rate("poisson:0.5") == 0.5
    for bad in ("poisson:-1", "poisson:x", "uniform"):
        with pytest.raises(ValueError):
            poisson_rate(bad)
    tr = record_trace(16, 256, prompt_len=8, max_new=2, seed=3,
                      arrival="poisson:0.5")
    ticks = [r["arrival_tick"] for r in tr]
    assert ticks == sorted(ticks) and ticks[0] >= 0
    assert len(set(ticks)) > 3          # spread over time, not one burst
    # deterministic replay given the seed, and mean gap ~ 1/rate ticks
    tr2 = record_trace(16, 256, prompt_len=8, max_new=2, seed=3,
                       arrival="poisson:0.5")
    assert [r["arrival_tick"] for r in tr2] == ticks
    assert 16 / 0.5 * 0.3 < ticks[-1] < 16 / 0.5 * 3


# ==========================================================================
# static admission baselines (StreamingLLM / DuoAttention)
# ==========================================================================
def test_streaming_llm_admits_only_sinks(served):
    cfg, params = served
    eng = make_backend("streaming_llm", params, cfg, slots=1, capacity=128,
                       sink=4, mirror_paged=False)
    prefix = eng.prefill(list(range(20, 68)), emit_first=False)
    assert prefix.mean_admission == pytest.approx(4 / 48)
    gcnt = np.asarray(prefix.caches["blocks"]["b0"].gcnt)
    assert (gcnt <= 4).all() and (gcnt > 0).all()


def test_streaming_llm_chunked_matches_one_shot_admission(served):
    """The engine's sink must govern BOTH prefill paths: the one-shot
    budgeted prefill (select_global's force-admitted sink floor) and the
    chunked extend path (lazy promotion of stored static gates) — with a
    sink different from cfg.wgkv.sink, the admitted sets must still agree."""
    cfg, params = served
    assert cfg.wgkv.sink != 2
    eng = make_backend("streaming_llm", params, cfg, slots=1, capacity=128,
                       sink=2, mirror_paged=False)
    one = eng.prefill(list(range(20, 68)), chunk_tokens=None, emit_first=False)
    chunked = eng.prefill(list(range(20, 68)), chunk_tokens=16,
                          emit_first=False)
    g1 = np.asarray(one.caches["blocks"]["b0"].gcnt)
    g2 = np.asarray(chunked.caches["blocks"]["b0"].gcnt)
    assert (g1 == g2).all()
    assert (g1 <= 2).all()
    assert one.mean_admission == pytest.approx(2 / 48)


def test_duo_retrieval_heads_admit_everything(served):
    cfg, params = served
    eng = make_backend("duo", params, cfg, slots=1, capacity=128, sink=4,
                       retrieval_heads=(0,), mirror_paged=False)
    prefix = eng.prefill(list(range(20, 68)), emit_first=False)
    gcnt = np.asarray(prefix.caches["blocks"]["b0"].gcnt)  # [layer..., B, H]
    # head 0 (retrieval) admits all pre-window tokens; head 1 sinks only
    assert (gcnt[..., 0] > gcnt[..., 1]).all()
    assert (gcnt[..., 1] <= 4).all()
    sink_frac = 4 / 48
    want = (1.0 + sink_frac) / 2  # mean over one retrieval + one sink head
    assert prefix.mean_admission == pytest.approx(want, abs=1e-3)


# ==========================================================================
# paged pool: lazy ring allocation (regression on page counts)
# ==========================================================================
@pytest.fixture(scope="module")
def wide_ring():
    # w_local (32) spans two pool pages so lazy vs eager ring mirroring
    # changes the page count for short prompts
    cfg = make_cfg("qwen3-0.6b", global_budget_frac=0.5, w_local=32)
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_lazy_ring_pages_short_prompt(wide_ring):
    cfg, params = wide_ring
    assert cfg.wgkv.w_local == 2 * PAGE_SIZE
    eng = make_backend("wgkv", params, cfg, slots=1, capacity=128)
    prefix = eng.prefill(list(range(10)), emit_first=True)  # 10 << w_local
    eng.insert(prefix, 0)
    w = cfg.wgkv.w_local
    # exactly the prompt: the first token is sampled from the prefill's
    # last-position logits, so it adds no KV write of its own
    n_local = 10
    local_tables = [t for k, t in eng.pool.tables.items() if k[-1] == "local"]
    assert local_tables, "no local streams mirrored"
    for t in local_tables:
        assert t.length == n_local          # only written slots, not the ring
        assert len(t.pages) == 1            # 11 tokens -> 1 page (eager: 2)
    assert eng.verify_paged() < 2e-3

    # decode past the wrap: stream grows page-by-page, then stabilizes at W
    for _ in range(w):
        _generate(eng)
    for t in local_tables:
        assert t.length == w
        assert len(t.pages) == 2
    assert eng.verify_paged() < 2e-3


# ==========================================================================
# paged pool: SnapKV eviction reclaims physical pages at eviction time
# ==========================================================================
def test_eviction_reclaims_pool_pages(served):
    cfg, params = served
    opts = I.DecodeOptions(evict_hard_budget=24, evict_frac=0.25, w_obs=16)
    eng = make_backend("wgkv", params, cfg, slots=1, capacity=128, opts=opts)
    rid = eng.add_request(list(range(0, 80)), max_new=40)
    triggered = False
    before = eng.stats["evict_triggers"]
    for _ in range(40):
        eng.step()
        if eng.requests[rid].done:
            break
        after = eng.stats["evict_triggers"]
        if after > before:
            triggered = True
            # physical streams must track the shrunken logical view NOW —
            # freed pages are back in the allocator, not parked until the
            # next insert re-sync
            for (lkey, dc) in eng._iter_dual(eng.caches):
                for h in range(cfg.n_kv_heads):
                    tbl = eng.pool.table((0, lkey, h, "global"))
                    assert tbl.length == int(dc.gcnt[0, h])
            assert eng.verify_paged() < 2e-3
        before = after
    assert triggered, "eviction never triggered; test setup is too small"
