import jax
import pytest

# Tests run on the single host CPU device. (The multi-device dry-run tests
# spawn subprocesses with XLA_FLAGS; never set it here.)
jax.config.update("jax_enable_x64", False)


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running tests (subprocess dry-runs etc.)")
    config.addinivalue_line(
        "markers", "backends: EngineBackend protocol, backend parity, and "
                   "serving A/B tests (pytest -m backends)")
    config.addinivalue_line(
        "markers", "sharded: mesh-sharded serving tests; in-process variants "
                   "need >= 8 devices (CI runs the suite under XLA_FLAGS="
                   "--xla_force_host_platform_device_count=8), subprocess "
                   "variants set the flag themselves")


@pytest.fixture
def key():
    return jax.random.PRNGKey(0)


def small_wgkv(**kw):
    from repro.configs.base import WGKVConfig

    defaults = dict(enabled=True, w_local=16, tau=0.1, gate_hidden=32,
                    global_budget_frac=1.0, sink=4)
    defaults.update(kw)
    return WGKVConfig(**defaults)


def make_cfg(arch: str = "qwen3-0.6b", **wgkv_kw):
    """Reduced fp32 config with a small WG-KV window for fast CPU tests."""
    from repro.configs import get_reduced_config

    cfg = get_reduced_config(arch).replace(dtype="float32")
    if cfg.wgkv.enabled:
        cfg = cfg.replace(wgkv=small_wgkv(**wgkv_kw))
    return cfg.replace(sliding_window=min(cfg.sliding_window, 32))
