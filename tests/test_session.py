"""ServeSession client surface + async dispatch/collect driver.

Covers the acceptance criteria of the async serving API redesign:

  * greedy-token parity: the dispatch-ahead driver (dispatch_ahead >= 1)
    emits byte-identical streams to the synchronous baseline on one
    arrival trace;
  * mid-stream cancellation during chunked prefill AND during decode:
    the slot is freed, paged-pool pages are reclaimed, and surviving
    requests' greedy tokens are bit-identical to an uncancelled run;
  * typed backpressure (QueueFull) and boundary validation
    (InvalidRequest) surface through ServeSession.submit;
  * per-request deadlines cancel overdue requests mid-stream;
  * sync and async iteration off the handle.
"""
import asyncio

import jax
import pytest

from conftest import make_cfg
from repro.models import transformer as T
from repro.serving.backend import make_backend
from repro.serving.orchestrator import (InvalidRequest, QueueFull,
                                        SchedulerConfig, ServeSession)

pytestmark = pytest.mark.backends


@pytest.fixture(scope="module")
def served():
    cfg = make_cfg("qwen3-0.6b", global_budget_frac=0.5)
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


PROMPTS = [list(range(10 + i, 58 + i)) for i in range(3)]


def _session(params, cfg, *, dispatch_ahead=1, mirror=False, slots=2,
             **kw):
    eng = make_backend("wgkv", params, cfg, slots=slots, capacity=128,
                       mirror_paged=mirror)
    return ServeSession(eng, sched=SchedulerConfig(
        chunk_tokens=16, dispatch_ahead=dispatch_ahead), **kw)


def _serve_all(sess, prompts=PROMPTS, max_new=5):
    hs = [sess.submit(p, max_new=max_new) for p in prompts]
    sess.run()
    sess.close()
    return [h.tokens() for h in hs]


# ==========================================================================
# async driver parity: dispatch-ahead == synchronous baseline, bytewise
# ==========================================================================
def test_async_driver_matches_sync(served):
    cfg, params = served
    want = _serve_all(_session(params, cfg, dispatch_ahead=0))
    for depth in (1, 2):
        got = _serve_all(_session(params, cfg, dispatch_ahead=depth))
        assert got == want, f"dispatch_ahead={depth} diverged"


def test_async_driver_parity_with_mirror(served):
    """Paged-pool mirroring runs at collect time (overlapped with the
    next in-flight step) — it must not change tokens, and every page
    must be reclaimed once the trace drains."""
    cfg, params = served
    want = _serve_all(_session(params, cfg, dispatch_ahead=0))
    sess = _session(params, cfg, dispatch_ahead=1, mirror=True)
    eng = sess.engine
    got = _serve_all(sess)
    assert got == want
    assert eng.pool.pages_in_use == 0
    assert not eng.pool.tables


# ==========================================================================
# mid-stream cancellation (satellite): prefill stage and decode stage
# ==========================================================================
def _run_with_victim(params, cfg, cancel_stage=None, *, min_tokens=2):
    """Serve two survivors + one victim; optionally cancel the victim
    once it reaches ``cancel_stage``. Returns (survivor streams, victim
    handle, engine)."""
    sess = _session(params, cfg, dispatch_ahead=1, mirror=True)
    eng = sess.engine
    survivors = [sess.submit(p, max_new=6) for p in PROMPTS[:2]]
    victim = sess.submit(list(range(30, 78)), max_new=6)
    if cancel_stage is not None:
        for _ in range(10_000):
            if victim.state == cancel_stage:
                break
            sess.tick()
        assert victim.state == cancel_stage
        if cancel_stage == "decode":
            while len(victim.tokens()) < min_tokens:
                sess.tick()
        assert victim.cancel()
        assert victim.cancelled
        assert not victim.cancel()          # idempotent: already terminal
    sess.run()
    sess.close()
    return [h.tokens() for h in survivors], victim, eng


def test_cancel_during_prefill(served):
    cfg, params = served
    base, full_victim, _ = _run_with_victim(params, cfg, None)
    got, victim, eng = _run_with_victim(params, cfg, "prefill")
    assert victim.cancelled and victim.tokens() == []
    # survivors are bit-identical to the uncancelled run
    assert got == base
    # the reserved slot was released and reused or left free; nothing
    # lingers in the pool once the trace drains
    assert not any(eng.live)
    assert eng.pool.pages_in_use == 0


def test_cancel_during_decode(served):
    """Cancel mid-stream with a step in flight: the slot frees, its pool
    pages return to the allocator immediately, the partial stream closes
    as cancelled, and survivors are bit-identical."""
    cfg, params = served
    base, full_victim, _ = _run_with_victim(params, cfg, None)
    got, victim, eng = _run_with_victim(params, cfg, "decode")
    assert victim.cancelled
    toks = victim.tokens()
    assert 2 <= len(toks) < 6                      # partial stream
    assert toks == full_victim.tokens()[:len(toks)]  # prefix of full run
    assert got == base
    assert not any(eng.live)
    assert eng.pool.pages_in_use == 0
    assert not eng.pool.tables                     # streams freed NOW


def test_cancel_frees_pool_pages_immediately(served):
    """Pool pages of a cancelled mid-decode request return to the
    allocator at cancel time, not when the trace drains."""
    cfg, params = served
    sess = _session(params, cfg, dispatch_ahead=1, mirror=True, slots=2)
    eng = sess.engine
    victim = sess.submit(list(range(30, 78)), max_new=32)
    for _ in range(10_000):
        if victim.state == "decode" and len(victim.tokens()) >= 2:
            break
        sess.tick()
    assert eng.pool.pages_in_use > 0
    assert victim.cancel()
    assert eng.pool.pages_in_use == 0              # reclaimed on the spot
    sess.run()
    sess.close()


# ==========================================================================
# typed backpressure + validation through the session
# ==========================================================================
def test_session_backpressure_and_validation(served):
    cfg, params = served
    sess = _session(params, cfg, max_pending=1)
    with pytest.raises(InvalidRequest):
        sess.submit([], max_new=4)
    with pytest.raises(InvalidRequest):
        sess.submit([1, 2], max_new=0)
    h = sess.submit(PROMPTS[0], max_new=2)  # fills the pending queue
    with pytest.raises(QueueFull) as ei:
        sess.submit(PROMPTS[1], max_new=2)
    assert ei.value.max_pending == 1 and ei.value.depth == 1
    sess.tick()                             # admission drains the queue
    h2 = sess.submit(PROMPTS[1], max_new=2)  # room again: accepted
    sess.run()
    sess.close()
    assert h.done and len(h.tokens()) == 2
    assert h2.done and len(h2.tokens()) == 2
    assert sess.telemetry.counters["rejected"] == 1


# ==========================================================================
# deadlines: overdue requests cancel mid-stream
# ==========================================================================
def test_deadline_cancels_mid_stream(served):
    cfg, params = served
    fake = {"t": 0.0}
    eng = make_backend("wgkv", params, cfg, slots=1, capacity=128,
                       mirror_paged=False)
    sess = ServeSession(eng, sched=SchedulerConfig(chunk_tokens=16,
                                                   dispatch_ahead=1),
                        clock=lambda: fake["t"])
    h = sess.submit(PROMPTS[0], max_new=64, deadline_s=5.0)
    ok = sess.submit(PROMPTS[1], max_new=4)  # no deadline: must finish
    for _ in range(200):
        fake["t"] += 0.1                     # 0.1 "s" per tick
        sess.tick()
        if h.cancelled and ok.done:
            break
    assert h.cancelled                      # deadline hit mid-stream
    assert 0 < len(h.tokens()) < 64
    assert ok.done
    assert sess.telemetry.counters["deadline_expired"] == 1
    sess.run()
    sess.close()


# ==========================================================================
# streaming: sync iterator and asyncio adapter drive the loop themselves
# ==========================================================================
def test_handle_iterators(served):
    cfg, params = served
    want = _serve_all(_session(params, cfg, dispatch_ahead=1))

    # sync: interleaved iteration over two handles
    sess = _session(params, cfg, dispatch_ahead=1)
    hs = [sess.submit(p, max_new=5) for p in PROMPTS]
    assert [list(h) for h in hs] == want
    sess.close()

    # async: concurrent astream consumers on one event loop
    sess = _session(params, cfg, dispatch_ahead=1)
    hs = [sess.submit(p, max_new=5) for p in PROMPTS]

    async def consume(h):
        return [t async for t in h.astream()]

    async def main():
        return await asyncio.gather(*(consume(h) for h in hs))

    assert asyncio.run(main()) == want
    sess.close()
