"""Continuous-batching orchestrator: queue backpressure, chunked-prefill
equivalence, streaming parity with the legacy engine loop, paged-pool
reclamation, and telemetry."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_cfg
from repro.models import inference as I
from repro.models import transformer as T
from repro.serving.engine import Engine
from repro.serving.orchestrator import (InvalidRequest, Orchestrator,
                                        QueueFull, RequestQueue, Scheduler,
                                        SchedulerConfig)


@pytest.fixture(scope="module")
def served():
    cfg = make_cfg("qwen3-0.6b", global_budget_frac=0.5)
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


# ==========================================================================
# queue: arrival ordering + backpressure
# ==========================================================================
def test_queue_fifo_and_backpressure():
    q = RequestQueue(max_pending=2)
    r0 = q.submit([1, 2], max_new=4)
    r1 = q.submit([3, 4], max_new=4)
    with pytest.raises(QueueFull):
        q.submit([5, 6], max_new=4)
    assert q.rejected == 1
    assert [q.pop().rid, q.pop().rid] == [r0, r1]  # arrival order
    assert q.pop() is None
    r2 = q.submit([7], max_new=1)  # drained -> accepts again
    assert q.pop().rid == r2


def test_queue_validates_requests():
    """Malformed requests fail with a typed error at the queue boundary,
    not deep inside the backend's start_prefill."""
    q = RequestQueue(max_pending=4)
    with pytest.raises(InvalidRequest, match="non-empty"):
        q.submit([], max_new=4)
    with pytest.raises(InvalidRequest, match="max_new"):
        q.submit([1, 2], max_new=0)
    with pytest.raises(InvalidRequest, match="deadline"):
        q.submit([1, 2], max_new=4, deadline_s=0.0)
    assert len(q) == 0 and q.rejected == 0  # validation is not shed load


def test_queue_full_is_typed():
    """QueueFull carries the queue state so a frontend can back off."""
    q = RequestQueue(max_pending=1)
    q.submit([1], max_new=1)
    with pytest.raises(QueueFull) as ei:
        q.submit([2], max_new=1)
    assert ei.value.depth == 1 and ei.value.max_pending == 1


def test_scheduler_plan_respects_limits():
    s = Scheduler(SchedulerConfig(chunk_tokens=16, max_prefill_batch=1,
                                  decode_while_prefill=False))
    p = s.plan(free_slots=2, queue_depth=5, active_prefills=0, live_decodes=1)
    assert p.admit == 2 and p.advance_prefills == 1  # capped at the knob
    assert not p.decode  # decode_while_prefill=False and prefills pending
    p = s.plan(free_slots=0, queue_depth=5, active_prefills=0, live_decodes=2)
    assert p.admit == 0 and p.decode
    # default: every in-flight prefill advances every tick (one batched
    # ragged device call), bounded only by the slot count
    s = Scheduler(SchedulerConfig(chunk_tokens=16))
    p = s.plan(free_slots=2, queue_depth=5, active_prefills=3, live_decodes=0)
    assert p.advance_prefills == 5
    with pytest.raises(ValueError, match="max_prefill_batch"):
        SchedulerConfig(max_prefill_batch=0)


# ==========================================================================
# chunked prefill == one-shot prefill
# ==========================================================================
def test_chunked_prefill_matches_one_shot(served):
    """The serving scan (both engine drivers now open from the empty
    template and scan token-by-token) stays equivalent to the offline
    one-shot ``I.prefill`` — same admitted globals and ring state, logits
    allclose (different attention path, so float bits may differ)."""
    cfg, params = served
    prompt = list(range(20, 68))  # 48 = 3 x w_local(16): window-multiple
    eng = Engine(params, cfg, slots=1, capacity=128, mirror_paged=False)
    chunked = eng.prefill(prompt, chunk_tokens=16)
    budget = cfg.wgkv.global_budget(128)
    po, one_caches = I.prefill(params, cfg,
                               jnp.asarray(prompt, jnp.int32)[None],
                               budget=budget, max_len=128, opts=eng.opts)
    one_logits = po.logits
    assert np.allclose(np.asarray(one_logits),
                       np.asarray(chunked.first_logits), atol=1e-4)
    assert int(np.asarray(one_logits).argmax()) == chunked.first_token
    # cache state matches too (same admitted globals, same ring)
    for attr in ("gcnt", "t", "ptr"):
        assert np.array_equal(np.asarray(getattr(
            one_caches["blocks"]["b0"], attr)),
            np.asarray(getattr(chunked.caches["blocks"]["b0"], attr)))
    assert np.allclose(np.asarray(one_caches["blocks"]["b0"].lk),
                       np.asarray(chunked.caches["blocks"]["b0"].lk),
                       atol=1e-4)


def test_chunked_prefill_ragged_tail(served):
    """Non-window-multiple prompts: chunk size is invariant — the
    unchunked scan and the chunk-16 scan produce identical greedy
    rollouts."""
    cfg, params = served
    prompt = list(range(5, 60))  # 55 tokens: ragged
    eng = Engine(params, cfg, slots=1, capacity=128, mirror_paged=False)
    one = eng.prefill(prompt, chunk_tokens=None)
    chunked = eng.prefill(prompt, chunk_tokens=16)
    assert one.first_token == chunked.first_token


def test_splice_extract_roundtrip(served):
    """insert's splice and its inverse agree on every cache-tree leaf
    (batch axes resolved per-path: blocks vs obs vs batch-leading)."""
    from repro.launch.specs import (alloc_batched_caches, extract_slot_caches,
                                    splice_caches)
    cfg, params = served
    eng = Engine(params, cfg, slots=3, capacity=128, mirror_paged=False,
                 opts=I.DecodeOptions(evict_hard_budget=48, w_obs=16))
    prefix = eng.prefill(list(range(20, 68)), emit_first=False)
    batch = alloc_batched_caches(prefix.caches, 3)
    batch = splice_caches(batch, prefix.caches, 1)
    back = extract_slot_caches(batch, 1)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), prefix.caches, back)
    # untouched rows stay zero
    other = extract_slot_caches(batch, 0)
    assert float(jnp.abs(other["blocks"]["b0"].lk).max()) == 0.0


# ==========================================================================
# orchestrator streaming parity with the legacy engine loop
# ==========================================================================
def test_stream_matches_engine_run(served):
    cfg, params = served
    prompts = [list(range(10 + i, 58 + i)) for i in range(3)]
    ref = Engine(params, cfg, slots=2, capacity=128, mirror_paged=False)
    for p in prompts:
        ref.add_request(p, max_new=5)
    ref.run(max_steps=40)
    want = [ref.requests[r].out for r in range(len(prompts))]

    eng = Engine(params, cfg, slots=2, capacity=128, mirror_paged=False)
    orch = Orchestrator(eng, sched=SchedulerConfig(chunk_tokens=16))
    streamed = {}
    for p in prompts:
        rid = orch.submit(p, max_new=5,
                          on_token=lambda r, t, last:
                          streamed.setdefault(r, []).append(t))
    orch.run()
    for rid in range(len(prompts)):
        assert orch.tokens(rid) == want[rid]
        assert streamed[rid] == want[rid]
        assert orch.queue.requests[rid].state == "done"


def test_orchestrator_with_composition(served):
    """Quest read-time selection + SnapKV eviction stay composable under
    the orchestrator's chunked prefill + batched decode."""
    cfg, params = served
    opts = I.DecodeOptions(quest_pages=2, evict_hard_budget=48, w_obs=16)
    eng = Engine(params, cfg, slots=2, capacity=128, opts=opts,
                 mirror_paged=False)
    orch = Orchestrator(eng, sched=SchedulerConfig(chunk_tokens=16))
    for i in range(3):
        orch.submit(list(range(i, 80 + i)), max_new=6)
    orch.run()
    assert all(r.state == "done" for r in orch.queue.requests.values())
    assert all(len(r.out) == 6 for r in orch.queue.requests.values())


# ==========================================================================
# paged-pool reclamation (regression: no page leak across request churn)
# ==========================================================================
def test_pool_reclaimed_after_completion(served):
    cfg, params = served
    eng = Engine(params, cfg, slots=2, capacity=128, pool_pages=4096)
    orch = Orchestrator(eng, sched=SchedulerConfig(chunk_tokens=16))
    for i in range(4):  # more requests than slots -> slot churn
        orch.submit(list(range(10 + i, 58 + i)), max_new=4)
    saw_pages = 0
    for _ in range(200):
        if orch.queue.all_done():
            break
        orch.tick()
        saw_pages = max(saw_pages, eng.pool.pages_in_use)
        if any(eng.live):
            assert eng.verify_paged() < 2e-3
    assert orch.queue.all_done()
    assert saw_pages > 0                      # pool was actually exercised
    assert eng.pool.pages_in_use == 0         # every stream freed
    assert eng.pool.utilization() == 1.0      # back to baseline
    assert not eng.pool.tables                # no stale page tables


# ==========================================================================
# telemetry
# ==========================================================================
def test_telemetry_records(served):
    cfg, params = served
    eng = Engine(params, cfg, slots=2, capacity=128)
    orch = Orchestrator(eng, sched=SchedulerConfig(chunk_tokens=16),
                        max_pending=8)
    for i in range(3):
        orch.submit(list(range(i, 48 + i)), max_new=4)
    orch.run()
    s = orch.telemetry.summary()
    assert s["requests"] == 3
    assert s["requests_per_s"] > 0 and s["tokens_per_s"] > 0
    assert s["ttft_mean_s"] is not None and s["ttft_mean_s"] >= 0
    assert s["tpot_mean_s"] is not None and s["tpot_mean_s"] >= 0
    assert 0.0 <= s["mean_admission"] <= 1.0
    assert 0.0 <= s["mean_admission_decode"] <= 1.0
    assert s["counters"]["generated_tokens"] == 12
    assert s["counters"]["decode_steps"] > 0
    assert s["counters"]["prefill_chunks"] >= 3
    # batched advance: one device call covers many tasks' chunks, while
    # prefill_chunks keeps its one-per-task-per-tick meaning
    assert 0 < s["counters"]["prefill_batches"] <= s["counters"]["prefill_chunks"]
    assert s["prefill_chunks_per_request_mean"] >= 1.0
    assert s["pool_util_mean"] is not None
    rep = orch.telemetry.report()
    assert "TTFT" in rep and "admission" in rep


def test_backpressure_under_load(served):
    cfg, params = served
    eng = Engine(params, cfg, slots=1, capacity=128, mirror_paged=False)
    orch = Orchestrator(eng, sched=SchedulerConfig(chunk_tokens=16),
                        max_pending=2)
    orch.submit(list(range(48)), max_new=2)
    orch.submit(list(range(48)), max_new=2)
    with pytest.raises(QueueFull):
        orch.submit(list(range(48)), max_new=2)
    orch.run()
    assert orch.telemetry.summary()["counters"]["rejected"] == 1
    assert all(r.state == "done" for r in orch.queue.requests.values())
