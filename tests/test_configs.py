"""Assigned-architecture configs: exact numbers from the assignment table."""
import pytest

from repro.configs import (ARCH_NAMES, all_configs, get_config,
                           get_reduced_config, get_shape, shape_applicable)

EXPECTED = {
    # name: (layers, d_model, heads, kv, d_ff, vocab)
    "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
    "xlstm-350m": (24, 1024, 4, 4, 0, 50304),
    "phi3-medium-14b": (40, 5120, 40, 10, 17920, 100352),
    "qwen2-vl-7b": (28, 3584, 28, 4, 18944, 152064),
    "phi4-mini-3.8b": (32, 3072, 24, 8, 8192, 200064),
    "qwen3-0.6b": (28, 1024, 16, 8, 3072, 151936),
    "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936),
    "smollm-360m": (32, 960, 15, 5, 2560, 49152),
    "granite-moe-3b-a800m": (32, 1536, 24, 8, 512, 49155),
    "whisper-medium": (24, 1024, 16, 16, 4096, 51865),  # 24 dec (+24 enc)
}


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_exact_assignment_numbers(name):
    cfg = get_config(name)
    l, d, h, kv, ff, v = EXPECTED[name]
    assert cfg.n_repeats * len(cfg.block_pattern) + len(cfg.stem_pattern) == l
    assert cfg.d_model == d
    assert cfg.n_heads == h
    assert cfg.n_kv_heads == kv
    assert cfg.d_ff == ff
    assert cfg.vocab_size == v
    assert cfg.source  # every config cites its source


def test_moe_configs():
    q = get_config("qwen3-moe-235b-a22b")
    assert q.moe.n_experts == 128 and q.moe.top_k == 8
    g = get_config("granite-moe-3b-a800m")
    assert g.moe.n_experts == 40 and g.moe.top_k == 8


def test_param_scale_sanity():
    """Backbone param counts should land near the models' nameplates."""
    import math

    expect = {
        "recurrentgemma-9b": (7e9, 12e9),
        "xlstm-350m": (0.25e9, 0.6e9),
        "phi3-medium-14b": (12e9, 16e9),
        "qwen2-vl-7b": (6e9, 9e9),
        "phi4-mini-3.8b": (3e9, 5e9),
        "qwen3-0.6b": (0.4e9, 0.9e9),
        "qwen3-moe-235b-a22b": (2.1e11, 2.6e11),
        "smollm-360m": (0.28e9, 0.45e9),
        "granite-moe-3b-a800m": (2.2e9, 4e9),
        "whisper-medium": (0.5e9, 1.1e9),
    }
    for name, (lo, hi) in expect.items():
        n = get_config(name).param_count()
        assert lo <= n <= hi, f"{name}: {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]B"


def test_active_params_moe():
    cfg = get_config("qwen3-moe-235b-a22b")
    act = cfg.active_param_count()
    tot = cfg.param_count()
    assert act < tot * 0.25
    assert 1.5e10 <= act <= 3e10  # ~22B active


def test_reduced_configs_small():
    for name in ARCH_NAMES:
        r = get_reduced_config(name)
        assert r.d_model <= 512
        assert r.n_repeats * len(r.block_pattern) + len(r.stem_pattern) <= 4
        if r.moe:
            assert r.moe.n_experts <= 4


def test_shape_applicability():
    long = get_shape("long_500k")
    ok, _ = shape_applicable(get_config("whisper-medium"), long)
    assert not ok  # documented skip
    ok, _ = shape_applicable(get_config("xlstm-350m"), long)
    assert ok
    ok, _ = shape_applicable(get_config("phi3-medium-14b"), long)
    assert ok  # via WG-KV budgeted cache
    # full-attention arch with WG-KV disabled cannot run long_500k
    cfg = get_config("phi3-medium-14b")
    from repro.configs.base import WGKVConfig
    ok, _ = shape_applicable(cfg.replace(wgkv=WGKVConfig(enabled=False)), long)
    assert not ok


def test_gate_overhead_fraction():
    """Paper: gate params ~= 0.4% of total."""
    from repro.core.gate import gate_param_count

    for name in ("phi3-medium-14b", "qwen3-0.6b", "qwen2-vl-7b"):
        cfg = get_config(name)
        frac = gate_param_count(cfg) * cfg.n_layers / cfg.param_count()
        assert frac < 0.01, f"{name}: gate overhead {frac:.3%}"
