"""Logical-axis sharding rules with divisibility fallback (MaxText-style).

Mesh axes: ("data", "model") single pod, ("pod", "data", "model") multi-pod.
  * "model"        — tensor parallel: attention heads / d_ff / experts
  * "data" (+pod)  — batch parallel + FSDP-style weight sharding
Several assigned archs have head/expert counts not divisible by 16 (phi3
40H, smollm 15H, granite 40e); rather than padding, each tensor dim is
sharded only when divisible, falling back to the next preference (e.g.
row-parallel on d_model for attention projections) or replication. The
roofline §Perf pass quantifies what the fallback costs and hillclimbs it
(head padding) for the worst pair.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig


# ==========================================================================
# activation-sharding pinning (prevents depth-dependent SPMD propagation —
# without this, XLA picks different activation layouts at different layer
# counts and the L1/L2 roofline diff is meaningless)
# ==========================================================================
import contextlib
import threading

_ACT = threading.local()


@contextlib.contextmanager
def activation_sharding(batch_axes_or_none, *, expert_ax="__unset__"):
    """Enable residual-stream sharding constraints inside model code.
    ``batch_axes_or_none``: mesh axes for the batch dim (None = pinned
    replicated). ``expert_ax``: mesh axis for the MoE expert dim (None =
    replicated experts, e.g. granite's 40e). Used by launch bundles; tests
    run without the context (no-op)."""
    prev = getattr(_ACT, "axes", "off")
    prev_e = getattr(_ACT, "expert_ax", None)
    _ACT.axes = batch_axes_or_none
    if expert_ax != "__unset__":
        _ACT.expert_ax = expert_ax
    try:
        yield
    finally:
        _ACT.axes = prev
        _ACT.expert_ax = prev_e


def constrain_tokens(x):
    """Pin an activation whose leading dim is batch: [B, ...]."""
    axes = getattr(_ACT, "axes", "off")
    if axes == "off" or x is None:
        return x
    spec = P(axes, *(None,) * (x.ndim - 1))
    return jax.lax.with_sharding_constraint(x, spec)


def constrain_moe(x, kind: str):
    """Pin MoE internals (XLA otherwise replicates the group dim across
    data in the backward pass — §Perf iteration 1). kinds:
      dispatch — [G, E, C, D] -> P(batch_axes, expert_ax, None, None)
      grouped  — [G, T_g, ...] -> P(batch_axes, None, ...)
    """
    axes = getattr(_ACT, "axes", "off")
    if axes == "off" or x is None:
        return x
    if kind == "dispatch":
        e_ax = getattr(_ACT, "expert_ax", None)
        spec = P(axes, e_ax, *(None,) * (x.ndim - 2))
    else:
        spec = P(axes, *(None,) * (x.ndim - 1))
    return jax.lax.with_sharding_constraint(x, spec)


def fsdp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return fsdp_axes(mesh)


def _axsize(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _fits(dim: int, mesh: Mesh, axes) -> bool:
    return dim % _axsize(mesh, axes) == 0


def pick(dim: int, mesh: Mesh, *prefs):
    """First preference (an axis name, tuple of names, or None) that divides
    ``dim``; None (replicate) if none fit."""
    for p in prefs:
        if p is None:
            return None
        if _fits(dim, mesh, p):
            return p
    return None


# ==========================================================================
# parameter shardings (path-based; mirrors models/* param trees)
# ==========================================================================
def _param_spec(path: Tuple[str, ...], shape: Tuple[int, ...], mesh: Mesh,
                cfg: ModelConfig) -> P:
    fa = fsdp_axes(mesh)
    name = path[-1]
    # stacked super-block params carry a leading n_repeats axis
    stacked = "blocks" in path
    lead = (None,) if stacked else ()
    core = shape[1:] if stacked else shape

    def spec(*dims):
        return P(*(lead + tuple(dims)))

    if len(core) == 0:
        return spec()
    in_gate = "gate" in path
    if in_gate:
        # Write-Gate MLP: tiny (~0.4% params) — replicate
        return spec(*(None,) * len(core))
    if name in ("tok", "unembed"):
        v_or_d, d_or_v = core
        return spec(pick(v_or_d, mesh, fa, "data"), pick(d_or_v, mesh, "model"))
    if name in ("w_q", "w_k", "w_v"):
        din, dout = core
        # column-parallel over heads when the HEAD COUNT divides (not just
        # the flattened H*hd dim): a partial head per device would split
        # head_dim, putting a cross-device reduction inside every attention
        # score and leaving [B,H,S,hd] activations in tilings the cache
        # shardings (and, on CPU SPMD, XLA's resharding of concat operands
        # — see serving/sharded.py) cannot consume; else row-parallel
        heads = cfg.n_heads if name == "w_q" else cfg.n_kv_heads
        out_ax = "model" if (_fits(heads, mesh, "model")
                             and _fits(dout, mesh, "model")) else None
        in_ax = pick(din, mesh, fa, "data") if out_ax else pick(din, mesh, "model", fa)
        if out_ax and in_ax == out_ax:
            in_ax = None
        return spec(in_ax, out_ax)
    if name == "w_o":
        din, dout = core
        # contraction over heads: same whole-head constraint as w_q
        in_ax = "model" if (_fits(cfg.n_heads, mesh, "model")
                            and _fits(din, mesh, "model")) else None
        out_ax = pick(dout, mesh, fa, "data")
        return spec(in_ax, out_ax)
    if name in ("w_gate", "w_up", "w_down", "router") and "moe" in path:
        if name == "router":
            d, e = core
            return spec(pick(d, mesh, fa), pick(e, mesh, "model"))
        e, a, b = core
        e_ax = pick(e, mesh, "model")
        if e_ax:
            return spec(e_ax, pick(a, mesh, fa), None)
        # experts not divisible (granite 40e): shard the expert FFN width
        if name == "w_down":
            return spec(None, pick(a, mesh, "model"), pick(b, mesh, fa))
        return spec(None, pick(a, mesh, fa), pick(b, mesh, "model"))
    if name in ("w_gate", "w_up"):        # dense SwiGLU
        d, f = core
        return spec(pick(d, mesh, fa, "data"), pick(f, mesh, "model"))
    if name == "w_down":
        f, d = core
        return spec(pick(f, mesh, "model"), pick(d, mesh, fa, "data"))
    if name in ("w_in",):                  # gelu mlp / slstm input
        d, f = core
        return spec(pick(d, mesh, fa, "data"), pick(f, mesh, "model"))
    if name == "w_out" and len(core) == 2:
        f, d = core
        return spec(pick(f, mesh, "model"), pick(d, mesh, fa, "data"))
    if name in ("w_gelu", "w_x", "w_up_x", "w_up_z", "w_up1", "w_up2"):
        d, f = core
        return spec(pick(d, mesh, fa, "data"), pick(f, mesh, "model"))
    if name in ("conv",):
        cw, dr = core
        return spec(None, pick(dr, mesh, "model"))
    if name in ("w_r", "w_i") and len(core) == 3:  # rglru block-diag [H,dh,dh]
        h, dh, _ = core
        return spec(pick(h, mesh, "model"), None, None)
    if name == "r" and len(core) == 4:     # slstm recurrent [4,H,dh,dh]
        _, h, dh, _ = core
        return spec(None, pick(h, mesh, "model"), None, None)
    if len(core) == 2 and min(core) >= 512:
        a, b = core
        return spec(pick(a, mesh, fa, "data"), pick(b, mesh, "model"))
    if len(core) == 1 and core[0] >= 4096:
        return spec(pick(core[0], mesh, "model"))
    return spec(*(None,) * len(core))


def _path_keys(path) -> Tuple[str, ...]:
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "name"):
            out.append(str(k.name))
        else:
            out.append(str(getattr(k, "idx", k)))
    return tuple(out)


def param_shardings(params: Any, mesh: Mesh, cfg: ModelConfig, *,
                    replicate_fsdp: bool = False):
    """NamedSharding tree matching ``params``.

    ``replicate_fsdp``: drop the FSDP ("data"/"pod") axes from every param
    spec — weights replicated across data, sharded only over "model".
    For inference of models that fit HBM this removes the per-step
    weight all-gathers (decode §Perf iteration); training and big-MoE
    inference keep FSDP.
    """

    def strip(spec: P) -> P:
        def fix(ax):
            if ax is None:
                return None
            axes = (ax,) if isinstance(ax, str) else tuple(ax)
            kept = tuple(a for a in axes if a == "model")
            return kept[0] if len(kept) == 1 else (kept if kept else None)

        return P(*(fix(a) for a in spec))

    def walk(path, leaf):
        spec = _param_spec(_path_keys(path), tuple(leaf.shape), mesh, cfg)
        if replicate_fsdp:
            spec = strip(spec)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(walk, params)


# ==========================================================================
# activation / cache shardings
# ==========================================================================
def tokens_spec(mesh: Mesh, batch: int, extra_dims: int = 1) -> P:
    ba = pick(batch, mesh, batch_axes(mesh), "data")
    return P(ba, *(None,) * extra_dims)


def _cache_leaf_spec(path: Tuple[str, ...], shape, mesh: Mesh,
                     cfg: ModelConfig, seq_shard: bool) -> P:
    """Cache trees: DualCache/DenseCache/recurrent states, possibly stacked
    with a leading n_repeats axis. When ``seq_shard`` (long_500k, batch=1)
    the long token axis goes to "data" (context-parallel decode)."""
    fa = batch_axes(mesh)
    if "obs" in path:
        # eviction observation windows: [n_repeats, n_attn, B, ...] (q ring)
        # or [n_repeats, n_attn, B] (counter) — batch over data, query heads
        # over model when divisible, repeat/attn axes replicated
        core = tuple(shape[2:])
        if not core:
            return P(None, None)
        b_ax = pick(core[0], mesh, fa, "data")
        if len(core) >= 2:
            return P(None, None, b_ax, pick(core[1], mesh, "model"),
                     *(None,) * (len(core) - 2))
        return P(None, None, b_ax)
    stacked = "blocks" in path
    lead = (None,) if stacked else ()
    core = tuple(shape[1:]) if stacked else tuple(shape)

    def spec(*dims):
        return P(*(lead + tuple(dims)))

    if len(core) == 0:
        return spec()
    b = core[0]
    b_ax = pick(b, mesh, fa, "data")
    name = path[-1]
    if name in ("gk", "gv", "k", "v") and len(core) == 4:
        _, h, s, hd = core
        if b_ax is None and seq_shard:
            return spec(None, pick(h, mesh, "model"), pick(s, mesh, "data"), None)
        return spec(b_ax, pick(h, mesh, "model"), None, None)
    if name in ("pkmin", "pkmax") and len(core) == 4:
        # Quest page metadata [B, H, P, hd]: follows gk's batch/head layout;
        # the page axis stays unsharded even under seq_shard (P = C/16 pages
        # are consumed whole by the selection top-k)
        _, h, p_pages, hd = core
        return spec(b_ax, pick(h, mesh, "model"), None, None)
    if name in ("gpos",) and len(core) == 3:
        _, h, s = core
        if b_ax is None and seq_shard:
            return spec(None, pick(h, mesh, "model"), pick(s, mesh, "data"))
        return spec(b_ax, pick(h, mesh, "model"), None)
    if name in ("lk", "lv") and len(core) == 4:
        _, h, w, hd = core
        return spec(b_ax, pick(h, mesh, "model"), None, None)
    if name in ("lg",) and len(core) == 3:
        return spec(b_ax, pick(core[1], mesh, "model"), None)
    if name == "c" and len(core) == 4:  # mLSTM matrix memory [B,H,dh,dh]
        return spec(b_ax, pick(core[1], mesh, "model"), None, None)
    if name == "conv" and len(core) == 3:  # [B,cw-1,dr]
        return spec(b_ax, None, pick(core[2], mesh, "model"))
    if name == "h" and len(core) == 2:  # rglru state [B,dr]
        return spec(b_ax, pick(core[1], mesh, "model"))
    if len(core) >= 2:
        return spec(b_ax, *(None,) * (len(core) - 1))
    return spec(b_ax)


def cache_shardings(caches: Any, mesh: Mesh, cfg: ModelConfig, *,
                    seq_shard: bool = False):
    def walk(path, leaf):
        return NamedSharding(
            mesh,
            _cache_leaf_spec(_path_keys(path), tuple(leaf.shape), mesh, cfg,
                             seq_shard))

    return jax.tree_util.tree_map_with_path(walk, caches)
