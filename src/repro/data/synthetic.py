"""Synthetic data pipeline (offline container: no FineWeb/HELMET downloads).

Three generators mirroring the paper's data needs:
  * ``token_stream``    — zipfian web-like token stream (gate distillation,
    paper Appendix C trains on FineWeb-Edu samples).
  * ``needle_task``     — key-value retrieval in a long haystack (HELMET
    RAG/recall proxy for the Fig. 7 memory-accuracy trade-off): the model
    must emit the payload that followed the needle marker when queried at
    the end. Local-attention policies provably lose the needle once it
    leaves the window; learned admission must keep it.
  * ``copy_task``       — prompt echo after long generation (Fig. 10/16
    reasoning-trace proxy: early context needed late under memory bounds).
"""
from __future__ import annotations

from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

# reserved control tokens at the top of the vocab
def _specials(vocab: int):
    return {"needle": vocab - 1, "query": vocab - 2, "sep": vocab - 3}


def token_stream(key: jax.Array, batch: int, seq: int, vocab: int,
                 zipf_a: float = 1.3) -> jax.Array:
    """Zipf-distributed token ids in [0, vocab-8) (specials excluded)."""
    # inverse-CDF zipf via uniform samples (numpy for the harmonic weights)
    u = jax.random.uniform(key, (batch, seq))
    n = min(vocab - 8, 4096)
    w = 1.0 / np.arange(1, n + 1) ** zipf_a
    cdf = jnp.asarray(np.cumsum(w) / np.sum(w))
    ids = jnp.searchsorted(cdf, u)
    return ids.astype(jnp.int32)


def needle_task(key: jax.Array, batch: int, seq: int, vocab: int,
                payload: int = 4, needle_frac_lo: float = 0.05,
                needle_frac_hi: float = 0.55, occurrences: int = 3
                ) -> Dict[str, jax.Array]:
    """tokens = [hay .. M p1..pk .. hay .. M p1..pk .. hay .. M p1..pk]
    (same marker M each time — canonical induction): the payload appears
    ``occurrences`` times in the first ``needle_frac_hi`` of the sequence
    (always far outside the local window of the final query), then the
    model must reproduce p1..pk after the final M at the tail. Trained
    causally. Returns tokens [B, S], loss_mask [B, S] (1 on the answer
    span), answer [B, payload]."""
    sp = _specials(vocab)
    k1, k2, k3 = jax.random.split(key, 3)
    hay = token_stream(k1, batch, seq, vocab)
    pay = jax.random.randint(k2, (batch, payload), 0, vocab - 8)
    lo = int(seq * needle_frac_lo)
    hi = int(seq * needle_frac_hi)
    span = max((hi - lo) // max(occurrences, 1), payload + 2)
    offs = jax.random.randint(k3, (batch, occurrences), 0,
                              max(span - payload - 1, 1))
    npos = lo + jnp.arange(occurrences)[None] * span + offs  # [B, O]
    qpos = seq - payload - 1
    idx = jnp.arange(seq)[None]
    toks = hay
    bidx = jnp.arange(batch)[:, None]
    for o in range(occurrences):
        off = idx - npos[:, o][:, None]
        toks = jnp.where(off == 0, sp["needle"], toks)
        in_pay = (off >= 1) & (off <= payload)
        pay_val = pay[bidx, jnp.clip(off - 1, 0, payload - 1)]
        toks = jnp.where(in_pay, pay_val, toks)
    # query (same marker) + answer span at the tail
    toks = jnp.where(idx == qpos, sp["needle"], toks)
    ans_off = idx - qpos - 1
    in_ans = (ans_off >= 0) & (ans_off < payload)
    ans_val = pay[bidx, jnp.clip(ans_off, 0, payload - 1)]
    toks = jnp.where(in_ans, ans_val, toks)
    loss_mask = jnp.broadcast_to(in_ans, toks.shape).astype(jnp.float32)
    return {"tokens": toks.astype(jnp.int32), "loss_mask": loss_mask,
            "answer": pay, "needle_pos": npos[:, 0], "query_pos": qpos}


def copy_task(key: jax.Array, batch: int, prompt: int, filler: int,
              vocab: int) -> Dict[str, jax.Array]:
    """[prompt tokens][SEP][filler][QUERY] -> model must echo the prompt."""
    sp = _specials(vocab)
    k1, k2 = jax.random.split(key)
    p = jax.random.randint(k1, (batch, prompt), 0, vocab - 8)
    f = token_stream(k2, batch, filler, vocab)
    toks = jnp.concatenate([
        p,
        jnp.full((batch, 1), sp["sep"], jnp.int32),
        f,
        jnp.full((batch, 1), sp["query"], jnp.int32),
    ], axis=1)
    return {"tokens": toks.astype(jnp.int32), "prompt": p}


class DistillStream:
    """Iterator of gate-distillation batches (paper Appendix C setup, with
    the generic instruction prefix replaced by a fixed SEP prefix)."""

    def __init__(self, seed: int, batch: int, seq: int, vocab: int,
                 task_mix: float = 0.5):
        self.key = jax.random.PRNGKey(seed)
        self.batch, self.seq, self.vocab = batch, seq, vocab
        self.task_mix = task_mix
        self._i = 0

    def __iter__(self) -> Iterator[Dict[str, jax.Array]]:
        return self

    def __next__(self) -> Dict[str, jax.Array]:
        self.key, k1, k2 = jax.random.split(self.key, 3)
        self._i += 1
        if self._i % max(int(1 / max(self.task_mix, 1e-6)), 1) == 0:
            b = needle_task(k1, self.batch, self.seq, self.vocab)
            return {"tokens": b["tokens"], "loss_mask": None}
        return {"tokens": token_stream(k1, self.batch, self.seq, self.vocab),
                "loss_mask": None}


def lm_loss(logits: jax.Array, tokens: jax.Array,
            loss_mask: Optional[jax.Array] = None) -> jax.Array:
    """Next-token cross entropy (teacher pre-training for benchmarks)."""
    lp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32), -1)
    tgt = tokens[:, 1:]
    nll = -jnp.take_along_axis(lp, tgt[..., None], -1)[..., 0]
    if loss_mask is not None:
        m = loss_mask[:, 1:]
        return (nll * m).sum() / jnp.maximum(m.sum(), 1.0)
    return nll.mean()
