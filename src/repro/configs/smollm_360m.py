"""smollm-360m [dense] — llama-arch small. [hf:HuggingFaceTB/SmolLM-135M card]"""
from repro.configs.base import ModelConfig, WGKVConfig

CONFIG = ModelConfig(
    name="smollm-360m",
    arch_type="dense",
    d_model=960,
    n_heads=15,
    n_kv_heads=5,
    head_dim=64,
    d_ff=2560,
    vocab_size=49_152,
    block_pattern=("attn",),
    n_repeats=32,
    rope_theta=10000.0,
    tie_embeddings=True,
    source="hf:HuggingFaceTB/SmolLM-135M",
    wgkv=WGKVConfig(enabled=True),
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        d_model=240, n_heads=3, n_kv_heads=1, head_dim=80, d_ff=512,
        vocab_size=512, n_repeats=2,
    )
