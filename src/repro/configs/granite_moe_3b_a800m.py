"""granite-moe-3b-a800m [moe] — 40 experts, top-8, per-expert d_ff=512.

[hf:ibm-granite/granite-3.0-1b-a400m-base family card]
"""
from repro.configs.base import MoEConfig, ModelConfig, WGKVConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    arch_type="moe",
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,  # per-expert
    vocab_size=49_155,
    block_pattern=("attn_moe",),
    n_repeats=32,
    rope_theta=10000.0,
    tie_embeddings=True,
    moe=MoEConfig(n_experts=40, top_k=8, expert_d_ff=512),
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
    wgkv=WGKVConfig(enabled=True),
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        d_model=256, n_heads=4, n_kv_heads=2, head_dim=64, d_ff=128,
        vocab_size=512, n_repeats=2,
        moe=MoEConfig(n_experts=4, top_k=2, expert_d_ff=128),
    )
