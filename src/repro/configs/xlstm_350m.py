"""xlstm-350m [ssm] — alternating mLSTM / sLSTM blocks.

[arXiv:2405.04517]. 24 layers = 12 x (mLSTM, sLSTM). d_ff=0: xLSTM blocks
carry their own up/down projections (proj factor 2). No attention KV cache
=> WG-KV inapplicable (noted in DESIGN.md §4); the arch runs with its native
O(1) recurrent state.
"""
from repro.configs.base import ModelConfig, WGKVConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    arch_type="ssm",
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    head_dim=256,
    d_ff=0,
    vocab_size=50_304,
    block_pattern=("mlstm", "slstm"),
    n_repeats=12,
    xlstm_proj_factor=2.0,
    xlstm_conv_width=4,
    source="arXiv:2405.04517",
    wgkv=WGKVConfig(enabled=False),  # inapplicable: no KV cache
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        d_model=256,
        n_heads=2,
        n_kv_heads=2,
        head_dim=128,
        vocab_size=512,
        n_repeats=1,
    )
