"""whisper-medium [audio] — encoder-decoder, conv frontend STUB.

[arXiv:2212.04356]. 24 encoder + 24 decoder layers, MHA (kv=16=H),
sinusoidal positions. The mel-spectrogram + conv feature extractor is a
stub: ``input_specs()`` provides precomputed frame embeddings of shape
[B, seq_len // 2, d_model] (the conv stack's 2x temporal downsample).
Decoder blocks are self-attn + cross-attn + FFN; WG-KV applies to decoder
self-attention (and optionally to cross-attn KV as learned encoder-memory
pruning). ``long_500k`` is skipped for this arch (DESIGN.md §4).
"""
from repro.configs.base import ModelConfig, WGKVConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    arch_type="audio",
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=51_865,
    block_pattern=("attn_cross",),
    n_repeats=24,
    enc_block_pattern=("enc_attn",),
    n_enc_repeats=24,
    enc_seq_divisor=2,
    dec_max_len=448,
    rope_theta=0.0,  # sinusoidal absolute positions, no RoPE
    tie_embeddings=True,
    source="arXiv:2212.04356",
    # w_local=64 divides the 448-token decoder prompt (whisper's max)
    wgkv=WGKVConfig(enabled=True, w_local=64),
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        d_model=256, n_heads=4, n_kv_heads=4, head_dim=64, d_ff=512,
        vocab_size=512, n_repeats=2, n_enc_repeats=2, dec_max_len=64,
    )
