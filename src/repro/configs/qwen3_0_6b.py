"""qwen3-0.6b [dense] — qk_norm, GQA. [hf:Qwen/Qwen3-8B family card]

Qwen3 uses per-head RMSNorm on q and k (qk_norm) and 128-dim heads
decoupled from d_model (16 * 128 = 2048 != 1024).
"""
from repro.configs.base import ModelConfig, WGKVConfig

CONFIG = ModelConfig(
    name="qwen3-0.6b",
    arch_type="dense",
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=3072,
    vocab_size=151_936,
    block_pattern=("attn",),
    n_repeats=28,
    qk_norm=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    source="hf:Qwen/Qwen3-8B",
    wgkv=WGKVConfig(enabled=True),
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        d_model=256, n_heads=4, n_kv_heads=2, head_dim=64, d_ff=512,
        vocab_size=512, n_repeats=2,
    )
