"""Config dataclasses for the WG-KV framework.

Every assigned architecture gets a module in this package exporting
``CONFIG`` (the full production config, exact numbers from the assignment
table) and ``reduced()`` (a CPU-smoke-testable variant of the same family:
<=2 pattern super-blocks, d_model<=512, <=4 experts).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class WGKVConfig:
    """Write-Gated KV (the paper's technique) hyper-parameters."""

    enabled: bool = True
    # Sliding local window (ring buffer size); paper uses 256 for training
    # alignment and the local cache.
    w_local: int = 256
    # Binarization threshold tau (paper: 0.1).
    tau: float = 0.1
    # Hidden width of the Write-Gate MLP.
    gate_hidden: int = 64
    # Global-cache capacity as a fraction of max sequence length. The paper
    # reports 46-68% memory reduction at 75% sparsity; a 0.25 budget is the
    # matching operating point.
    global_budget_frac: float = 0.25
    # epsilon used inside log(m + eps) for the log-space bias.
    log_eps: float = 1e-6
    # sparsity-loss weight (lambda); swept by benchmarks.
    lam: float = 0.08
    # number of attention-sink tokens always admitted (StreamingLLM-style;
    # used by baselines and as a safety floor for WG-KV).
    sink: int = 16

    def global_budget(self, seq_len: int) -> int:
        b = int(seq_len * self.global_budget_frac)
        return max(16, min(b, seq_len))


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0
    # shared dense ffn alongside experts (0 = none)
    shared_d_ff: int = 0
    # capacity factor for fixed-shape dispatch
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01


# block types that carry a decoder-side KV cache
ATTN_BLOCKS = ("attn", "attn_moe", "local_attn", "attn_cross")


@dataclass(frozen=True)
class ModelConfig:
    """Architecture description.

    ``block_pattern`` lists the block types of one *pattern super-block*;
    the model is ``n_repeats`` copies of that pattern (scan-over-superblocks)
    plus optional non-repeated stem/head. Block types:
      "attn"   — GQA self-attention + dense FFN (SwiGLU)
      "attn_moe" — GQA self-attention + MoE FFN
      "local_attn" — sliding-window GQA attention + dense FFN
      "rglru"  — Griffin recurrent block (temporal conv + RG-LRU) + FFN
      "mlstm"  — xLSTM matrix-memory block (self-contained projections)
      "slstm"  — xLSTM scalar-memory block (self-contained projections)
    """

    name: str
    arch_type: str  # dense | moe | ssm | hybrid | vlm | audio
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    block_pattern: Tuple[str, ...]
    n_repeats: int
    # extra non-repeated blocks placed before the scanned repeats (used to
    # hit exact layer counts when n_layers % len(pattern) != 0, e.g.
    # recurrentgemma's 38 = 2 + 12*3).
    stem_pattern: Tuple[str, ...] = ()
    head_dim: int = 0  # 0 => d_model // n_heads
    source: str = ""  # citation from the assignment table

    # positional / attention details
    rope_theta: float = 10000.0
    qk_norm: bool = False
    mrope: bool = False  # Qwen2-VL multimodal 3D RoPE
    sliding_window: int = 2048  # for "local_attn" blocks
    tie_embeddings: bool = True

    # MoE
    moe: Optional[MoEConfig] = None

    # encoder-decoder (whisper): encoder layer stack
    n_enc_repeats: int = 0
    enc_block_pattern: Tuple[str, ...] = ()
    enc_seq_divisor: int = 2  # conv frontend downsampling factor (stub)
    dec_max_len: int = 448  # whisper decoder max length (training shapes)

    # rglru
    rglru_conv_width: int = 4
    rglru_expand: float = 1.0  # recurrence width = expand * d_model

    # xlstm
    xlstm_proj_factor: float = 2.0  # mLSTM up-projection factor
    xlstm_conv_width: int = 4

    # WG-KV
    wgkv: WGKVConfig = field(default_factory=WGKVConfig)

    # numerics
    dtype: str = "bfloat16"
    param_dtype: str = "float32"

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    # ---- derived ------------------------------------------------------
    @property
    def n_layers(self) -> int:
        return self.n_repeats * len(self.block_pattern) + len(self.stem_pattern)

    @property
    def n_enc_layers(self) -> int:
        return self.n_enc_repeats * len(self.enc_block_pattern)

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def is_encdec(self) -> bool:
        return self.n_enc_repeats > 0

    @property
    def has_attention_cache(self) -> bool:
        """Does any decoder block keep a KV cache (i.e. is WG-KV applicable)?"""
        return any(
            b in ATTN_BLOCKS for b in self.block_pattern + self.stem_pattern
        )

    @property
    def attn_blocks_per_pattern(self) -> int:
        return sum(1 for b in self.block_pattern if b in ATTN_BLOCKS)

    def wgkv_applicable(self) -> bool:
        return self.has_attention_cache

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ---- parameter counting (exact, mirrors models/*.py) ---------------
    def param_count(self) -> int:
        """Exact backbone parameter count (no gate)."""
        from repro.models.registry import count_params_analytic

        return count_params_analytic(self)

    def active_param_count(self) -> int:
        from repro.models.registry import count_params_analytic

        return count_params_analytic(self, active_only=True)


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"
