"""phi4-mini-3.8b [dense] — RoPE SwiGLU GQA. [arXiv:2412.08905]"""
from repro.configs.base import ModelConfig, WGKVConfig

CONFIG = ModelConfig(
    name="phi4-mini-3.8b",
    arch_type="dense",
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=200_064,
    block_pattern=("attn",),
    n_repeats=32,
    rope_theta=10000.0,
    tie_embeddings=True,
    source="arXiv:2412.08905",
    wgkv=WGKVConfig(enabled=True),
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        d_model=256, n_heads=4, n_kv_heads=2, head_dim=64, d_ff=512,
        vocab_size=512, n_repeats=2,
    )
