"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, 1 attn : 2 recurrent.

[arXiv:2402.19427] (Griffin / RecurrentGemma). 38 layers = 2 RG-LRU stem +
12 x (RG-LRU, RG-LRU, local-attn). MQA (kv=1) on the attention layers,
sliding window 2048. WG-KV applies to the local-attn layers, giving them a
budgeted learned global cache (the RG-LRU layers carry recurrent state and
need no KV cache).
"""
from repro.configs.base import ModelConfig, WGKVConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    arch_type="hybrid",
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,  # RecurrentGemma-9B uses 256-dim heads (16*256=4096)
    d_ff=12288,
    vocab_size=256_000,
    block_pattern=("rglru", "rglru", "local_attn"),
    n_repeats=12,
    stem_pattern=("rglru", "rglru"),
    sliding_window=2048,
    rope_theta=10000.0,
    rglru_conv_width=4,
    rglru_expand=1.0,
    source="arXiv:2402.19427",
    wgkv=WGKVConfig(enabled=True, w_local=256, gate_hidden=64),
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        d_model=256,
        n_heads=4,
        n_kv_heads=1,
        head_dim=64,
        d_ff=512,
        vocab_size=512,
        n_repeats=1,
        stem_pattern=(),
        sliding_window=64,
        wgkv=CONFIG.wgkv,
    )
