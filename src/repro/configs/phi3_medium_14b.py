"""phi3-medium-14b [dense] — RoPE SwiGLU GQA. [arXiv:2404.14219]"""
from repro.configs.base import ModelConfig, WGKVConfig

CONFIG = ModelConfig(
    name="phi3-medium-14b",
    arch_type="dense",
    d_model=5120,
    n_heads=40,
    n_kv_heads=10,
    head_dim=128,
    d_ff=17920,
    vocab_size=100_352,
    block_pattern=("attn",),
    n_repeats=40,
    rope_theta=10000.0,
    tie_embeddings=False,
    source="arXiv:2404.14219",
    wgkv=WGKVConfig(enabled=True),
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        d_model=256, n_heads=4, n_kv_heads=2, head_dim=64, d_ff=512,
        vocab_size=512, n_repeats=2,
    )
