"""Assigned-architecture registry: ``--arch <id>`` selects one of these."""
from __future__ import annotations

import importlib
from typing import Callable, Dict, Tuple

from repro.configs.base import InputShape, ModelConfig, MoEConfig, WGKVConfig
from repro.configs.shapes import SHAPES, get_shape

_ARCH_MODULES = {
    "recurrentgemma-9b": "repro.configs.recurrentgemma_9b",
    "xlstm-350m": "repro.configs.xlstm_350m",
    "phi3-medium-14b": "repro.configs.phi3_medium_14b",
    "qwen2-vl-7b": "repro.configs.qwen2_vl_7b",
    "phi4-mini-3.8b": "repro.configs.phi4_mini_3_8b",
    "qwen3-0.6b": "repro.configs.qwen3_0_6b",
    "qwen3-moe-235b-a22b": "repro.configs.qwen3_moe_235b_a22b",
    "smollm-360m": "repro.configs.smollm_360m",
    "granite-moe-3b-a800m": "repro.configs.granite_moe_3b_a800m",
    "whisper-medium": "repro.configs.whisper_medium",
}

ARCH_NAMES: Tuple[str, ...] = tuple(_ARCH_MODULES)


def get_config(name: str) -> ModelConfig:
    if name not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_ARCH_MODULES)}")
    return importlib.import_module(_ARCH_MODULES[name]).CONFIG


def get_reduced_config(name: str) -> ModelConfig:
    return importlib.import_module(_ARCH_MODULES[name]).reduced()


def all_configs() -> Dict[str, ModelConfig]:
    return {n: get_config(n) for n in ARCH_NAMES}


def shape_applicable(cfg: ModelConfig, shape: InputShape) -> Tuple[bool, str]:
    """Is (arch x shape) a runnable pair? Returns (ok, reason-if-not)."""
    if shape.name == "long_500k":
        if cfg.arch_type == "audio":
            return False, (
                "long_500k skipped for whisper-medium: 500k mel frames is far "
                "beyond the enc-dec design (DESIGN.md §4)"
            )
        if cfg.arch_type in ("ssm", "hybrid"):
            return True, ""  # native sub-quadratic state
        # attention archs: runnable only via the WG-KV budgeted cache
        if cfg.wgkv.enabled:
            return True, ""
        return False, "long_500k needs sub-quadratic attention (enable WG-KV)"
    return True, ""


__all__ = [
    "ARCH_NAMES",
    "InputShape",
    "ModelConfig",
    "MoEConfig",
    "SHAPES",
    "WGKVConfig",
    "all_configs",
    "get_config",
    "get_reduced_config",
    "get_shape",
    "shape_applicable",
]
