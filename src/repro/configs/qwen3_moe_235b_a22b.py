"""qwen3-moe-235b-a22b [moe] — 128 experts, top-8. [hf:Qwen/Qwen3-30B-A3B card]

d_ff=1536 is the per-expert FFN width; every layer is attn + MoE FFN.
"""
from repro.configs.base import MoEConfig, ModelConfig, WGKVConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    arch_type="moe",
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    head_dim=128,
    d_ff=1536,  # per-expert
    vocab_size=151_936,
    block_pattern=("attn_moe",),
    n_repeats=94,
    qk_norm=True,
    rope_theta=1_000_000.0,
    tie_embeddings=False,
    moe=MoEConfig(n_experts=128, top_k=8, expert_d_ff=1536),
    source="hf:Qwen/Qwen3-30B-A3B",
    wgkv=WGKVConfig(enabled=True),
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        d_model=256, n_heads=4, n_kv_heads=2, head_dim=64, d_ff=128,
        vocab_size=512, n_repeats=2,
        moe=MoEConfig(n_experts=4, top_k=2, expert_d_ff=128),
    )
