"""qwen2-vl-7b [vlm] — M-RoPE, dynamic resolution. [arXiv:2409.12191]

Transformer backbone only; the ViT vision encoder + projector is a STUB —
``input_specs()`` provides precomputed patch embeddings of shape
[B, n_img_tokens, d_model] that are scattered into the token stream, plus
3D (t, h, w) M-RoPE position ids.
"""
from repro.configs.base import ModelConfig, WGKVConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    arch_type="vlm",
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    head_dim=128,
    d_ff=18944,
    vocab_size=152_064,
    block_pattern=("attn",),
    n_repeats=28,
    mrope=True,
    rope_theta=1_000_000.0,
    tie_embeddings=False,
    source="arXiv:2409.12191",
    wgkv=WGKVConfig(enabled=True),
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        d_model=256, n_heads=4, n_kv_heads=2, head_dim=64, d_ff=512,
        vocab_size=512, n_repeats=2,
    )
