"""repro.analysis — repo-specific static analysis + runtime contract sentinels.

Static side (stdlib-only, runs without jax)::

    python -m repro.analysis.lint src --baseline analysis/baseline.json

Runtime side (needs jax; imported lazily so the hot-path modules can import
:func:`hot_path` without pulling jax back in through here)::

    from repro.analysis import CompileSentinel, SyncSentinel
"""

from __future__ import annotations

from .contracts import hot_path  # stdlib-only, safe at import time

__all__ = [
    "hot_path",
    "CompileSentinel",
    "SyncSentinel",
    "CompileBudgetExceeded",
    "SyncViolation",
    "Finding",
    "lint_paths",
]

_LAZY = {
    "CompileSentinel": "repro.analysis.sentinels",
    "SyncSentinel": "repro.analysis.sentinels",
    "CompileBudgetExceeded": "repro.analysis.sentinels",
    "SyncViolation": "repro.analysis.sentinels",
    "Finding": "repro.analysis.findings",
    "lint_paths": "repro.analysis.lint",
}


def __getattr__(name: str):
    mod = _LAZY.get(name)
    if mod is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib

    return getattr(importlib.import_module(mod), name)
