"""Runtime contract sentinels for the serving hot path.

Two executable counterparts to the static passes:

* :class:`CompileSentinel` — the PR 7/8 claim "exactly three compiled
  shapes per engine" as an assertion: jit-cache entry counts per fused-step
  kind must stay within ``Engine.COMPILE_SHAPE_BUDGETS``.
* :class:`SyncSentinel` — the PR 4/8 dispatch discipline as an assertion:
  while a fused step is in flight, ``jax.device_get`` may only run inside a
  sanctioned engine method (``collect`` above all); a naked host sync
  between dispatch and collect raises.

Unlike the rest of ``repro.analysis`` these need jax at runtime — import
them from test/serving code only.
"""

from __future__ import annotations

import functools
from typing import Dict, Iterable, Optional

import jax


class CompileBudgetExceeded(AssertionError):
    pass


class SyncViolation(AssertionError):
    pass


class CompileSentinel:
    """Assert an engine's jit-cache growth stays within its declared budget.

    Usage::

        with CompileSentinel(engine):
            ... full serve replay ...
        # raises CompileBudgetExceeded if any fused-step kind compiled more
        # shapes than Engine.COMPILE_SHAPE_BUDGETS declares

    Pass ``budgets`` to override the engine's declaration (e.g. tightening
    to the shapes one specific replay may legally touch).  ``check()`` can
    be called mid-run; ``__exit__`` always checks (except when unwinding an
    exception, which it never masks).
    """

    def __init__(self, engine, budgets: Optional[Dict[str, int]] = None):
        self.engine = engine
        self.budgets = dict(
            budgets
            if budgets is not None
            else getattr(engine, "COMPILE_SHAPE_BUDGETS", {})
        )
        if not self.budgets:
            raise ValueError(
                "no shape budgets: engine declares no COMPILE_SHAPE_BUDGETS "
                "and none were passed"
            )

    def counts(self) -> Dict[str, int]:
        return self.engine.compiled_shape_counts()

    def check(self) -> Dict[str, int]:
        counts = self.counts()
        over = {
            kind: (counts.get(kind, 0), budget)
            for kind, budget in self.budgets.items()
            if counts.get(kind, 0) > budget
        }
        if over:
            detail = ", ".join(
                f"{kind}: {got} compiled shapes > budget {budget}"
                for kind, (got, budget) in sorted(over.items())
            )
            raise CompileBudgetExceeded(
                f"jit cache exceeded declared shape budget ({detail}); "
                "every extra shape is a recompile stall in the serving tick "
                "— either the feed shapes regressed or the budget "
                "declaration (Engine.COMPILE_SHAPE_BUDGETS) must be updated "
                "with the jaxlint shapes(...) annotation"
            )
        return counts

    def __enter__(self) -> "CompileSentinel":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        if exc_type is None:
            self.check()
        return False


class SyncSentinel:
    """Assert no host sync escapes the two-phase dispatch/collect contract.

    Patches ``jax.device_get`` and wraps the engine's tick methods: after a
    ``step_batch`` dispatch returns an in-flight step, any ``device_get``
    raises :class:`SyncViolation` until the step is collected — unless it
    runs inside a sanctioned engine method (``collect`` is the designated
    sync point; ``insert``/``free_slot``/``memory_snapshot``/
    ``capture_prefix`` are host-side slot maintenance the dispatch-ahead
    window deliberately overlaps).
    A sync *inside* ``step_batch`` itself is always a violation: dispatch
    must never block on device results.
    """

    SANCTIONED: Iterable[str] = (
        "collect",
        "insert",
        "free_slot",
        "memory_snapshot",
        "capture_prefix",
    )

    def __init__(self, engine, sanctioned: Optional[Iterable[str]] = None):
        self.engine = engine
        self.sanctioned = tuple(
            sanctioned if sanctioned is not None else self.SANCTIONED
        )
        self.outstanding = 0
        self._depth = 0  # inside a sanctioned frame
        self.syncs_in_collect = 0
        self._orig_device_get = None
        self._wrapped: Dict[str, object] = {}

    # -- patching ----------------------------------------------------------

    def _guard_device_get(self, orig):
        @functools.wraps(orig)
        def device_get(x):
            if self._depth == 0 and self.outstanding > 0:
                raise SyncViolation(
                    "jax.device_get while a fused step is in flight and "
                    "outside any sanctioned engine method — collect() is "
                    "the tick's only sync point (PR 4/8 dispatch "
                    "discipline); hoist this host pull into collect or out "
                    "of the dispatch window"
                )
            if self._depth > 0:
                self.syncs_in_collect += 1
            return orig(x)

        return device_get

    def _wrap_step_batch(self, orig):
        @functools.wraps(orig)
        def step_batch(*args, **kwargs):
            # dispatch itself must be sync-free: outstanding>0 covers the
            # steady state, and even the first dispatch runs under the
            # guard via a provisional in-flight count
            self.outstanding += 1
            try:
                step = orig(*args, **kwargs)
            finally:
                self.outstanding -= 1
            if step is not None:
                self.outstanding += 1
            return step

        return step_batch

    def _wrap_collect(self, orig):
        @functools.wraps(orig)
        def collect(*args, **kwargs):
            self._depth += 1
            try:
                return orig(*args, **kwargs)
            finally:
                self._depth -= 1
                self.outstanding = max(0, self.outstanding - 1)

        return collect

    def _wrap_sanctioned(self, orig):
        @functools.wraps(orig)
        def method(*args, **kwargs):
            self._depth += 1
            try:
                return orig(*args, **kwargs)
            finally:
                self._depth -= 1

        return method

    def __enter__(self) -> "SyncSentinel":
        self._orig_device_get = jax.device_get
        jax.device_get = self._guard_device_get(self._orig_device_get)
        eng = self.engine
        self._wrapped["step_batch"] = eng.step_batch
        eng.step_batch = self._wrap_step_batch(eng.step_batch)
        for name in self.sanctioned:
            fn = getattr(eng, name, None)
            if fn is None:
                continue
            self._wrapped[name] = fn
            wrap = self._wrap_collect if name == "collect" \
                else self._wrap_sanctioned
            setattr(eng, name, wrap(fn))
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        jax.device_get = self._orig_device_get
        for name in self._wrapped:
            # instance attributes shadowed the bound methods; drop them
            try:
                delattr(self.engine, name)
            except AttributeError:
                setattr(self.engine, name, self._wrapped[name])
        self._wrapped.clear()
        return False
