"""jaxlint CLI: ``python -m repro.analysis.lint src --baseline analysis/baseline.json``.

Exit codes: 0 = clean (all findings accepted by the baseline), 1 = new
findings, 2 = bad arguments / unreadable baseline / syntax error in a
target file.  Stdlib-only — runs in a bare interpreter without jax.
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path
from typing import List, Optional

from .findings import Finding, load_baseline, write_baseline
from .passes import ALL_CODES, ModuleContext, run_passes


def iter_py_files(paths: List[str]) -> List[Path]:
    out: List[Path] = []
    for p in paths:
        path = Path(p)
        if path.is_dir():
            out.extend(sorted(path.rglob("*.py")))
        elif path.is_file():
            out.append(path)
        else:
            raise FileNotFoundError(p)
    return out


def lint_paths(
    paths: List[str], select: Optional[List[str]] = None
) -> List[Finding]:
    """Run the selected passes over every .py file under `paths`."""
    findings: List[Finding] = []
    for file in iter_py_files(paths):
        source = file.read_text()
        ctx = ModuleContext.parse(file.as_posix(), source)
        findings.extend(run_passes(ctx, select))
    findings.sort(key=lambda f: (f.path, f.line, f.code))
    return findings


def main(argv: Optional[List[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="Repo-specific static analysis for the serving hot path.",
    )
    parser.add_argument("paths", nargs="+", help="files or directories to lint")
    parser.add_argument(
        "--baseline",
        type=Path,
        default=None,
        help="accepted-findings JSON; matched findings don't fail the run",
    )
    parser.add_argument(
        "--select",
        default=None,
        help="comma-separated codes to run (default: all of %s)"
        % ",".join(ALL_CODES),
    )
    parser.add_argument(
        "--write-baseline",
        type=Path,
        default=None,
        metavar="FILE",
        help="write every current finding to FILE as the new baseline and "
        "exit 0",
    )
    parser.add_argument(
        "--reason",
        default="accepted at baseline creation",
        help="reason recorded for entries written by --write-baseline",
    )
    parser.add_argument(
        "--quiet", action="store_true", help="suppress accepted-findings note"
    )
    args = parser.parse_args(argv)

    select = None
    if args.select:
        select = [c.strip().upper() for c in args.select.split(",") if c.strip()]
        bad = [c for c in select if c not in ALL_CODES]
        if bad:
            print(f"error: unknown code(s) {bad}; known: {list(ALL_CODES)}",
                  file=sys.stderr)
            return 2

    try:
        findings = lint_paths(args.paths, select)
    except FileNotFoundError as e:
        print(f"error: no such path: {e}", file=sys.stderr)
        return 2
    except SyntaxError as e:
        print(f"error: {e.filename}:{e.lineno}: syntax error: {e.msg}",
              file=sys.stderr)
        return 2

    if args.write_baseline is not None:
        write_baseline(findings, args.write_baseline, reason=args.reason)
        print(f"wrote {len(findings)} finding(s) to {args.write_baseline}")
        return 0

    accepted: List[Finding] = []
    if args.baseline is not None:
        try:
            baseline = load_baseline(args.baseline)
        except (OSError, ValueError, KeyError) as e:
            print(f"error: cannot load baseline {args.baseline}: {e}",
                  file=sys.stderr)
            return 2
        new, accepted = baseline.split(findings)
    else:
        new = findings

    for f in new:
        print(f.render())
    if accepted and not args.quiet:
        print(f"note: {len(accepted)} finding(s) accepted by baseline")
    if new:
        print(f"{len(new)} new finding(s)")
        return 1
    print("clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
