"""Annotation grammar shared by the static passes and the runtime markers.

The serving stack's invariants are declared in source via ``# jaxlint:``
comments and the :func:`hot_path` decorator.  This module is pure stdlib —
it is imported by the hot-path modules themselves (for ``hot_path``) and by
the lint CLI, neither of which may pull in jax at import time.

Grammar (one directive per comment, attached to the physical line)::

    # jaxlint: hot-path                      scope marker on a ``def`` line
    # jaxlint: sharded-path                  scope marker on a ``def`` line
    # jaxlint: masked-scan-body              scope marker on a ``def`` line
    # jaxlint: allow-sync(reason)            suppress JL001 on this line
    # jaxlint: allow-concat(reason)          suppress JL002 on this line
    # jaxlint: allow-unmasked-write(reason)  suppress JL003 on this line
    # jaxlint: allow-tracer-branch(reason)   suppress JL004 on this line
    # jaxlint: allow-dead-import(reason)     suppress JL006 on this line
    # jaxlint: shapes(name=N, ...)           declare a jit shape budget (JL005)

``allow-*`` directives REQUIRE a non-empty reason; a reasonless suppression
is itself reported (JL000).  Scope markers may sit on the ``def`` line or on
the line directly above it.  Suppressions apply to the line carrying the
flagged expression's first token, or the line directly above it.
"""

from __future__ import annotations

import io
import re
import tokenize
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple, TypeVar

# Directive kinds -----------------------------------------------------------

SCOPE_MARKERS = frozenset({"hot-path", "sharded-path", "masked-scan-body"})
SUPPRESSIONS = frozenset(
    {
        "allow-sync",
        "allow-concat",
        "allow-unmasked-write",
        "allow-tracer-branch",
        "allow-dead-import",
    }
)
DECLARATIONS = frozenset({"shapes"})
KNOWN_DIRECTIVES = SCOPE_MARKERS | SUPPRESSIONS | DECLARATIONS

# Which suppression silences which pass.
SUPPRESSION_FOR_CODE = {
    "JL001": "allow-sync",
    "JL002": "allow-concat",
    "JL003": "allow-unmasked-write",
    "JL004": "allow-tracer-branch",
    "JL006": "allow-dead-import",
}

_DIRECTIVE_RE = re.compile(
    r"#\s*jaxlint:\s*(?P<name>[a-z][a-z0-9-]*)\s*(?:\((?P<arg>[^)]*)\))?"
)


@dataclass(frozen=True)
class Directive:
    """One parsed ``# jaxlint:`` comment."""

    name: str
    arg: Optional[str]  # text inside parens, stripped; None if absent
    line: int  # 1-based physical line carrying the comment

    @property
    def is_scope(self) -> bool:
        return self.name in SCOPE_MARKERS

    @property
    def is_suppression(self) -> bool:
        return self.name in SUPPRESSIONS


@dataclass
class AnnotationIndex:
    """All directives of one source file, indexed for the passes."""

    by_line: Dict[int, List[Directive]] = field(default_factory=dict)
    errors: List[Directive] = field(default_factory=list)  # malformed (JL000)

    def at(self, line: int) -> List[Directive]:
        return self.by_line.get(line, [])

    def suppressed(self, code: str, line: int) -> bool:
        """True if a valid suppression for `code` sits on `line` or `line-1`."""
        want = SUPPRESSION_FOR_CODE.get(code)
        if want is None:
            return False
        for ln in (line, line - 1):
            for d in self.at(ln):
                if d.name == want and d.arg:
                    return True
        return False

    def scope_marker(self, marker: str, def_line: int) -> bool:
        """True if a scope marker sits on the ``def`` line or the line above."""
        for ln in (def_line, def_line - 1):
            for d in self.at(ln):
                if d.name == marker:
                    return True
        return False

    def shapes_decl(self, line: int) -> Optional[Directive]:
        """A ``shapes(...)`` declaration on `line` or `line-1`, if any."""
        for ln in (line, line - 1):
            for d in self.at(ln):
                if d.name == "shapes":
                    return d
        return None


def parse_annotations(source: str) -> AnnotationIndex:
    """Extract every ``# jaxlint:`` directive from `source`.

    Malformed directives (unknown name, or an ``allow-*`` with a missing or
    empty reason) land in ``index.errors`` for the driver to report as JL000;
    they never suppress anything.
    """
    index = AnnotationIndex()
    for lineno, text in _comments(source):
        if "jaxlint" not in text:
            continue
        for m in _DIRECTIVE_RE.finditer(text):
            arg = m.group("arg")
            d = Directive(
                name=m.group("name"),
                arg=arg.strip() if arg is not None else None,
                line=lineno,
            )
            bad = d.name not in KNOWN_DIRECTIVES or (
                d.name in SUPPRESSIONS and not d.arg
            )
            if bad:
                index.errors.append(d)
            else:
                index.by_line.setdefault(lineno, []).append(d)
    return index


def _comments(source: str) -> List[Tuple[int, str]]:
    """(lineno, text) of every real comment token — directives inside string
    literals (docstrings quoting the grammar) must not parse as annotations.
    Falls back to whole lines if the file doesn't tokenize."""
    try:
        return [
            (tok.start[0], tok.string)
            for tok in tokenize.generate_tokens(io.StringIO(source).readline)
            if tok.type == tokenize.COMMENT
        ]
    except (tokenize.TokenError, IndentationError, SyntaxError):
        return list(enumerate(source.splitlines(), start=1))


def parse_shapes_decl(arg: Optional[str]) -> Optional[Dict[str, str]]:
    """Parse ``shapes(fused_step=2, call=per-structure)`` into a dict.

    Values are either decimal shape counts or symbolic tags (e.g.
    ``per-structure`` for calls keyed on input structure, ``per-batch-width``
    for the legacy per-width decode jits).  Returns None when malformed.
    """
    if not arg:
        return None
    out: Dict[str, str] = {}
    for part in arg.split(","):
        part = part.strip()
        if not part:
            continue
        if "=" not in part:
            return None
        key, _, val = part.partition("=")
        key, val = key.strip(), val.strip()
        if not key or not re.fullmatch(r"\d+|[a-z][a-z0-9-]*", val):
            return None
        out[key] = val
    return out or None


# Runtime marker ------------------------------------------------------------

_F = TypeVar("_F", bound=Callable)


def hot_path(fn: _F) -> _F:
    """Mark `fn` as serving-hot-path: JL001 forbids unannotated host syncs
    inside it, and the runtime sentinels treat it as tick-critical.

    Pure marker — zero call overhead, no wrapper frame.
    """
    fn.__jaxlint_hot_path__ = True  # type: ignore[attr-defined]
    return fn
