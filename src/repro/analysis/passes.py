"""The jaxlint static passes (JL000-JL006).

Each pass mechanizes an invariant that previously lived as prose in
CHANGES.md.  Everything here is stdlib-only ``ast`` analysis — the lint CLI
must run in a bare CI interpreter without jax installed.

Codes
-----
JL000  malformed ``# jaxlint:`` annotation (unknown directive, reasonless
       ``allow-*``, unparseable ``shapes(...)``)
JL001  host sync in a hot-path function (``jax.device_get`` / ``.item()`` /
       ``float()/int()/bool()`` of device values / ``np.asarray`` of device
       values) without ``allow-sync(reason)``
JL002  ``jnp.concatenate``/``jnp.stack`` in a sharded code path — the
       PR 3/5 XLA-CPU SPMD mixed-tiling-concat miscompute class
JL003  cache state escaping a masked scan body without routing through the
       per-leaf masked select (``tree_map`` + ``jnp.where``)
JL004  Python ``if``/``while``/``assert`` on a traced value inside a jitted
       function
JL005  ``jax.jit`` call in the tick path without a declared shape budget
JL006  dead import
"""

from __future__ import annotations

import ast
from dataclasses import dataclass, field
from typing import Dict, FrozenSet, Iterable, List, Optional, Set, Tuple

from .contracts import AnnotationIndex, parse_annotations, parse_shapes_decl
from .findings import Finding

# Scope defaults ------------------------------------------------------------

# Modules whose every jnp.concatenate/jnp.stack is a JL002 finding: these
# carry batched cache trees whose batch axis may be sharded over "data".
SHARDED_PATH_MODULES: Tuple[str, ...] = (
    "repro/serving/sharded.py",
    "repro/serving/engine.py",
    "repro/serving/dense.py",
    "repro/serving/static_admission.py",
    "repro/launch/specs.py",
    "repro/models/inference.py",
)

# Modules whose jax.jit calls feed the serving tick and therefore need an
# explicit compiled-shape budget declaration (JL005).
TICK_PATH_MODULES: Tuple[str, ...] = (
    "repro/serving/sharded.py",
    "repro/serving/engine.py",
)

# Calls whose outputs count as already-masked cache state for JL003: the
# per-leaf select itself, and the ragged extend whose body performs it.
MASKED_PRODUCERS: Tuple[str, ...] = (
    "tree_map",
    "tree_map_with_path",
    "where",
    "select",
    "prefill_extend_ragged",
)

# Parameter names that seed JL003's cache-flow tracking.
CACHE_PARAM_NAMES: FrozenSet[str] = frozenset(
    {"carry", "caches", "cache", "old", "state"}
)

SAFE_TRACER_ATTRS: FrozenSet[str] = frozenset(
    {"shape", "ndim", "dtype", "size", "sharding"}
)
SAFE_TRACER_CALLS: FrozenSet[str] = frozenset(
    {"len", "isinstance", "getattr", "hasattr", "type", "id"}
)

ALL_CODES: Tuple[str, ...] = (
    "JL000", "JL001", "JL002", "JL003", "JL004", "JL005", "JL006",
)


@dataclass
class ModuleContext:
    path: str  # as passed on the CLI, '/'-separated
    source: str
    tree: ast.Module
    ann: AnnotationIndex
    lines: List[str] = field(default_factory=list)
    parents: Dict[ast.AST, ast.AST] = field(default_factory=dict)

    @classmethod
    def parse(cls, path: str, source: str) -> "ModuleContext":
        tree = ast.parse(source, filename=path)
        ctx = cls(
            path=path.replace("\\", "/"),
            source=source,
            tree=tree,
            ann=parse_annotations(source),
            lines=source.splitlines(),
        )
        for parent in ast.walk(tree):
            for child in ast.iter_child_nodes(parent):
                ctx.parents[child] = parent
        return ctx

    def line_text(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1].strip()
        return ""

    def in_modules(self, suffixes: Iterable[str]) -> bool:
        return any(self.path.endswith(s) for s in suffixes)

    def finding(self, code: str, lineno: int, message: str) -> Finding:
        return Finding(
            code=code,
            path=self.path,
            line=lineno,
            message=message,
            text=self.line_text(lineno),
        )


# Shared AST helpers --------------------------------------------------------


def _dotted(node: ast.AST) -> Optional[str]:
    """``a.b.c`` for Name/Attribute chains, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _name_targets(target: ast.AST) -> List[str]:
    """Flatten assignment targets into plain names (ignores attrs/subscripts)."""
    if isinstance(target, ast.Name):
        return [target.id]
    if isinstance(target, (ast.Tuple, ast.List)):
        out: List[str] = []
        for elt in target.elts:
            out.extend(_name_targets(elt))
        return out
    if isinstance(target, ast.Starred):
        return _name_targets(target.value)
    return []


def _functions(tree: ast.AST):
    """Yield (funcdef, ancestors) for every def/async def, outermost first."""
    stack: List[Tuple[ast.AST, Tuple[ast.AST, ...]]] = [(tree, ())]
    while stack:
        node, anc = stack.pop()
        for child in ast.iter_child_nodes(node):
            child_anc = anc
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                child_anc = anc  # already extended below
            stack.append((child, child_anc + ((node,) if isinstance(
                node, (ast.FunctionDef, ast.AsyncFunctionDef)) else ())))
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node, anc


def _assignments_in_order(fn: ast.AST) -> List[ast.Assign]:
    out = [n for n in ast.walk(fn) if isinstance(n, ast.Assign)]
    out.sort(key=lambda n: n.lineno)
    return out


# jnp/jax calls that compute on host metadata, not device values
_HOST_SAFE_CALLS: FrozenSet[str] = frozenset(
    {
        "jax.device_get",
        "jnp.dtype",
        "jnp.shape",
        "jnp.ndim",
        "jnp.size",
        "jnp.result_type",
        "jnp.issubdtype",
        "jax.eval_shape",
        "jax.tree_util.tree_structure",
    }
)


def _contains_device_call(node: ast.AST, tainted: Set[str]) -> bool:
    """True if the expression evaluates on-device values: a jnp./jax. call
    (other than the host-safe metadata helpers) or a reference to a
    device-tainted name."""
    for n in ast.walk(node):
        if isinstance(n, ast.Call):
            d = _dotted(n.func) or ""
            if d in _HOST_SAFE_CALLS:
                continue
            if d.startswith("jnp.") or d.startswith("jax."):
                return True
        elif isinstance(n, ast.Name) and n.id in tainted:
            return True
    return False


def _parent_map(expr: ast.AST) -> Dict[ast.AST, ast.AST]:
    return {
        child: parent
        for parent in ast.walk(expr)
        for child in ast.iter_child_nodes(parent)
    }


def _tainted_value_uses(expr: ast.AST, tainted: Set[str]) -> List[ast.Name]:
    """Tainted Name nodes used *as values* in `expr` — uses under shape-like
    attributes, len()/isinstance() calls, or `is None` compares don't count."""
    parents = _parent_map(expr)
    hits: List[ast.Name] = []
    for n in ast.walk(expr):
        if not (isinstance(n, ast.Name) and n.id in tainted):
            continue
        parent = parents.get(n)
        if isinstance(parent, ast.Attribute) and parent.attr in SAFE_TRACER_ATTRS:
            continue
        if (
            isinstance(parent, ast.Call)
            and n in parent.args
            and isinstance(parent.func, ast.Name)
            and parent.func.id in SAFE_TRACER_CALLS
        ):
            continue
        if isinstance(parent, ast.Compare) and all(
            isinstance(op, (ast.Is, ast.IsNot)) for op in parent.ops
        ):
            continue
        hits.append(n)
    return hits


def _func_params(fn) -> List[str]:
    a = fn.args
    names = [p.arg for p in a.posonlyargs + a.args + a.kwonlyargs]
    if a.vararg:
        names.append(a.vararg.arg)
    if a.kwarg:
        names.append(a.kwarg.arg)
    return [n for n in names if n != "self"]


# JL000 — annotation errors -------------------------------------------------


def check_annotations(ctx: ModuleContext) -> List[Finding]:
    out = [
        ctx.finding(
            "JL000",
            d.line,
            f"malformed jaxlint annotation '{d.name}'"
            + (
                " (allow-* suppressions require a reason in parens)"
                if d.name.startswith("allow-")
                else " (unknown directive)"
            ),
        )
        for d in ctx.ann.errors
    ]
    for directives in ctx.ann.by_line.values():
        for d in directives:
            if d.name == "shapes" and parse_shapes_decl(d.arg) is None:
                out.append(
                    ctx.finding(
                        "JL000",
                        d.line,
                        "unparseable shapes(...) declaration: expected "
                        "shapes(name=COUNT|tag, ...)",
                    )
                )
    return out


# JL001 — host sync in hot path ---------------------------------------------


def _is_hot_function(fn, ctx: ModuleContext, ancestors) -> bool:
    for anc in ancestors:
        if getattr(anc, "__jaxlint_hot__", False):
            return True
    for dec in fn.decorator_list:
        d = _dotted(dec) or _dotted(getattr(dec, "func", ast.Pass())) or ""
        if d == "hot_path" or d.endswith(".hot_path"):
            return True
    return ctx.ann.scope_marker("hot-path", fn.lineno)


def check_host_sync(ctx: ModuleContext) -> List[Finding]:
    out: List[Finding] = []
    hot_spans: List[Tuple[int, int]] = []  # (lineno, end_lineno) of hot defs
    for fn, anc in _functions(ctx.tree):
        if _is_hot_function(fn, ctx, anc):
            fn.__jaxlint_hot__ = True  # noqa — marker for nested lookups
            hot_spans.append((fn.lineno, fn.end_lineno or fn.lineno))
    if not hot_spans:
        return out

    def in_hot(node) -> bool:
        return any(lo <= node.lineno <= hi for lo, hi in hot_spans)

    # device-taint over local names, assignments in source order
    tainted: Set[str] = set()
    for st in _assignments_in_order(ctx.tree):
        if not in_hot(st):
            continue
        targets: List[str] = []
        for t in st.targets:
            targets.extend(_name_targets(t))
        rhs = _dotted(getattr(st.value, "func", ast.Pass())) or ""
        if rhs == "jax.device_get" or rhs.startswith("np."):
            tainted.difference_update(targets)  # pulled to host
        elif _contains_device_call(st.value, tainted):
            tainted.update(targets)
        else:
            tainted.difference_update(targets)

    def emit(node, what: str) -> None:
        if ctx.ann.suppressed("JL001", node.lineno):
            return
        out.append(
            ctx.finding(
                "JL001",
                node.lineno,
                f"{what} in hot-path function blocks the tick on a host "
                "sync — hoist out of the tick or annotate "
                "`# jaxlint: allow-sync(reason)` (collect() is the only "
                "sanctioned sync point)",
            )
        )

    for node in ast.walk(ctx.tree):
        if not (isinstance(node, ast.Call) and in_hot(node)):
            continue
        d = _dotted(node.func) or ""
        if d == "jax.device_get":
            emit(node, "jax.device_get")
        elif d in ("np.asarray", "numpy.asarray") and node.args:
            arg = node.args[0]
            benign = isinstance(
                arg, (ast.Constant, ast.List, ast.Tuple)
            ) or (isinstance(arg, ast.Name) and arg.id not in tainted)
            if not benign:
                emit(node, "np.asarray of device value")
        elif (
            isinstance(node.func, ast.Name)
            and node.func.id in ("float", "int", "bool")
            and len(node.args) == 1
            and _contains_device_call(node.args[0], tainted)
        ):
            emit(node, f"{node.func.id}() of device value")
        elif (
            isinstance(node.func, ast.Attribute)
            and node.func.attr == "item"
            and not node.args
            and _contains_device_call(node.func.value, tainted)
        ):
            emit(node, ".item()")
    return out


# JL002 — concat on sharded axis --------------------------------------------


def check_sharded_concat(ctx: ModuleContext) -> List[Finding]:
    spans: List[Tuple[int, int]]
    if ctx.in_modules(SHARDED_PATH_MODULES):
        spans = [(1, len(ctx.lines) or 1)]
    else:
        spans = [
            (fn.lineno, fn.end_lineno or fn.lineno)
            for fn, _ in _functions(ctx.tree)
            if ctx.ann.scope_marker("sharded-path", fn.lineno)
        ]
    if not spans:
        return []
    out: List[Finding] = []
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        d = _dotted(node.func) or ""
        if d not in ("jnp.concatenate", "jnp.stack"):
            continue
        if not any(lo <= node.lineno <= hi for lo, hi in spans):
            continue
        if ctx.ann.suppressed("JL002", node.lineno):
            continue
        out.append(
            ctx.finding(
                "JL002",
                node.lineno,
                f"{d} in a sharded code path — XLA CPU's SPMD partitioner "
                "miscomputes mixed-tiling concats on sharded batch axes "
                "(PR 3/5); use the splice helpers in launch/specs.py "
                "(splice_caches / alloc_batched_caches) or annotate "
                "`# jaxlint: allow-concat(reason)` for non-batch axes",
            )
        )
    return out


# JL003 — unmasked cache write ----------------------------------------------

_PLAIN, _CACHE, _RAW, _MASKED = "plain", "cache", "raw", "masked"


def _is_masked_producer(call: ast.Call) -> bool:
    d = _dotted(call.func) or ""
    last = d.rsplit(".", 1)[-1]
    return last in MASKED_PRODUCERS


def check_masked_scan_body(ctx: ModuleContext) -> List[Finding]:
    out: List[Finding] = []
    for fn, _anc in _functions(ctx.tree):
        if not ctx.ann.scope_marker("masked-scan-body", fn.lineno):
            continue
        state: Dict[str, str] = {
            p: _CACHE for p in _func_params(fn) if p in CACHE_PARAM_NAMES
        }

        def names_state(expr) -> str:
            worst = _PLAIN
            for n in ast.walk(expr):
                if isinstance(n, ast.Name):
                    s = state.get(n.id, _PLAIN)
                    if s == _RAW:
                        return _RAW
                    if s == _CACHE:
                        worst = _CACHE
            return worst

        for st in _assignments_in_order(fn):
            targets: List[str] = []
            for t in st.targets:
                targets.extend(_name_targets(t))
            if not targets:
                continue
            v = st.value
            if isinstance(v, ast.Call):
                if _is_masked_producer(v):
                    new = _MASKED
                elif names_state(v) in (_CACHE, _RAW):
                    new = _RAW
                else:
                    new = _PLAIN
            else:
                new = names_state(v)
                if new == _PLAIN and isinstance(v, ast.Name):
                    new = state.get(v.id, _PLAIN)
            for t in targets:
                state[t] = new

        # (a) .at[...].set/add without a masked select in the value
        for node in ast.walk(fn):
            if not (
                isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr in ("set", "add", "mul", "max", "min")
            ):
                continue
            base = node.func.value
            if not (
                isinstance(base, ast.Subscript)
                and isinstance(base.value, ast.Attribute)
                and base.value.attr == "at"
            ):
                continue
            masked = any(
                isinstance(n, ast.Call) and _is_masked_producer(n)
                for a in node.args
                for n in ast.walk(a)
            )
            if masked or ctx.ann.suppressed("JL003", node.lineno):
                continue
            out.append(
                ctx.finding(
                    "JL003",
                    node.lineno,
                    ".at[...] write inside a masked scan body without a "
                    "per-row select — padding rows will be corrupted; wrap "
                    "the value in jnp.where(active, ...) or annotate "
                    "`# jaxlint: allow-unmasked-write(reason)`",
                )
            )

        # (b) raw cache state escaping through the return
        for node in ast.walk(fn):
            if not isinstance(node, ast.Return) or node.value is None:
                continue
            raw = sorted(
                {
                    n.id
                    for n in ast.walk(node.value)
                    if isinstance(n, ast.Name) and state.get(n.id) == _RAW
                }
            )
            if not raw or ctx.ann.suppressed("JL003", node.lineno):
                continue
            out.append(
                ctx.finding(
                    "JL003",
                    node.lineno,
                    f"cache state {raw} escapes the masked scan body without "
                    "routing through the per-leaf masked select "
                    "(tree_map + jnp.where over the pre-step tree) — "
                    "short/padding rows will see unmasked writes",
                )
            )
    return out


# JL004 — tracer leak -------------------------------------------------------


def _jit_static_params(call: ast.Call, fn) -> Set[str]:
    """Parameter names excluded from tracing by static_argnames/argnums."""
    static: Set[str] = set()
    params = _func_params(fn)
    for kw in call.keywords or []:
        vals: List[ast.AST] = []
        if isinstance(kw.value, (ast.Tuple, ast.List)):
            vals = list(kw.value.elts)
        elif isinstance(kw.value, ast.Constant):
            vals = [kw.value]
        if kw.arg == "static_argnames":
            static.update(
                v.value
                for v in vals
                if isinstance(v, ast.Constant) and isinstance(v.value, str)
            )
        elif kw.arg == "static_argnums":
            for v in vals:
                if isinstance(v, ast.Constant) and isinstance(v.value, int):
                    if 0 <= v.value < len(params):
                        static.add(params[v.value])
    return static


def _jitted_functions(ctx: ModuleContext):
    """Yield (funcdef, static_param_names) for functions traced under jit."""
    defs: Dict[str, List] = {}
    for fn, _ in _functions(ctx.tree):
        defs.setdefault(fn.name, []).append(fn)

    # decorator forms
    for fn, _ in _functions(ctx.tree):
        for dec in fn.decorator_list:
            d = _dotted(dec) or ""
            if d in ("jax.jit", "jit"):
                yield fn, set()
                break
            if isinstance(dec, ast.Call):
                dd = _dotted(dec.func) or ""
                if dd in ("jax.jit", "jit"):
                    yield fn, _jit_static_params(dec, fn)
                    break
                if dd in ("functools.partial", "partial") and dec.args:
                    inner = _dotted(dec.args[0]) or ""
                    if inner in ("jax.jit", "jit"):
                        yield fn, _jit_static_params(dec, fn)
                        break

    # call forms: jax.jit(fn, ...) / self._mesh_jit(fn, kind=...)
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        d = _dotted(node.func) or ""
        if not (d in ("jax.jit", "jit") or d.rsplit(".", 1)[-1].endswith("mesh_jit")):
            continue
        for arg in node.args[:1]:
            if isinstance(arg, ast.Name) and arg.id in defs:
                for fn in defs[arg.id]:
                    yield fn, _jit_static_params(node, fn)


def check_tracer_leak(ctx: ModuleContext) -> List[Finding]:
    out: List[Finding] = []
    seen: Set[int] = set()
    for fn, static in _jitted_functions(ctx):
        if id(fn) in seen:
            continue
        seen.add(id(fn))
        tainted: Set[str] = set(_func_params(fn)) - static
        for st in _assignments_in_order(fn):
            targets: List[str] = []
            for t in st.targets:
                targets.extend(_name_targets(t))
            if _tainted_value_uses(st.value, tainted):
                tainted.update(targets)
        for node in ast.walk(fn):
            if isinstance(node, (ast.If, ast.While)):
                test = node.test
            elif isinstance(node, ast.Assert):
                test = node.test
            else:
                continue
            hits = _tainted_value_uses(test, tainted)
            if not hits or ctx.ann.suppressed("JL004", node.lineno):
                continue
            kind = type(node).__name__.lower()
            names = sorted({h.id for h in hits})
            out.append(
                ctx.finding(
                    "JL004",
                    node.lineno,
                    f"Python {kind} on traced value(s) {names} inside jitted "
                    f"function '{fn.name}' — leaks a tracer (ConcretizationError "
                    "at best, silent constant-folding at worst); use "
                    "jnp.where/lax.cond, or mark the argument static",
                )
            )
    return out


# JL005 — untracked compiled shape ------------------------------------------


def check_shape_budget(ctx: ModuleContext) -> List[Finding]:
    if not ctx.in_modules(TICK_PATH_MODULES):
        return []
    out: List[Finding] = []

    def decl_covers(lineno: int, enclosing) -> bool:
        if ctx.ann.shapes_decl(lineno) is not None:
            return True
        return any(
            ctx.ann.shapes_decl(fn.lineno) is not None for fn in enclosing
        )

    # walk with an explicit def-stack so each jit call knows its enclosing defs
    def visit(node, stack):
        is_def = isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef))
        if is_def:
            stack = stack + [node]
        if isinstance(node, ast.Call):
            d = _dotted(node.func) or ""
            if d in ("jax.jit", "jit") and not decl_covers(node.lineno, stack):
                out.append(
                    ctx.finding(
                        "JL005",
                        node.lineno,
                        "jax.jit in the tick path without a declared shape "
                        "budget — every compiled shape here is tick latency; "
                        "annotate the enclosing def with "
                        "`# jaxlint: shapes(name=COUNT|per-structure)` and "
                        "account for it in Engine.COMPILE_SHAPE_BUDGETS",
                    )
                )
        if is_def:
            for dec in node.decorator_list:
                dd = _dotted(dec) or _dotted(getattr(dec, "func", ast.Pass()))
                inner = ""
                if isinstance(dec, ast.Call) and dec.args:
                    inner = _dotted(dec.args[0]) or ""
                if (dd in ("jax.jit", "jit")
                        or inner in ("jax.jit", "jit")) and not decl_covers(
                            dec.lineno, stack):
                    out.append(
                        ctx.finding(
                            "JL005",
                            dec.lineno,
                            "jitted def in the tick path without a declared "
                            "shape budget — annotate with "
                            "`# jaxlint: shapes(name=COUNT|per-structure)`",
                        )
                    )
        for child in ast.iter_child_nodes(node):
            visit(child, stack)

    visit(ctx.tree, [])
    return out


# JL006 — dead imports ------------------------------------------------------


def check_dead_imports(ctx: ModuleContext) -> List[Finding]:
    if ctx.path.endswith("__init__.py"):
        return []
    imports: List[Tuple[str, ast.stmt]] = []  # (bound name, stmt)
    import_nodes: Set[ast.AST] = set()
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Import):
            import_nodes.add(node)
            for alias in node.names:
                imports.append(
                    (alias.asname or alias.name.split(".")[0], node)
                )
        elif isinstance(node, ast.ImportFrom):
            if node.module == "__future__":
                continue
            import_nodes.add(node)
            for alias in node.names:
                if alias.name == "*":
                    continue
                imports.append((alias.asname or alias.name, node))
    if not imports:
        return []

    # a defensive `try: import x` is a capability probe, not a dead import
    guarded: Set[ast.AST] = set()
    for node in import_nodes:
        p = ctx.parents.get(node)
        while p is not None:
            if isinstance(p, (ast.Try, ast.If)):
                guarded.add(node)
                break
            p = ctx.parents.get(p)

    used: Set[str] = set()
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Name):
            used.add(node.id)
        elif isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name) and t.id == "__all__":
                    for n in ast.walk(node.value):
                        if isinstance(n, ast.Constant) and isinstance(
                            n.value, str
                        ):
                            used.add(n.value)

    out: List[Finding] = []
    for name, node in imports:
        if name in used or name == "_" or node in guarded:
            continue
        text = ctx.line_text(node.lineno)
        if "noqa" in text:
            continue
        if ctx.ann.suppressed("JL006", node.lineno):
            continue
        out.append(
            ctx.finding(
                "JL006",
                node.lineno,
                f"imported name '{name}' is unused",
            )
        )
    return out


# Driver --------------------------------------------------------------------

PASSES = {
    "JL000": check_annotations,
    "JL001": check_host_sync,
    "JL002": check_sharded_concat,
    "JL003": check_masked_scan_body,
    "JL004": check_tracer_leak,
    "JL005": check_shape_budget,
    "JL006": check_dead_imports,
}


def run_passes(
    ctx: ModuleContext, select: Optional[Iterable[str]] = None
) -> List[Finding]:
    codes = tuple(select) if select else ALL_CODES
    out: List[Finding] = []
    for code in codes:
        out.extend(PASSES[code](ctx))
    out.sort(key=lambda f: (f.path, f.line, f.code))
    return out
