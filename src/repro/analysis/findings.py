"""Finding records and the accepted-findings baseline.

A baseline entry is matched by ``(code, path, stripped source text)`` with a
count, NOT by line number — accepted findings survive unrelated edits that
shift lines, but a new occurrence of the same pattern in the same file still
fails the build (the count caps how many may match).
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List, Tuple

BASELINE_VERSION = 1


@dataclass(frozen=True)
class Finding:
    code: str  # "JL001".."JL006", "JL000" for annotation errors
    path: str  # repo-relative, '/'-separated
    line: int  # 1-based
    message: str
    text: str = ""  # stripped source line, used for baseline matching

    def render(self) -> str:
        return f"{self.path}:{self.line}: {self.code} {self.message}"

    def fingerprint(self) -> Tuple[str, str, str]:
        return (self.code, self.path, self.text)


@dataclass
class Baseline:
    """Accepted findings: fingerprint -> allowed count (+ recorded reason)."""

    counts: Dict[Tuple[str, str, str], int] = field(default_factory=dict)
    reasons: Dict[Tuple[str, str, str], str] = field(default_factory=dict)

    def split(self, findings: List[Finding]) -> Tuple[List[Finding], List[Finding]]:
        """Partition into (new, accepted) against this baseline."""
        remaining = dict(self.counts)
        new: List[Finding] = []
        accepted: List[Finding] = []
        for f in findings:
            fp = f.fingerprint()
            if remaining.get(fp, 0) > 0:
                remaining[fp] -= 1
                accepted.append(f)
            else:
                new.append(f)
        return new, accepted


def load_baseline(path: Path) -> Baseline:
    data = json.loads(path.read_text())
    if data.get("version") != BASELINE_VERSION:
        raise ValueError(
            f"unsupported baseline version {data.get('version')!r} in {path}"
        )
    base = Baseline()
    for entry in data.get("findings", []):
        fp = (entry["code"], entry["path"], entry["text"])
        base.counts[fp] = base.counts.get(fp, 0) + int(entry.get("count", 1))
        if entry.get("reason"):
            base.reasons[fp] = entry["reason"]
    return base


def write_baseline(findings: List[Finding], path: Path, reason: str = "") -> None:
    grouped: Dict[Tuple[str, str, str], int] = {}
    for f in findings:
        fp = f.fingerprint()
        grouped[fp] = grouped.get(fp, 0) + 1
    entries = [
        {
            "code": code,
            "path": p,
            "text": text,
            "count": count,
            **({"reason": reason} if reason else {}),
        }
        for (code, p, text), count in sorted(grouped.items())
    ]
    payload = {"version": BASELINE_VERSION, "findings": entries}
    path.write_text(json.dumps(payload, indent=2) + "\n")
