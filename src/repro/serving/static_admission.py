"""StaticAdmissionEngine: StreamingLLM / DuoAttention baselines as serving
backends.

The paper's §5.2 baselines are *input-independent* admission policies
re-expressed in the write-gate interface (core/baselines.py): g depends
only on a token's absolute position (and, for DuoAttention, its head).
Plugging those gates into the identical dual-cache machinery — same ring,
same lazy promotion, same paged mirror, same two-phase
``step_batch``/``collect`` surface (the gate is a jit-time option,
so the dispatched step and on-device token feed are inherited from
:class:`Engine` unchanged) — turns each baseline into a full serving
backend behind the :class:`EngineBackend` protocol, so the A/B harness
can replay one arrival trace through WG-KV, dense full-KV, and the
static baselines under the same scheduler, synchronous or
dispatch-ahead.

Policies:
  * ``streaming_llm`` — admit only the first ``sink`` tokens; everything
    else lives (transiently) in the sliding local window.
  * ``duo`` — per-head static split: ``retrieval_heads`` admit every
    token, the remaining (streaming) heads admit sinks only. Heads can be
    given explicitly, derived as the first ``retrieval_ratio`` fraction,
    or profiled from a learned gate via
    :func:`repro.core.baselines.identify_retrieval_heads`.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

from repro.configs.base import ModelConfig
from repro.models import inference as I
from repro.serving.backend import BackendCapabilities
from repro.serving.engine import Engine

POLICIES = ("streaming_llm", "duo")


class StaticAdmissionEngine(Engine):
    """Dual-cache engine whose write gate is a static position/head policy."""

    def __init__(self, params, cfg: ModelConfig, *,
                 policy: str = "streaming_llm",
                 sink: Optional[int] = None,
                 retrieval_heads: Optional[Sequence[int]] = None,
                 retrieval_ratio: float = 0.25,
                 opts: Optional[I.DecodeOptions] = None, **kw):
        if policy not in POLICIES:
            raise ValueError(f"policy must be one of {POLICIES}, got {policy!r}")
        sink = cfg.wgkv.sink if sink is None else int(sink)
        if policy == "duo":
            if retrieval_heads is None:
                k = max(1, round(retrieval_ratio * cfg.n_kv_heads))
                retrieval_heads = range(k)
            retrieval_heads = tuple(int(h) for h in retrieval_heads)
        else:
            retrieval_heads = ()
        opts = dataclasses.replace(
            opts or I.DecodeOptions(), admission_policy=policy,
            admission_sink=sink, duo_retrieval_heads=retrieval_heads)
        # align the config's sink floor with the policy's: select_global /
        # prefill_populate force-admit cfg.wgkv.sink positions regardless of
        # the gate, so a mismatched floor would make one-shot and chunked
        # prefill admit different token sets
        cfg = cfg.replace(wgkv=dataclasses.replace(cfg.wgkv, sink=sink))
        super().__init__(params, cfg, opts=opts, **kw)
        self.policy = policy

    def capabilities(self) -> BackendCapabilities:
        return BackendCapabilities(
            name=self.policy, gated=True, paged=self.mirror,
            description="static admission baseline "
                        "(position/head-only write gate)",
            sharded=self.mesh is not None, selection=self.selection)
