"""EngineBackend: the backend-agnostic serving protocol.

The orchestrator (serving/orchestrator/) schedules *any* accelerator
backend that exposes the JetStream-style prefill/insert/generate
decomposition; the concrete cache policy — write-gated dual cache, dense
full KV, static StreamingLLM/DuoAttention admission — is a backend
implementation detail. The paper's headline numbers (memory reduction,
decode speedup) are comparative, so serving the baselines under the SAME
scheduler/queue/telemetry stack is what makes an apples-to-apples A/B
possible (``benchmarks/bench_serving.py --backends wgkv,dense``).

Protocol surface (one request = one chunked prefill + one decode slot):

  * ``start_prefill(prompt) -> PrefillTask`` — open a chunked prefill.
  * ``prefill_step_batch(tasks, max_tokens) -> [bool]`` — advance EVERY
    task by at most one chunk, running the model math for all
    mid-prefill tasks as ONE batched ragged jitted call (tokens
    ``[B, S]`` + per-row lengths; writes past a row's length are masked,
    so each row's cache state is bit-identical to the sequential batch-1
    path). Returns each task's done flag. Gated by
    ``BackendCapabilities.batched_prefill``.
  * ``prefill_step(task, max_tokens) -> bool`` — DEPRECATED batch-of-one
    shim over ``prefill_step_batch`` (one deprecation cycle, like
    ``generate()`` before it); kept so single-request callers and
    backends without batched prefill keep working.
  * ``finish_prefill(task, emit_first=True) -> Prefix`` — seal the task;
    with ``emit_first`` the first generated token is sampled from the
    prefill's own last-position logits (no extra decode step, no
    duplicate KV write — JetStream semantics: TTFT ends at prefill).
  * ``insert(prefix, slot)`` — splice the batch-1 caches into decode row
    ``slot`` of the batched state.
  * ``free_slot(slot)`` — retire a slot and release its physical memory.
  * ``capabilities() -> BackendCapabilities`` — static descriptor
    (gated? physically paged?) the orchestrator/telemetry key off.
  * ``memory_snapshot() -> dict`` — point-in-time memory telemetry
    (resident KV tokens/bytes, paged-pool pages/utilization when paged).

Decode is a TWO-PHASE surface so host work never blocks the device:

  * ``dispatch_decode() -> InflightStep | None`` — enqueue one jitted
    batched decode step over all live slots WITHOUT synchronizing. The
    sampled next-token vector stays on device and becomes the feed of
    the next dispatch, so the driver may dispatch step t+1 before
    step t's result has ever touched the host (dispatch-ahead depth
    >= 1). Returns None when no slot is live.
  * ``collect(step) -> {slot: token}`` — the sync point: pull the
    sampled tokens to host, fold eviction/admission stats into
    ``stats``, and apply the step's cache delta to the paged mirror.
    Host-side mirroring and bookkeeping for step t therefore overlap
    device compute for step t+1. A slot whose request was freed (or
    re-inserted) between dispatch and collect is skipped — its token is
    discarded and its pool streams are left exactly as ``free_slot`` /
    ``insert`` put them (per-slot generation counters guard the race).

(The ``generate()`` synchronous shim — ``collect(dispatch_decode())`` —
served its one deprecation cycle and is gone; single-step callers run
the two-phase surface directly.)

Lifecycle of one request (slots are rows of one batched cache tree)::

    submit ──> start_prefill ──> prefill_step_batch* ──> finish_prefill
                                                        │ first token
                                                        v
                                       insert(prefix, slot)
                                                        │
              ┌─────────────────────────────────────────┘
              v
        dispatch_decode ──> [device: step t]──────────┐
              │  (no sync; feed stays on device)      │
              ├──> dispatch_decode [device: step t+1] │
              v                                       │
        collect(step t) <─────────────────────────────┘
              │  {slot: token} ──> streams / telemetry
              v
        free_slot(slot)          (finished / cancelled)

Concrete implementations:
  serving/engine.py           Engine                (wgkv — paper system)
  serving/dense.py            DenseEngine           (full-KV baseline)
  serving/static_admission.py StaticAdmissionEngine (StreamingLLM / Duo)
The mesh-sharded execution path (serving/sharded.py ShardedDecodeMixin)
builds the jitted step and on-device sampler every backend dispatches.
"""
from __future__ import annotations

import dataclasses
from typing import (Any, Dict, List, Optional, Protocol, Tuple,
                    runtime_checkable)

import jax


@dataclasses.dataclass
class Prefix:
    """Result of a (possibly chunked) batch-1 prefill, ready to `insert`."""
    caches: Any                        # batch-1 cache tree
    prompt_len: int
    mean_admission: float              # token-weighted write-gate admission
    first_token: Optional[int] = None  # emitted iff finish_prefill(emit_first)
    first_logits: Optional[jax.Array] = None  # [V] logits behind first_token


@dataclasses.dataclass
class PrefillTask:
    """Incremental chunked-prefill state (one request, batch 1)."""
    prompt: List[int]
    pos: int = 0                       # prompt tokens already in the cache
    caches: Any = None
    adm_weighted: float = 0.0          # sum(admission * tokens) so far
    # [1, V] device logits of the newest prefilled position; once the task
    # is done these are the first-token logits (finish_prefill samples
    # them directly instead of re-feeding prompt[-1] through decode_step)
    last_logits: Any = None

    @property
    def done(self) -> bool:
        return self.caches is not None and self.pos >= len(self.prompt)


@dataclasses.dataclass
class InflightStep:
    """One dispatched-but-uncollected batched decode step.

    Every field except the two snapshots is a DEVICE value — holding the
    step does not synchronize. ``live``/``gen`` freeze which request
    owned each slot at dispatch time so ``collect`` can discard tokens
    for slots that were freed or re-inserted while the step was in
    flight."""
    tokens: Any                 # [slots] int32 on device: sampled next tokens
    stats: Any                  # device stats tree from decode_step
    before: Any                 # cache tree before the step (mirror delta)
    after: Any                  # cache tree after the step
    live: Tuple[bool, ...]      # live mask snapshot at dispatch
    gen: Tuple[int, ...]        # per-slot generation snapshot at dispatch
    collected: bool = False


@dataclasses.dataclass(frozen=True)
class BackendCapabilities:
    """Static backend descriptor consumed by orchestrator/telemetry/bench."""
    name: str            # registry name ("wgkv", "dense", "streaming_llm", ...)
    gated: bool          # admission < 1.0 expected (learned or static gates)
    paged: bool          # mirrors into a physical paged pool (verify_paged)
    description: str = ""
    # decode/extend run SPMD over a data x model device mesh (slots batch
    # over "data", KV heads over "model"; serving/sharded.py)
    sharded: bool = False
    # prefill_step_batch advances every mid-prefill task in one batched
    # ragged jitted call (the scheduler falls back to per-task
    # prefill_step when False)
    batched_prefill: bool = False


@runtime_checkable
class EngineBackend(Protocol):
    """What the orchestrator requires of a serving backend."""

    slots: int
    eos: Optional[int]
    live: List[bool]
    stats: Dict[str, float]
    # observability handle (repro.serving.obs.trace.Tracer). Backends
    # default it to NULL_TRACER; the Orchestrator overwrites it with its
    # own tracer at construction so engine-side sub-phase spans
    # (prefill_open / prefill_extend_ragged / decode dispatch) land on
    # the same timeline as the scheduler's tick phases.
    tracer: Any

    def capabilities(self) -> BackendCapabilities: ...

    def start_prefill(self, prompt: List[int]) -> PrefillTask: ...

    def prefill_step_batch(self, tasks: List[PrefillTask],
                           max_tokens: Optional[int] = None) -> List[bool]: ...

    # deprecated batch-of-one shim: prefill_step_batch([task])[0]
    def prefill_step(self, task: PrefillTask,
                     max_tokens: Optional[int] = None) -> bool: ...

    def finish_prefill(self, task: PrefillTask, *,
                       emit_first: bool = True) -> Prefix: ...

    def insert(self, prefix: Prefix, slot: int) -> None: ...

    def dispatch_decode(self) -> Optional[InflightStep]: ...

    def collect(self, step: InflightStep) -> Dict[int, int]: ...

    def free_slot(self, slot: int) -> None: ...

    def memory_snapshot(self) -> Dict[str, float]: ...


# ==========================================================================
# registry: name -> backend factory (lazy imports; no concrete backend is
# imported until requested, so orchestrator code stays protocol-only)
# ==========================================================================
BACKEND_NAMES: Tuple[str, ...] = ("wgkv", "dense", "streaming_llm", "duo")


def make_backend(name: str, params, cfg, **kw) -> EngineBackend:
    """Construct a registered backend by name.

    Common keyword args (all backends): ``slots``, ``capacity``, ``opts``,
    ``eos``, ``temperature``, ``seed``, and ``mesh`` (a
    ``jax.sharding.Mesh`` with ("data", "model") axes — decode/extend run
    SPMD over it; see serving/sharded.py and
    ``repro.serving.sharded.build_mesh``). WG-KV family: ``pool_pages``,
    ``mirror_paged``. Static admission: ``sink``, ``retrieval_heads`` /
    ``retrieval_ratio`` (duo).
    """
    if name == "wgkv":
        from repro.serving.engine import Engine
        return Engine(params, cfg, **kw)
    if name == "dense":
        from repro.serving.dense import DenseEngine
        return DenseEngine(params, cfg, **kw)
    if name in ("streaming_llm", "duo"):
        from repro.serving.static_admission import StaticAdmissionEngine
        return StaticAdmissionEngine(params, cfg, policy=name, **kw)
    raise ValueError(f"unknown backend {name!r}; known: {BACKEND_NAMES}")
