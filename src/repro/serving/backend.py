"""EngineBackend: the backend-agnostic serving protocol.

The orchestrator (serving/orchestrator/) schedules *any* accelerator
backend that exposes the JetStream-style prefill/insert/generate
decomposition; the concrete cache policy — write-gated dual cache, dense
full KV, static StreamingLLM/DuoAttention admission — is a backend
implementation detail. The paper's headline numbers (memory reduction,
decode speedup) are comparative, so serving the baselines under the SAME
scheduler/queue/telemetry stack is what makes an apples-to-apples A/B
possible (``benchmarks/bench_serving.py --backends wgkv,dense``).

Protocol surface (one request = one chunked prefill + one decode slot):

  * ``start_prefill(prompt) -> PrefillTask`` — open a chunked prefill.
  * ``step_batch(tasks, max_tokens, decode=True) -> FusedStep | None`` —
    THE fused megabatch tick: ONE jitted ragged device call advances
    every live row of the persistent batched cache tree, whatever its
    phase. A first-chunk row is spliced in as an EMPTY row (per-row
    ``t`` offsets make the ragged scan start it from position 0 — no
    separately-compiled batch-1 open), a mid-prefill row takes its next
    prompt chunk, a live decode row piggybacks as a length-1 ragged row
    fed from the on-device sampled-token vector, and a dead row is
    length-0 padding whose state is kept bit-identical by per-leaf
    masked writes. Sampling runs inside the same jitted call; the
    result is an uncollected :class:`FusedStep`. A task-less
    ``step_batch([])`` is the decode-only dispatch — and, when the
    backend was built with a ``selection`` policy (``"quest:K"``), the
    tick where gathered top-K page selection applies: decode rows
    attend over only the K highest-scoring global pages for the live
    query, scored from incremental per-page key min/max metadata
    (core/selection.py). Mixed ticks always run the full path.
    (The unfused ``prefill_step_batch`` / ``dispatch_decode`` split
    drivers served their deprecation cycle and are gone — every backend
    runs the fused tick.)
  * ``finish_prefill(task, emit_first=True) -> Prefix`` — seal the task;
    with ``emit_first`` the first generated token is sampled from the
    prefill's own last-position logits (no extra decode step, no
    duplicate KV write — JetStream semantics: TTFT ends at prefill).
    Fused-path tasks never reach it: their first token comes out of
    ``collect`` on the step whose chunk completed the prompt.
  * ``insert(prefix, slot)`` — splice the batch-1 caches into decode row
    ``slot`` of the batched state (unfused path only; fused-path rows
    are already resident).
  * ``free_slot(slot)`` — retire a slot and release its physical memory.
  * ``capabilities() -> BackendCapabilities`` — static descriptor
    (gated? physically paged? fused?) the orchestrator/telemetry key off.
  * ``memory_snapshot() -> dict`` — point-in-time memory telemetry
    (resident KV tokens/bytes, paged-pool pages/utilization when paged).

Decode is a TWO-PHASE surface so host work never blocks the device:

  * ``step_batch(...) -> FusedStep | None`` — enqueue one jitted
    batched step WITHOUT synchronizing. The sampled next-token vector
    stays on device and becomes the feed of the next dispatch, so the
    driver may dispatch step t+1 before step t's result has ever
    touched the host (dispatch-ahead depth >= 1). Returns None when
    nothing can advance.
  * ``collect(step) -> {slot: token}`` — the sync point: pull the
    sampled tokens to host, fold eviction/admission stats into
    ``stats``, and apply the step's cache delta to the paged mirror.
    Host-side mirroring and bookkeeping for step t therefore overlap
    device compute for step t+1. A slot whose request was freed (or
    re-inserted) between dispatch and collect is skipped — its token is
    discarded and its pool streams are left exactly as ``free_slot`` /
    ``insert`` put them (per-slot generation counters guard the race).
    For a :class:`FusedStep` the token map also carries FIRST tokens of
    rows whose prompt completed in that step (``step.finishing``).

(The ``generate()`` synchronous shim — ``collect(dispatch_decode())`` —
served its one deprecation cycle and is gone; single-step callers run
the two-phase surface directly.)

Fused lifecycle (default; slots are rows of ONE persistent batched tree)::

    submit ──> start_prefill (slot reserved; row spliced empty on the
              │                first step_batch that includes the task)
              v
        step_batch(tasks, chunk) ──> [device: ONE fused ragged step]
              │   prefill rows: next chunk   decode rows: length-1
              │   dead rows: length-0 (bit-identical padding)
              ├──> step_batch(...)  [device: step t+1, dispatch-ahead]
              v
        collect(step t) ── {slot: token} (decode tokens + first tokens
              │                           of rows finishing prompt)
              v
        free_slot(slot)          (finished / cancelled)

(The unfused lifecycle — ``prefill_step_batch`` chunk loops feeding
``finish_prefill``/``insert``, plus ``dispatch_decode`` — served its
deprecation cycle and is gone. ``prefill``/``finish_prefill``/``insert``
remain as the offline prefix surface: build a batch-1 prefix eagerly and
splice it into a decode row.)

Concrete implementations:
  serving/engine.py           Engine                (wgkv — paper system)
  serving/dense.py            DenseEngine           (full-KV baseline)
  serving/static_admission.py StaticAdmissionEngine (StreamingLLM / Duo)
The mesh-sharded execution path (serving/sharded.py ShardedDecodeMixin)
builds the jitted step and on-device sampler every backend dispatches.
"""
from __future__ import annotations

import dataclasses
from typing import (Any, Dict, List, Optional, Protocol, Tuple,
                    runtime_checkable)

import jax


@dataclasses.dataclass
class Prefix:
    """Result of a (possibly chunked) batch-1 prefill, ready to `insert`."""
    caches: Any                        # batch-1 cache tree
    prompt_len: int
    mean_admission: float              # token-weighted write-gate admission
    first_token: Optional[int] = None  # emitted iff finish_prefill(emit_first)
    first_logits: Optional[jax.Array] = None  # [V] logits behind first_token


@dataclasses.dataclass
class PrefillTask:
    """Incremental chunked-prefill state (one request).

    Unfused path: ``caches`` is the task's own batch-1 tree. Fused path:
    the task's state lives as row ``slot`` of the engine's persistent
    batched tree (``caches`` stays None; ``done`` keys off ``slot``)."""
    prompt: List[int]
    pos: int = 0                       # prompt tokens already in the cache
    caches: Any = None
    adm_weighted: float = 0.0          # sum(admission * tokens) so far
    # [1, V] device logits of the newest prefilled position; once the task
    # is done these are the first-token logits (finish_prefill samples
    # them directly instead of re-feeding prompt[-1] through decode_step)
    last_logits: Any = None
    # fused path: the decode row this task is resident in (set by the
    # scheduler at admit; step_batch requires it)
    slot: Optional[int] = None
    # prefix-cache hit (serving/prefix_cache.py CachedPrefix), adopted at
    # admit: the engine splices the entry's cached tree instead of the
    # empty template on this task's first fused dispatch, so the ragged
    # scan resumes at the suffix (``pos`` starts at ``entry.n_tokens``).
    # The orchestrator releases the store reference after that dispatch.
    prefix_entry: Any = None
    # miss path: (n_tokens, chain_key) boundary the orchestrator wants
    # captured once ``pos`` reaches it (consumed at dispatch registration)
    capture_plan: Optional[Tuple[int, str]] = None

    @property
    def done(self) -> bool:
        opened = self.caches is not None or self.slot is not None
        return opened and self.pos >= len(self.prompt)


@dataclasses.dataclass
class InflightStep:
    """One dispatched-but-uncollected batched decode step.

    Every field except the two snapshots is a DEVICE value — holding the
    step does not synchronize. ``live``/``gen`` freeze which request
    owned each slot at dispatch time so ``collect`` can discard tokens
    for slots that were freed or re-inserted while the step was in
    flight."""
    tokens: Any                 # [slots] int32 on device: sampled next tokens
    stats: Any                  # device stats tree from decode_step
    before: Any                 # cache tree before the step (mirror delta)
    after: Any                  # cache tree after the step
    live: Tuple[bool, ...]      # live mask snapshot at dispatch
    gen: Tuple[int, ...]        # per-slot generation snapshot at dispatch
    collected: bool = False


@dataclasses.dataclass
class FusedStep(InflightStep):
    """One dispatched-but-uncollected FUSED megabatch step.

    Extends :class:`InflightStep` with the per-row role bookkeeping of a
    fused tick: which rows took prompt chunks (and whether that chunk
    completed the prompt), which rows decoded, and which were length-0
    padding. ``tokens`` holds the on-device sampled vector — the next
    token for decode rows AND the first generated token for finishing
    prefill rows (their last-real-position logits are the prompt's final
    logits, so sampling them inside the fused call IS JetStream's
    emit-first semantics with zero extra device work)."""
    tasks: Tuple[PrefillTask, ...] = ()   # prefill rows advanced this step
    takes: Tuple[int, ...] = ()           # prompt tokens each task consumed
    fulls: Tuple[bool, ...] = ()          # task chunk == full chunk width?
    finishing: Tuple[bool, ...] = ()      # task's prompt completed this step?
    decode_rows: Tuple[int, ...] = ()     # rows that decoded (length-1)
    had_prefill: bool = False
    t_dispatch: float = 0.0               # host wall clock at dispatch
    # this step ran the gathered top-K page-selection variant (decode-only
    # dispatch on a selection-configured backend)
    selection: bool = False


@dataclasses.dataclass(frozen=True)
class BackendCapabilities:
    """Static backend descriptor consumed by orchestrator/telemetry/bench."""
    name: str            # registry name ("wgkv", "dense", "streaming_llm", ...)
    gated: bool          # admission < 1.0 expected (learned or static gates)
    paged: bool          # mirrors into a physical paged pool (verify_paged)
    description: str = ""
    # decode/extend run SPMD over a data x model device mesh (slots batch
    # over "data", KV heads over "model"; serving/sharded.py)
    sharded: bool = False
    # active decode-time page-selection policy ("quest:K"), None = full
    # attention on every decode row
    selection: Optional[str] = None


@runtime_checkable
class EngineBackend(Protocol):
    """What the orchestrator requires of a serving backend."""

    slots: int
    eos: Optional[int]
    live: List[bool]
    stats: Dict[str, float]
    # observability handle (repro.serving.obs.trace.Tracer). Backends
    # default it to NULL_TRACER; the Orchestrator overwrites it with its
    # own tracer at construction so engine-side sub-phase spans
    # (fused_open / prefill_extend_ragged / decode dispatch) land on
    # the same timeline as the scheduler's tick phases.
    tracer: Any

    def capabilities(self) -> BackendCapabilities: ...

    def start_prefill(self, prompt: List[int]) -> PrefillTask: ...

    # fused megabatch tick: one jitted ragged call advancing prefill
    # chunks + piggybacked decode rows; collect() accepts the returned
    # FusedStep. step_batch([]) is the decode-only dispatch (and where
    # gathered top-K page selection applies when configured).
    def step_batch(self, tasks: List[PrefillTask],
                   max_tokens: Optional[int] = None, *,
                   decode: bool = True) -> Optional[FusedStep]: ...

    def finish_prefill(self, task: PrefillTask, *,
                       emit_first: bool = True) -> Prefix: ...

    def insert(self, prefix: Prefix, slot: int) -> None: ...

    def collect(self, step: FusedStep) -> Dict[int, int]: ...

    def free_slot(self, slot: int) -> None: ...

    def memory_snapshot(self) -> Dict[str, float]: ...

    # content-addressed prefix store hooks (serving/prefix_cache.py). The
    # store itself lives ABOVE this protocol in the orchestrator; the
    # backend only provides the two narrow primitives it cannot: freezing
    # one row of a collected step into a shareable batch-1 artifact
    # (a sanctioned sync point — SyncSentinel.SANCTIONED), and freeing an
    # evicted entry's pool streams. Adoption of a hit needs no extra
    # protocol surface: step_batch splices ``task.prefix_entry`` in place
    # of the empty template on the task's first dispatch.
    def capture_prefix(self, step: FusedStep, slot: int, key: str, *,
                       adm_weighted: float = 0.0) -> Any: ...

    def release_prefix(self, entry: Any) -> None: ...


# ==========================================================================
# registry: name -> backend factory (lazy imports; no concrete backend is
# imported until requested, so orchestrator code stays protocol-only)
# ==========================================================================
BACKEND_NAMES: Tuple[str, ...] = ("wgkv", "dense", "streaming_llm", "duo")


def make_backend(name: str, params, cfg, **kw) -> EngineBackend:
    """Construct a registered backend by name.

    Common keyword args (all backends): ``slots``, ``capacity``, ``opts``,
    ``eos``, ``temperature``, ``seed``, ``selection`` (a decode-time
    page-selection policy, ``"quest:K"`` — folded into
    ``opts.selection_policy``; dual-cache backends only), and ``mesh``
    (a ``jax.sharding.Mesh`` with ("data", "model") axes — decode/extend
    run SPMD over it; see serving/sharded.py and
    ``repro.serving.sharded.build_mesh``). WG-KV family: ``pool_pages``,
    ``mirror_paged``. Static admission: ``sink``, ``retrieval_heads`` /
    ``retrieval_ratio`` (duo).
    """
    selection = kw.pop("selection", None)
    if selection is not None:
        from repro.models import inference as I
        I.parse_selection_policy(selection)  # fail fast on a bad spec
        kw["opts"] = dataclasses.replace(kw.get("opts") or I.DecodeOptions(),
                                         selection_policy=selection)
    if name == "wgkv":
        from repro.serving.engine import Engine
        return Engine(params, cfg, **kw)
    if name == "dense":
        from repro.serving.dense import DenseEngine
        return DenseEngine(params, cfg, **kw)
    if name in ("streaming_llm", "duo"):
        from repro.serving.static_admission import StaticAdmissionEngine
        return StaticAdmissionEngine(params, cfg, policy=name, **kw)
    raise ValueError(f"unknown backend {name!r}; known: {BACKEND_NAMES}")
