"""Serving engine: the JetStream-style accelerator backend for the WG-KV
dual cache, with the paged physical layer (serving/paged.py) mirroring
every logical cache write — page tables, lazy-promotion page appends,
ring-slot overwrites — exactly as §4.1/§4.3 of the paper describe, plus
Quest/SnapKV composition flags.

The model math runs through the jitted decode path (models/inference.py);
the engine implements the :class:`repro.serving.backend.EngineBackend`
protocol — the prefill/insert/generate decomposition an outer
continuous-batching orchestrator (serving/orchestrator/) schedules
backend-agnostically (dense full-KV and static-admission siblings live in
serving/dense.py and serving/static_admission.py):

  * ``step_batch(tasks, chunk, decode=True)`` — the FUSED megabatch
    tick: one jitted ragged call over the persistent batched cache tree
    advances every live row whatever its phase. A first-chunk task is
    spliced in as an EMPTY row (per-row ``t`` makes the ragged scan
    start it at position 0 — the batch-1 budgeted open path is gone
    from the fused tick), mid-prefill rows take their next prompt
    chunk, live decode rows piggyback as length-1 rows fed from the
    on-device sampled vector, dead rows are length-0 bit-identical
    padding. Sampling runs inside the same call; ``collect`` returns
    decode tokens AND the first tokens of rows whose prompt finished.
    On a DECODE-ONLY tick (no prefill tasks in the dispatch) an engine
    configured with ``DecodeOptions.selection_policy = "quest:K"``
    dispatches a second compiled variant of the same fused step whose
    attention GATHERS only the top-K global pages per (row, kv head) —
    scored query-aware from the incremental per-page key min/max
    metadata the dual cache maintains in-jit (core/selection.py) — so
    decode attention reads K*16 + W entries instead of the full global
    budget. Mixed ticks (any prompt chunk aboard) always run the full
    path; with K >= resident pages the gather is the identity
    permutation and the token stream is byte-identical to selection
    off.
  * ``start_prefill`` / ``finish_prefill`` / ``prefill`` — task
    construction plus the one-shot convenience wrapper over the same
    batched ragged ``prefill_extend_ragged`` scan the fused tick runs
    (offline/eval callers; serving traffic rides ``step_batch``). The
    unfused per-cycle driver (``prefill_step_batch``) served its
    deprecation cycle and is gone.
  * ``insert(prefix, slot)`` — splice a batch-1 cache tree into the
    batched decode state (launch/specs.py helpers) and mirror it into
    the physical paged pool (offline prefix path; fused rows are
    already resident).
  * ``collect(step)`` — the host sync point: pull sampled tokens, fold
    stats, apply the paged-mirror delta. (``dispatch_decode`` served
    its deprecation cycle and is gone — ``step_batch([])`` is the
    decode-only dispatch.)
  * ``free_slot(slot)`` — release the slot and reclaim its pool pages.

The legacy fixed-slot loop (``add_request``/``step``/``run``) is kept as a
thin layer over that API. The ``verify_paged()`` method recomputes one
layer's decode attention from the *physical pool* via the paged_decode
Pallas kernel and asserts it matches the logical path — the systems-level
correctness check that theoretical paging actually serves the right bytes.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from jax.sharding import Mesh

from repro.analysis.contracts import hot_path
from repro.configs.base import ModelConfig
from repro.core.dual_cache import DualCache
from repro.launch.specs import (alloc_batched_caches, build_decode_caches,
                                cache_tree_bytes, extract_slot_caches)
from repro.models import inference as I
from repro.serving import paged
from repro.serving.backend import (BackendCapabilities, FusedStep,  # noqa: F401,E501
                                   InflightStep, Prefix, PrefillTask)
from repro.serving.obs.trace import NULL_TRACER
from repro.serving.sampling import sample
from repro.serving.sharded import ShardedDecodeMixin


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class Engine(ShardedDecodeMixin):
    """Batched serving backend (slots = max concurrent decodes).

    Implements the :class:`repro.serving.backend.EngineBackend` protocol
    for the paper's write-gated dual cache. With ``mesh`` set (a
    ("data", "model") :class:`jax.sharding.Mesh`), params are placed
    model-parallel, the batched slot state shards rows over "data" and KV
    heads over "model", and every jitted decode/extend runs as one SPMD
    step over the mesh (serving/sharded.py)."""

    def __init__(self, params, cfg: ModelConfig, *, slots: int = 4,
                 capacity: int = 4096, opts: Optional[I.DecodeOptions] = None,
                 pool_pages: int = 4096, eos: Optional[int] = None,
                 temperature: float = 0.0, seed: int = 0,
                 mirror_paged: bool = True, mesh: Optional[Mesh] = None):
        assert cfg.has_attention_cache, "engine serves KV-cache archs"
        self.cfg = cfg
        self.slots = slots
        self.capacity = capacity
        self.opts = opts or I.DecodeOptions()
        self.eos = eos
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)
        self.requests: Dict[int, Request] = {}
        self.slot_rid: List[Optional[int]] = [None] * slots
        self._next_rid = 0
        self.caches = None
        self.live: List[bool] = [False] * slots
        # host view of each row's newest token (telemetry / invariants);
        # the authoritative decode feed is the DEVICE vector `_tok_dev`,
        # which dispatch-ahead keeps one or more steps ahead of this list
        self.last_token: List[int] = [0] * slots
        # bumped on every insert/free so collect() can tell whether a slot
        # still belongs to the request a step was dispatched for
        self._slot_gen: List[int] = [0] * slots
        self.mirror = mirror_paged
        if mirror_paged:
            self.pool = paged.PagedKVPool(pool_pages, cfg.head_dim)
        # decode-time page selection: the engine's base opts run the full
        # path (prefill chunks and mixed ticks must see every admitted
        # token); the policy compiles into a SECOND fused-step variant
        # dispatched only on decode-only ticks
        self.selection = self.opts.selection_policy
        self._sel_k = I.parse_selection_policy(self.selection)  # validates
        if self.selection is not None:
            self.opts = dataclasses.replace(self.opts, selection_policy=None)
        self.params = self._sharding_setup(params, mesh)
        self._extend_batch = self._make_extend_batch()
        self._fused = self._make_fused_step()
        self._fused_sel = None if self.selection is None \
            else self._make_fused_step(
                dataclasses.replace(self.opts,
                                    selection_policy=self.selection),
                kind="fused_step_sel")
        self._tok_dev = jnp.zeros((slots,), jnp.int32)
        # fused path: which rows of the persistent batched tree hold a
        # mid-prefill task's state (spliced empty at its first step_batch)
        self._resident: List[bool] = [False] * slots
        self._empty_tree = None
        # host cache of per-row resident KV tokens: computed IN-JIT by the
        # fused step (stats["kv_tokens_rows"]) and refreshed at collect's
        # one sync — memory_snapshot reads this instead of pulling device
        # counters on the metrics path
        self._kv_rows = np.zeros((slots,), np.float64)
        # prefix-cache adoption bookkeeping: the CachedPrefix a row was
        # seeded from (drives the suffix-only pool mirror at finish) and
        # whether an eviction trigger fired since the row opened (eviction
        # compacts/reorders the global cache, forcing the full re-mirror)
        self._slot_prefix: List[Optional[object]] = [None] * slots
        self._slot_evicted: List[bool] = [False] * slots
        self.stats = {"steps": 0, "evict_triggers": 0.0, "decode_adm_sum": 0.0,
                      # extend-phase advances only (the path batching
                      # coalesces): wall time is a true device measure
                      # because _extend_ragged syncs on the step's stats
                      # before returning
                      "extend_time_s": 0.0, "extend_tokens": 0.0,
                      # fused megabatch ticks: dispatch->collect wall per
                      # step, plus the prefill-stage share (steps carrying
                      # at least one prompt chunk, and the chunk tokens
                      # they advanced) so bench can report a compile-free
                      # fused prefill-stage tokens/s
                      "fused_steps": 0.0, "fused_time_s": 0.0,
                      "fused_prefill_time_s": 0.0,
                      "fused_prefill_tokens": 0.0,
                      # fixed-shape padding accounting: every fused
                      # dispatch pays for ``slots`` rows whatever their
                      # length; 1 - active/slot rows is the padding
                      # fraction bench reports so the CPU-XLA stage
                      # ratios are interpretable
                      "fused_slot_rows": 0.0, "fused_active_rows": 0.0,
                      # decode-time page selection: pages gathered (mean
                      # over kv heads, summed over attention layers and
                      # decode row-steps) and the wall time of
                      # selection-enabled fused steps
                      "selected_pages": 0.0, "selection_time_s": 0.0}
        # observability handle; the Orchestrator overwrites this with its
        # own tracer so engine-side sub-phase spans share its timeline
        self.tracer = NULL_TRACER

    # ------------------------------------------------------------------
    # EngineBackend protocol: descriptor + memory telemetry
    # ------------------------------------------------------------------
    def capabilities(self) -> BackendCapabilities:
        return BackendCapabilities(
            name="wgkv", gated=True, paged=self.mirror,
            description="write-gated dual cache (learned admission)",
            sharded=self.mesh is not None, selection=self.selection)

    # the fused tick's declared compiled-shape budget — PR 7/8's "exactly
    # three compiled shapes" as data: the base fused step compiles
    # (slots, chunk) for prefill-carrying ticks and (slots, 1) for
    # decode-only ticks; the selection variant compiles (slots, 1) only.
    # analysis.CompileSentinel asserts the jit caches stay within this
    # over a replay; the legacy synchronous extend path ("extend_batch")
    # is per-batch-width by design and carries no budget.
    COMPILE_SHAPE_BUDGETS: Dict[str, int] = {
        "fused_step": 2,
        "fused_step_sel": 1,
    }

    def compiled_shape_counts(self) -> Dict[str, int]:
        """Jit-cache entry count per step kind: ``_cache_size()`` of the
        plain jits when unmeshed, ``_fn_cache`` entries per kind under a
        mesh (each memoized entry is one compiled structure)."""
        out: Dict[str, int] = {}
        for kind, fn in (("extend_batch", self._extend_batch),
                         ("fused_step", self._fused),
                         ("fused_step_sel", self._fused_sel)):
            if fn is None:
                continue
            size = getattr(fn, "_cache_size", None)
            out[kind] = (int(size()) if size is not None else
                         sum(1 for k in self._fn_cache if k and k[0] == kind))
        return out

    @hot_path
    def memory_snapshot(self) -> Dict[str, float]:
        """Point-in-time memory telemetry: resident logical KV tokens/bytes
        over live slots, plus physical pool occupancy when mirroring and
        per-shard KV bytes when meshed.

        Reads HOST state only: the per-row token counts are computed
        in-jit by the fused step (``stats["kv_tokens_rows"]``) and cached
        at collect's designated sync (``insert`` seeds its slot the same
        way), so sampling memory every tick never pulls device counters
        inside the dispatch-ahead window."""
        snap: Dict[str, float] = {}
        if self.mirror:
            snap["pool_pages"] = float(self.pool.pages_in_use)
            snap["pool_util"] = float(self.pool.utilization())
        live = [s for s in range(self.slots) if self.live[s]]
        toks = float(self._kv_rows[live].sum()) if live else 0.0
        snap["kv_tokens"] = float(toks)
        snap["kv_bytes"] = float(
            toks * 2 * self.cfg.head_dim * jnp.dtype(self.cfg.dtype).itemsize)
        return self._per_shard_snapshot(snap, self._snapshot_leaf())

    def _snapshot_leaf(self):
        """A representative batched cache leaf whose sharding metadata
        gives the per-device KV fraction (no device sync)."""
        if self.caches is None:
            return None
        blocks = self.caches["blocks"]
        for i in range(len(self.cfg.block_pattern)):
            node = blocks[f"b{i}"]
            if isinstance(node, dict) and "self" in node:
                node = node["self"]
            if isinstance(node, DualCache):
                return node.gk
        return None

    def _kv_tokens_device(self, caches) -> jax.Array:
        """[B] resident KV token count per row, computed from device
        values WITHOUT syncing (traced inside the fused step): per layer,
        admitted global entries summed over kv heads plus the filled ring
        window per head — the same accounting memory_snapshot reported
        when it pulled these counters itself."""
        total = None
        for _, dc in self._iter_dual(caches):
            per = (dc.gcnt.sum(axis=1)
                   + jnp.minimum(dc.t, dc.w_local) * dc.gcnt.shape[1])
            total = per if total is None else total + per
        if total is None:
            b = int(np.shape(caches["t"])[0])
            return jnp.zeros((b,), jnp.int32)
        return total.astype(jnp.int32)

    # ------------------------------------------------------------------
    # JetStream-style backend API: chunked prefill
    # ------------------------------------------------------------------
    @property
    def _w_align(self) -> int:
        """Prefill chunk alignment: the largest ring window in the model."""
        w = self.cfg.wgkv.w_local
        if any(bt == "local_attn"
               for bt in self.cfg.block_pattern + self.cfg.stem_pattern):
            w = max(w, self.cfg.sliding_window)
        return w

    def start_prefill(self, prompt: List[int]) -> PrefillTask:
        return PrefillTask(prompt=list(prompt))

    def _fresh_task_caches(self):
        """Batch-1 EMPTY decode-cache tree: the state a prefill row starts
        from before its first token. Cached — jax arrays are immutable, so
        one template serves every unfused short-prompt open and every
        fused row splice."""
        if self._empty_tree is None:
            self._empty_tree = self._build_empty_caches()
        return self._empty_tree

    def _build_empty_caches(self):
        caches = build_decode_caches(
            self.cfg, 1, self.capacity, use_wgkv=True, prefilled=0)
        if self.opts.evict_hard_budget is not None:
            caches["obs"] = I._init_obs_tree(self.cfg, 1, self.opts)
        return caches

    @hot_path
    def _extend_ragged(self, tasks: List[PrefillTask],
                       max_tokens: Optional[int]) -> None:
        """ONE batched ragged extend for every mid-prefill task. ``S`` is
        pinned to ``max_tokens`` when chunked, and rounded up to a
        ``w_align`` multiple when unchunked — one compiled shape per
        batch width instead of one per distinct tail length; rows whose
        remaining prompt is shorter are masked padding past their
        length. At B = 1 the task's own batch-1 tree feeds the scan
        directly — no stack/unstack round trip."""
        t_wall = time.perf_counter()
        takes = [len(t.prompt) - t.pos if max_tokens is None
                 else min(len(t.prompt) - t.pos, max_tokens) for t in tasks]
        if max_tokens is None:
            q = self._w_align
            s = -(-max(takes) // q) * q
        else:
            s = max_tokens
        b = len(tasks)
        toks = np.zeros((b, s), np.int32)
        for i, (t, take) in enumerate(zip(tasks, takes)):
            toks[i, :take] = t.prompt[t.pos:t.pos + take]
        batched = tasks[0].caches if b == 1 \
            else self.batched_prefill_stack([t.caches for t in tasks])
        with self.tracer.span("prefill_extend_ragged", batch=b, s=s,
                              tokens=int(sum(takes))):
            logits, batched, st = self._extend_batch(
                self.params,
                (jnp.asarray(toks), jnp.asarray(takes, jnp.int32)), batched)
            outs = (batched,) if b == 1 \
                else self.batched_prefill_unstack(batched, b)
            trig, adm = jax.device_get(  # jaxlint: allow-sync(synchronous extend path - the sync IS the extend_time_s measure)
                (st["evict_trigger_rows"], st["adm_sum_rows"]))
        # the device_get above blocked on the extend, so this wall delta
        # is a true device+host measure of the coalesced advance — the
        # batched-vs-per-request axis bench_serving's speedup rides on
        self.stats["extend_time_s"] += time.perf_counter() - t_wall
        self.stats["extend_tokens"] += float(sum(takes))
        self.stats["evict_triggers"] += float(trig.sum())
        for i, (t, take) in enumerate(zip(tasks, takes)):
            t.caches = outs[i]
            t.last_logits = logits[i:i + 1]
            t.adm_weighted += self._extend_admission(
                adm[i], take, full=(max_tokens is not None
                                    and take == max_tokens))
            t.pos += take

    def _extend_admission(self, adm_sum, take: int, full: bool) -> float:
        """Admission mass one ragged extend adds to a task's
        ``adm_weighted``, mirroring the sequential accounting: a full
        chunk records mean * take (float32 mean, like the device scan's),
        a ragged tail the raw per-step sum."""
        if full:
            return float(np.float32(adm_sum) / np.float32(take)) * take
        return float(adm_sum)

    def finish_prefill(self, task: PrefillTask, *,
                       emit_first: bool = True) -> Prefix:
        """Seal a completed prefill task into a Prefix. With
        ``emit_first`` the first generated token is sampled from the
        prefill's own last-position logits (JetStream semantics: prefill
        returns the first token, so streaming TTFT ends at prefill, not at
        the next batched decode). The prefill paths already computed those
        logits, so no extra decode step runs — the old convention of
        re-feeding ``prompt[-1]`` wrote a duplicate KV entry at position n
        and shifted every later position by one."""
        assert task.done, "prefill task not finished"
        assert task.last_logits is not None, "prefill produced no logits"
        adm = task.adm_weighted / max(task.pos, 1)
        prefix = Prefix(caches=task.caches, prompt_len=len(task.prompt),
                        mean_admission=adm)
        if emit_first:
            self.key, sk = jax.random.split(self.key)
            prefix.first_token = int(
                sample(sk, task.last_logits, temperature=self.temperature)[0])
            prefix.first_logits = task.last_logits[0]
        return prefix

    def prefill(self, prompt: List[int], *,
                chunk_tokens: Optional[int] = None,
                emit_first: bool = True) -> Prefix:
        """One-shot convenience wrapper: drive one task's whole prompt
        through the batched ragged extend (``chunk_tokens`` sets the
        chunk width; None ingests the remaining prompt in one aligned
        call). Offline/eval surface — serving traffic rides the fused
        :meth:`step_batch`, which shares the identical per-token scan."""
        if chunk_tokens is not None and chunk_tokens < 1:
            raise ValueError(f"chunk_tokens must be >= 1, got {chunk_tokens}")
        task = self.start_prefill(prompt)
        task.caches = self._fresh_task_caches()
        while task.pos < len(task.prompt):
            self._extend_ragged([task], chunk_tokens)
        return self.finish_prefill(task, emit_first=emit_first)

    # ------------------------------------------------------------------
    # JetStream-style backend API: insert / dispatch-collect / free
    # ------------------------------------------------------------------
    def insert(self, prefix: Prefix, slot: int) -> None:
        """Splice a prefix's caches into batch row ``slot`` (device-put
        onto the mesh when sharded) and mirror it into the physical paged
        pool."""
        if self.caches is None:
            self.caches = self.place_caches(
                alloc_batched_caches(prefix.caches, self.slots))
        self.caches = self.sharded_splice(self.caches, prefix.caches, slot)
        self.live[slot] = True
        self._slot_gen[slot] += 1
        tok = prefix.first_token if prefix.first_token is not None else 0
        self.last_token[slot] = tok
        self._tok_dev = self._tok_dev.at[slot].set(tok)
        # seed the host kv accounting (insert is a sanctioned sync point;
        # fused rows are refreshed by every collect instead)
        self._kv_rows[slot] = float(jax.device_get(
            self._kv_tokens_device(prefix.caches))[0])
        if self.mirror:
            self._mirror_prefill(slot, prefix.caches)

    # ------------------------------------------------------------------
    # fused megabatch tick: ONE jitted ragged call per dispatched step
    # ------------------------------------------------------------------
    @hot_path
    def step_batch(self, tasks: List[PrefillTask],
                   max_tokens: Optional[int] = None, *,
                   decode: bool = True) -> Optional[FusedStep]:
        """Dispatch ONE fused jitted ragged step advancing every live row
        of the persistent batched cache tree — prefill chunks and decode
        tokens together — without synchronizing.

        Each ``task`` must carry its reserved ``slot``. A task seen for
        the first time has an EMPTY batch-1 tree spliced into its row
        (per-row ``t`` offsets mean the ragged scan simply starts it at
        position 0 — there is no separately-compiled batch-1 open); a
        mid-prefill row takes up to ``max_tokens`` of its remaining
        prompt; with ``decode`` every live slot not taking a chunk joins
        as a length-1 row fed from the ON-DEVICE sampled-token vector;
        all other rows are length-0 padding kept bit-identical by the
        scan's per-leaf masked writes. Sampling runs inside the same
        jitted call, so a finishing row's first generated token and every
        decode row's next token come back together from :meth:`collect`.

        Host state advances at dispatch (teacher-forced positions; a
        finishing row goes live immediately) so a second fused step can
        be dispatched behind this one (dispatch-ahead depth >= 1). With
        ``DecodeOptions.selection_policy`` set, a task-less dispatch
        runs the gathered top-K page-selection variant of the same
        compiled step (full-path parity at K >= resident pages). At most
        three compiled shapes exist per engine: ``[slots, chunk]``,
        ``[slots, 1]``, and the selection ``[slots, 1]``. Returns None
        when nothing can advance."""
        if max_tokens is not None and max_tokens < 1:
            raise ValueError(f"max_tokens must be >= 1, got {max_tokens}")
        tasks = [t for t in tasks if not t.done]
        if not tasks and not (decode and any(self.live)):
            return None
        t0 = time.perf_counter()
        if self.caches is None:
            self.caches = self.place_caches(
                alloc_batched_caches(self._fresh_task_caches(), self.slots))
        for t in tasks:
            assert t.slot is not None, "fused step_batch needs slot-bound tasks"
            assert not self.live[t.slot], "prefill task in a live decode row"
            if not self._resident[t.slot]:
                if t.prefix_entry is not None:
                    # prefix-cache hit: splice the cached (already
                    # gate-filtered) tree instead of the empty template —
                    # the row's per-cache ``t`` makes the ragged scan
                    # resume at the suffix, skipping the re-prefill
                    with self.tracer.span("prefix_splice", slot=t.slot,
                                          tokens=t.prefix_entry.n_tokens):
                        self.caches = self.sharded_splice(
                            self.caches, t.prefix_entry.caches, t.slot)
                    self._adopt_prefix(t.slot, t.prefix_entry)
                else:
                    # first-chunk open: splice the empty template into the
                    # row (a dynamic-update-slice, not a model call — the
                    # chunk itself runs through the same fused scan below)
                    with self.tracer.span("fused_open", slot=t.slot):
                        self.caches = self.sharded_splice(
                            self.caches, self._fresh_task_caches(), t.slot)
                    self._slot_prefix[t.slot] = None
                self._resident[t.slot] = True
                self._slot_gen[t.slot] += 1
        # ragged feed: prompt chunks left-aligned per row; S pinned to the
        # chunk width (or w-aligned when unchunked) for compile stability
        takes = [len(t.prompt) - t.pos if max_tokens is None
                 else min(len(t.prompt) - t.pos, max_tokens) for t in tasks]
        if not tasks:
            s = 1
        elif max_tokens is None:
            q = self._w_align
            s = -(-max(takes) // q) * q
        else:
            s = max_tokens
        toks = np.zeros((self.slots, s), np.int32)
        lengths = np.zeros((self.slots,), np.int32)
        use_dev = np.zeros((self.slots,), bool)
        for t, take in zip(tasks, takes):
            toks[t.slot, :take] = t.prompt[t.pos:t.pos + take]
            lengths[t.slot] = take
        decode_rows = tuple(sl for sl in range(self.slots)
                            if decode and self.live[sl] and lengths[sl] == 0)
        for sl in decode_rows:
            lengths[sl] = 1
            use_dev[sl] = True
        # a dead row decodes masked but still feeds its last_token; a
        # nonzero token there is a missed free_slot reset (a stale replay
        # of the retired request's final token)
        assert all(self.last_token[sl] == 0 for sl in range(self.slots)
                   if not self.live[sl] and lengths[sl] == 0), \
            "stale last_token on a dead row"
        self._pre_fused_dispatch(
            [(t.slot, take) for t, take in zip(tasks, takes)], decode_rows)
        # fixed-shape padding accounting: the compiled step always spans
        # ``slots`` rows; only length>0 rows do real work
        self.stats["fused_slot_rows"] += float(self.slots)
        self.stats["fused_active_rows"] += float(int((lengths > 0).sum()))
        # decode-only ticks run the gathered top-K selection variant when
        # configured; any prompt chunk aboard forces the full path (its
        # decode rows ride that mixed call with full attention)
        use_sel = self._fused_sel is not None and not tasks
        self.key, sk = jax.random.split(self.key)
        before = self.caches
        mirror = self.mirror
        feed = (jnp.asarray(toks), jnp.asarray(lengths), self._tok_dev,
                jnp.asarray(use_dev), sk[None])
        with self.tracer.device_scope("fused_step"):
            if use_sel:
                with self.tracer.span("selection", k=self._sel_k,
                                      rows=len(decode_rows)):
                    _logits, self.caches, st = self._fused_sel(
                        self.params, feed, before)
            else:
                _logits, self.caches, st = self._fused(
                    self.params, feed, before)
        sampled = st["sampled"]
        # host bookkeeping at dispatch (teacher-forced, deterministic):
        # advance positions; a finishing row goes live NOW so the next
        # dispatched step can already decode it
        finishing = []
        for t, take in zip(tasks, takes):
            t.pos += take
            fin = t.pos >= len(t.prompt)
            finishing.append(fin)
            if fin:
                self.live[t.slot] = True
        # only rows that really sampled this step (decode rows + finishing
        # prefill rows) update the device feed; a masked/mid-prefill row's
        # sampled value is garbage and must not clobber its feed token
        fed = np.zeros((self.slots,), bool)
        for sl in decode_rows:
            fed[sl] = True
        for t, fin in zip(tasks, finishing):
            fed[t.slot] = fin
        self._tok_dev = jnp.where(jnp.asarray(fed), sampled, self._tok_dev)
        fulls = [max_tokens is not None and take == max_tokens
                 for take in takes]
        return FusedStep(
            tokens=sampled, stats=st,
            before=before if mirror else None,
            # ``after`` is kept unconditionally (a tree of references, no
            # device copy): prefix capture snapshots it at collect even
            # when the host paged mirror is off (timed/meshed engines)
            after=self.caches,
            live=tuple(self.live), gen=tuple(self._slot_gen),
            tasks=tuple(tasks), takes=tuple(takes), fulls=tuple(fulls),
            finishing=tuple(finishing), decode_rows=decode_rows,
            had_prefill=bool(tasks), t_dispatch=t0, selection=use_sel)

    def _pre_fused_dispatch(self, prefill: List[Tuple[int, int]],
                            decode_rows: Tuple[int, ...]) -> None:
        """Hook before a fused dispatch: ``prefill`` is [(slot, take)].
        DenseEngine uses it for host-side slot-length tracking and the
        capacity overflow guard; the dual cache never overflows (ring
        wraps, global is budget-bounded)."""

    @hot_path
    def _collect_fused(self, step: FusedStep) -> Dict[int, int]:
        """Collect one fused step: ONE host sync pulls sampled tokens and
        per-row stats; fold admission/eviction accounting, mirror
        finishing rows' full prefixes and decode rows' deltas into the
        paged pool, and return {slot: token} — decode tokens plus the
        FIRST tokens of rows whose prompt completed in this step. The
        per-slot generation guard drops rows freed (or freed and
        re-opened) while the step was in flight."""
        assert not step.collected, "in-flight step collected twice"
        step.collected = True
        nxt, trig, adm, selp, kvr = jax.device_get(  # jaxlint: allow-sync(collect is THE designated sync point of the dispatch/collect contract)
            (step.tokens, step.stats["evict_trigger_rows"],
             step.stats["adm_sum_rows"],
             step.stats["selected_pages_rows"],
             step.stats["kv_tokens_rows"]))
        # refresh the host kv accounting memory_snapshot reads (rows whose
        # slot churned while the step was in flight keep their newer value)
        for sl in range(self.slots):
            if self._slot_gen[sl] == step.gen[sl]:
                self._kv_rows[sl] = float(kvr[sl])
            if trig[sl] > 0:
                # SnapKV eviction compacts/reorders the row's global cache:
                # a prefix-hit row can no longer take the suffix-only
                # mirror at finish
                self._slot_evicted[sl] = True
        # the device_get blocked on the fused call, so this wall delta is
        # a true device+host measure of the whole dispatched step
        wall = time.perf_counter() - step.t_dispatch
        self.stats["fused_steps"] += 1
        self.stats["fused_time_s"] += wall
        if step.had_prefill:
            self.stats["fused_prefill_time_s"] += wall
            self.stats["fused_prefill_tokens"] += float(sum(step.takes))
        if step.selection:
            self.stats["selection_time_s"] += wall
            if step.decode_rows:
                self.stats["selected_pages"] += float(
                    selp[list(step.decode_rows)].sum())
        self.stats["evict_triggers"] += float(trig.sum())
        # prefill-row admission: same float path as the unfused extend
        for t, take, full in zip(step.tasks, step.takes, step.fulls):
            t.adm_weighted += self._extend_admission(adm[t.slot], take,
                                                     full=full)
        if step.decode_rows:
            self.stats["steps"] += 1
            # a decode row has exactly one real position, so its ragged
            # adm SUM is that step's per-row mean admission
            self.stats["decode_adm_sum"] += self._decode_admission(
                {"mean_admission": adm}, list(step.decode_rows))
        rows = [s for s in step.decode_rows
                if self.live[s] and self._slot_gen[s] == step.gen[s]]
        if self.mirror and step.before is not None:
            for t, fin in zip(step.tasks, step.finishing):
                if fin and self._slot_gen[t.slot] == step.gen[t.slot]:
                    # prompt complete: mirror the resident prefix (the
                    # fused analogue of insert's mirror). A prefix-hit row
                    # already aliases the entry's pool pages, so only the
                    # suffix is mirrored — unless an eviction compacted
                    # the global cache, which forces the full re-sync.
                    entry = self._slot_prefix[t.slot]
                    sc = extract_slot_caches(step.after, t.slot)
                    if entry is not None and not self._slot_evicted[t.slot]:
                        self._mirror_prefill_suffix(t.slot, sc, entry)
                    else:
                        self._mirror_prefill(t.slot, sc)
            if rows:
                self._mirror_decode(step.before, step.after, rows=rows,
                                    evicted_rows=trig > 0)
        out: Dict[int, int] = {}
        for t, fin in zip(step.tasks, step.finishing):
            if fin and self._slot_gen[t.slot] == step.gen[t.slot]:
                tok = int(nxt[t.slot])
                self.last_token[t.slot] = tok
                out[t.slot] = tok
        for s in rows:
            tok = int(nxt[s])
            self.last_token[s] = tok
            out[s] = tok
        return out

    # ------------------------------------------------------------------
    # collect: the host sync point of the two-phase dispatch contract
    # ------------------------------------------------------------------
    @hot_path
    def collect(self, step: FusedStep) -> Dict[int, int]:
        """Synchronize one in-flight fused step: pull its sampled tokens
        to host, fold eviction/admission/selection stats, and apply the
        cache delta to the paged mirror. Returns {slot: token} for every
        slot still owned by the request the step was dispatched for — a
        slot freed (or freed + re-inserted) while the step was in flight
        is skipped, so a cancelled request can never leak its token into
        a successor and the mirror never resurrects freed pool streams.
        (The unfused ``dispatch_decode`` step kind served its
        deprecation cycle and is gone; every step is a
        :class:`FusedStep` now.)"""
        return self._collect_fused(step)

    def _decode_admission(self, st, live_rows: List[int]) -> float:
        """Mean write-gate admission over live rows for one decode step."""
        adm_rows = np.asarray(st["mean_admission"])
        return float(adm_rows[live_rows].mean())

    def free_slot(self, slot: int) -> None:
        """Retire a slot: stop decoding it and reclaim its pool pages.
        Safe to call with steps in flight: the generation bump makes
        :meth:`collect` discard the dead row's token and skip its mirror
        delta, so the pages freed here stay freed."""
        self.live[slot] = False
        self._resident[slot] = False
        self._slot_gen[slot] += 1
        # a retired row keeps decoding (masked) in the batched step; zero
        # its token so the dead row never replays its final token
        self.last_token[slot] = 0
        self._tok_dev = self._tok_dev.at[slot].set(0)
        self._kv_rows[slot] = 0.0
        self._slot_prefix[slot] = None
        self._slot_evicted[slot] = False
        if self.mirror and self.caches is not None:
            for lkey, _ in self._iter_dual(self.caches):
                for h in range(self.cfg.n_kv_heads):
                    # pages shared with a prefix-store entry are only
                    # dereferenced here; the entry's own refs keep them
                    self.pool.free_stream((slot, lkey, h, "global"))
                    self.pool.free_stream((slot, lkey, h, "local"))

    # ------------------------------------------------------------------
    # content-addressed prefix store hooks (serving/prefix_cache.py)
    # ------------------------------------------------------------------
    @hot_path
    def _adopt_prefix(self, slot: int, entry) -> None:
        """Host-side adoption of a cached prefix into a freshly spliced
        row: alias the entry's pool pages into the slot's streams (incref
        only — copy-on-write unshares any page either side later writes,
        so a hit can never alias mutable decode state) and seed the host
        kv accounting. Runs inside the fused dispatch, so it is hot-path
        code: pure host bookkeeping, never a device sync."""
        self._slot_prefix[slot] = entry
        self._slot_evicted[slot] = False
        self._kv_rows[slot] = float(entry.kv_tokens)
        if self.mirror:
            for skey in entry.stream_keys:
                # ("pfx", key, lkey, h, region) -> (slot, lkey, h, region)
                dst = (slot,) + skey[2:]
                self.pool.free_stream(dst)
                self.pool.share_stream(skey, dst)

    def capture_prefix(self, step: FusedStep, slot: int, key: str, *,
                       adm_weighted: float = 0.0):
        """Freeze row ``slot`` of a collected step into a shareable
        :class:`~repro.serving.prefix_cache.CachedPrefix`: the batch-1
        device tree (immutable — later dispatches update the batched tree
        functionally and cannot disturb it), the per-layer host counters
        the suffix mirror needs, and — when mirroring — entry-owned pool
        streams holding the post-admission bytes, ready to be aliased
        into a hitting slot with zero copies.

        A sanctioned sync point (:data:`SyncSentinel.SANCTIONED`): the
        per-layer pulls run once per unique prefix, off the dispatch
        window, exactly like insert's mirror."""
        from repro.serving.prefix_cache import CachedPrefix
        caches = extract_slot_caches(step.after, slot)
        meta: Dict[Tuple, Dict] = {}
        stream_keys: List[Tuple] = []
        kv_tokens = 0
        n_tokens = 0
        pool_pages = 0
        for lkey, dc in self._iter_dual(caches):
            hdc = jax.device_get(dc)          # batch-1: one pull per layer
            n_tokens = int(hdc.t[0])
            n_local = min(n_tokens, dc.w_local)
            gcnt = np.asarray(hdc.gcnt[0], np.int64)        # [H]
            meta[lkey] = {"gcnt": gcnt, "n_local": n_local}
            kv_tokens += int(gcnt.sum()) + n_local * gcnt.shape[0]
            if not self.mirror:
                continue
            for h in range(self.cfg.n_kv_heads):
                gkey = ("pfx", key, lkey, h, "global")
                self.pool.free_stream(gkey)
                cnt = int(gcnt[h])
                self.pool.bulk_append(
                    gkey, np.asarray(hdc.gk[0, h, :cnt], np.float32),
                    np.asarray(hdc.gv[0, h, :cnt], np.float32))
                lkey_ = ("pfx", key, lkey, h, "local")
                self.pool.free_stream(lkey_)
                self.pool.bulk_append(
                    lkey_, np.asarray(hdc.lk[0, h, :n_local], np.float32),
                    np.asarray(hdc.lv[0, h, :n_local], np.float32))
                stream_keys += [gkey, lkey_]
                pool_pages += len(self.pool.table(gkey).pages)
                pool_pages += len(self.pool.table(lkey_).pages)
        n_bytes = cache_tree_bytes(caches) + \
            pool_pages * paged.PAGE_SIZE * self.cfg.head_dim * 2 * 4
        return CachedPrefix(key=key, n_tokens=n_tokens, caches=caches,
                            adm_weighted=adm_weighted, meta=meta,
                            kv_tokens=kv_tokens, n_bytes=n_bytes,
                            stream_keys=tuple(stream_keys))

    def release_prefix(self, entry) -> None:
        """Free an evicted store entry's pool streams. Pages a live slot
        still shares survive via their per-page refcounts."""
        if self.mirror:
            for skey in entry.stream_keys:
                self.pool.free_stream(skey)

    def _mirror_prefill_suffix(self, slot: int, caches, entry) -> None:
        """Mirror only the tokens a prefix-hit row appended past the
        cached boundary: global entries grown beyond the entry's per-head
        counts are appended, and only the ring slots positions
        ``[n_tokens, t)`` touched are written (copy-on-write unshares any
        page the entry still references). The full :meth:`_mirror_prefill`
        re-sync handles the eviction fallback upstream."""
        t0 = entry.n_tokens
        for lkey, dc in self._iter_dual(caches):
            hdc = jax.device_get(dc)
            t1 = int(hdc.t[0])
            w = dc.w_local
            len0, len1 = min(t0, w), min(t1, w)
            touched = (set(range(len1)) if t1 - t0 >= w
                       else {p % w for p in range(t0, t1)})
            grow = list(range(len0, len1))
            over = sorted(touched.difference(grow))
            gcnt0 = entry.meta[lkey]["gcnt"]
            for h in range(self.cfg.n_kv_heads):
                c0, c1 = int(gcnt0[h]), int(hdc.gcnt[0, h])
                assert c1 >= c0, \
                    "global cache shrank without an eviction trigger"
                if c1 > c0:
                    self.pool.bulk_append(
                        (slot, lkey, h, "global"),
                        np.asarray(hdc.gk[0, h, c0:c1], np.float32),
                        np.asarray(hdc.gv[0, h, c0:c1], np.float32))
                lkey_ = (slot, lkey, h, "local")
                for i in grow:
                    self.pool.append(
                        lkey_, np.asarray(hdc.lk[0, h, i], np.float32),
                        np.asarray(hdc.lv[0, h, i], np.float32))
                for i in over:
                    self.pool.overwrite(
                        lkey_, i, np.asarray(hdc.lk[0, h, i], np.float32),
                        np.asarray(hdc.lv[0, h, i], np.float32))

    # ------------------------------------------------------------------
    # paged-pool mirroring
    # ------------------------------------------------------------------
    def _mirror_prefill(self, slot: int, caches) -> None:
        """Copy the logical dual caches into the physical paged pool.

        Ring pages are allocated lazily: before the ring wraps only slots
        ``0..t-1`` hold tokens (slot = pos % W), so a short prompt mirrors
        ``min(t, W)`` tokens instead of the full ring — `_mirror_decode`
        grows the stream page-by-page until the wrap. The batch-1 prefix
        is pulled to host in one transfer per layer (under a mesh,
        per-head slicing would issue a cross-shard gather per vector)."""
        for lkey, dc in self._iter_dual(caches):
            hdc = jax.device_get(dc)          # batch-1: one pull per leaf
            n_local = min(int(hdc.t[0]), dc.w_local)
            for h in range(self.cfg.n_kv_heads):
                gkey = (slot, lkey, h, "global")
                self.pool.free_stream(gkey)
                cnt = int(hdc.gcnt[0, h])
                self.pool.bulk_append(
                    gkey, np.asarray(hdc.gk[0, h, :cnt], np.float32),
                    np.asarray(hdc.gv[0, h, :cnt], np.float32))
                lkey_ = (slot, lkey, h, "local")
                self.pool.free_stream(lkey_)
                self.pool.bulk_append(
                    lkey_, np.asarray(hdc.lk[0, h, :n_local], np.float32),
                    np.asarray(hdc.lv[0, h, :n_local], np.float32))

    def _iter_dual(self, caches) -> List[Tuple[Tuple, DualCache]]:
        """Yield (layer-key, DualCache[batch=...]) pairs from a cache tree."""
        out = []
        blocks = caches["blocks"]
        for i, bt in enumerate(self.cfg.block_pattern):
            node = blocks[f"b{i}"]
            if isinstance(node, dict) and "self" in node:
                node = node["self"]
            if isinstance(node, DualCache):
                for r in range(node.gk.shape[0] if node.gk.ndim == 5 else 1):
                    if node.gk.ndim == 5:  # stacked [n_repeats, B, ...]
                        out.append(((r, i), jax.tree.map(lambda x: x[r], node)))
                    else:
                        out.append(((0, i), node))
        return out

    def _mirror_decode(self, before, after, *,
                       rows: Optional[List[int]] = None,
                       evicted_rows: Optional[np.ndarray] = None) -> None:
        """Apply one decode step's logical cache delta to the pool.

        ``rows`` limits the mirror to those slot rows (collect passes the
        rows still owned by the request the step was dispatched for —
        mirroring a freed or re-inserted row would resurrect freed pool
        streams or corrupt the successor's); None mirrors all live rows.

        ``evicted_rows`` ([slots] bool) marks rows whose jitted decode
        reported a SnapKV eviction trigger: eviction compacts and reorders
        the logical global cache, so that row's shrunken/unchanged streams
        are re-synced NOW — freed physical pages return to the allocator
        at eviction time instead of lingering until the slot's next
        insert. A stream that *grew* (ca > cb) cannot have evicted this
        step, so the cheap append path still applies to it.

        Device -> host traffic is bounded per layer regardless of
        slots/heads: only the requested slot rows are gathered, and only
        the vectors the step can have written (the ring slot at each
        row's pre-step pointer, the newest global entry per head, and —
        only on an eviction trigger — that row's compacted global
        streams). Under a mesh the batched tree is spread over devices,
        so per-vector slicing would otherwise issue a cross-shard
        transfer each."""
        if rows is None:
            rows = [s for s in range(self.slots) if self.live[s]]
        if not rows:
            return
        ridx = jnp.asarray(rows, jnp.int32)
        ev_rows = [s for s in rows
                   if evicted_rows is not None and bool(evicted_rows[s])]
        for (lkey, dcb), (_, dca) in zip(self._iter_dual(before),
                                         self._iter_dual(after)):
            gcb, ptrb, gca = jax.device_get((
                jnp.take(dcb.gcnt, ridx, 0), jnp.take(dcb.ptr, ridx, 0),
                jnp.take(dca.gcnt, ridx, 0)))
            # one fused gather each for the ring vector every live row
            # wrote this step and the newest global entry per (row, head):
            # [R, Hkv, hd] straight from the batched buffers, no
            # full-capacity [R, Hkv, C, hd] intermediate copies
            r2 = ridx[:, None]
            h2 = jnp.arange(dca.lk.shape[1])[None, :]
            p2 = jnp.asarray(ptrb, jnp.int32)[:, None]
            g2 = jnp.maximum(jnp.asarray(gca, jnp.int32) - 1, 0)
            ring_k, ring_v, prom_k, prom_v = jax.device_get((
                dca.lk[r2, h2, p2], dca.lv[r2, h2, p2],
                dca.gk[r2, h2, g2], dca.gv[r2, h2, g2]))
            full = None
            if ev_rows:
                eidx = jnp.asarray(ev_rows, jnp.int32)
                full = jax.device_get((jnp.take(dca.gk, eidx, 0),
                                       jnp.take(dca.gv, eidx, 0)))
            ev_pos = {s: i for i, s in enumerate(ev_rows)}
            for j, slot in enumerate(rows):
                k = ev_pos.get(slot)
                for h in range(self.cfg.n_kv_heads):
                    cb, ca = int(gcb[j, h]), int(gca[j, h])
                    gkey = (slot, lkey, h, "global")
                    if k is not None and ca <= cb:
                        # post-eviction re-sync (reclaims freed pages)
                        self.pool.free_stream(gkey)
                        self.pool.bulk_append(
                            gkey, np.asarray(full[0][k, h, :ca], np.float32),
                            np.asarray(full[1][k, h, :ca], np.float32))
                    elif ca > cb:
                        # promotion: gcnt increased -> append promoted token
                        self.pool.append(
                            gkey, np.asarray(prom_k[j, h], np.float32),
                            np.asarray(prom_v[j, h], np.float32))
                    # ring write at ptr_before: grows the stream until the
                    # ring wraps (lazy page allocation), overwrites after
                    p = int(ptrb[j])
                    lkey_ = (slot, lkey, h, "local")
                    kvec = np.asarray(ring_k[j, h], np.float32)
                    vvec = np.asarray(ring_v[j, h], np.float32)
                    if p == self.pool.table(lkey_).length:
                        self.pool.append(lkey_, kvec, vvec)
                    else:
                        self.pool.overwrite(lkey_, p, kvec, vvec)

    # ------------------------------------------------------------------
    # legacy fixed-slot loop (thin layer over prefill/insert/dispatch)
    # ------------------------------------------------------------------
    def add_request(self, prompt: List[int], max_new: int = 32) -> int:
        rid = self._next_rid
        self._next_rid += 1
        self.requests[rid] = Request(rid, list(prompt), max_new)
        return rid

    def _free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slot_rid) if r is None]

    def _retire_if_done(self, req: Request, slot: int, tok: int) -> None:
        if len(req.out) >= req.max_new or (self.eos is not None
                                           and tok == self.eos):
            req.done = True
            self.slot_rid[slot] = None
            self.free_slot(slot)

    def step(self) -> Dict[int, int]:
        """Admit pending requests, run one decode step, return {rid:
        newest token}. A request admitted THIS step emits both its
        prefill first token and a decode token; the dict keeps only the
        newest, ``requests[rid].out`` holds the full record."""
        pending = [r for r in self.requests.values()
                   if not r.done and r.rid not in self.slot_rid]
        emitted: Dict[int, int] = {}
        for slot in self._free_slots():
            if not pending:
                break
            req = pending.pop(0)
            self.slot_rid[slot] = req.rid
            # the first generated token comes straight from the prefill's
            # last-position logits; insert feeds it to the batched decode
            prefix = self.prefill(req.prompt, emit_first=True)
            self.insert(prefix, slot)
            req.out.append(prefix.first_token)
            emitted[req.rid] = prefix.first_token
            self._retire_if_done(req, slot, prefix.first_token)
        inflight = self.step_batch([])
        emitted_slots = self.collect(inflight) if inflight is not None else {}
        for slot, tok in emitted_slots.items():
            rid = self.slot_rid[slot]
            if rid is None:
                continue
            req = self.requests[rid]
            req.out.append(tok)
            emitted[rid] = tok
            self._retire_if_done(req, slot, tok)
        return emitted

    def run(self, max_steps: int = 256) -> None:
        for _ in range(max_steps):
            self.step()
            if all(r.done for r in self.requests.values()):
                break

    # ------------------------------------------------------------------
    def verify_paged(self, layer_repeat: int = 0, block: int = 0,
                     atol: float = 2e-3) -> float:
        """Recompute one layer's decode attention for all live slots from
        the PHYSICAL pool via the paged_decode kernel and compare with the
        logical dual-cache contents. Returns max abs deviation."""
        assert self.mirror and self.caches is not None
        live = [s for s in range(self.slots) if self.live[s]]
        if not live:
            return 0.0
        node = self.caches["blocks"][f"b{block}"]
        if isinstance(node, dict):
            node = node["self"]
        dc: DualCache = jax.tree.map(lambda x: x[layer_repeat], node)
        worst = 0.0
        for slot in live:
            n_local = min(int(dc.t[slot]), dc.w_local)
            for h in range(self.cfg.n_kv_heads):
                gk, gv = self.pool.gather((slot, (layer_repeat, block), h, "global"))
                cnt = int(dc.gcnt[slot, h])
                logical = np.asarray(dc.gk[slot, h, :cnt], np.float32)
                if cnt:
                    worst = max(worst, float(np.abs(gk[:cnt] - logical).max()))
                lk, _ = self.pool.gather((slot, (layer_repeat, block), h, "local"))
                # ring pages are allocated lazily: the stream holds exactly
                # the min(t, W) slots written so far
                assert lk.shape[0] == n_local, (lk.shape, n_local)
                if n_local:
                    worst = max(worst, float(np.abs(
                        lk - np.asarray(dc.lk[slot, h, :n_local],
                                        np.float32)).max()))
        # kernel-level check: paged attention over global streams
        keys = [(s, (layer_repeat, block), h, "global")
                for s in live for h in range(self.cfg.n_kv_heads)]
        kp, vp, tbl, lens = self.pool.kernel_args(keys)
        if int(lens.max()) > 0:
            hd = self.cfg.head_dim
            q = jnp.ones((len(keys), hd), jnp.float32) / hd
            from repro.kernels.paged_decode import paged_decode
            out = paged_decode(q, kp, vp, tbl, lens)
            # oracle from logical cache
            i = 0
            for s in live:
                for h in range(self.cfg.n_kv_heads):
                    cnt = int(dc.gcnt[s, h])
                    if cnt:
                        kk = np.asarray(dc.gk[s, h, :cnt], np.float32)
                        vv = np.asarray(dc.gv[s, h, :cnt], np.float32)
                        lg = (np.ones(hd) / hd) @ kk.T / np.sqrt(hd)
                        w = np.exp(lg - lg.max())
                        w /= w.sum()
                        oracle = w @ vv
                        worst = max(worst, float(
                            np.abs(np.asarray(out[i]) - oracle).max()))
                    i += 1
        return worst
