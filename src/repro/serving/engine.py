"""Serving engine: batched requests over the WG-KV dual cache, with the
paged physical layer (serving/paged.py) mirroring every logical cache write
— page tables, lazy-promotion page appends, ring-slot overwrites — exactly
as §4.1/§4.3 of the paper describe, plus Quest/SnapKV composition flags.

The model math runs through the jitted decode path (models/inference.py);
the engine owns request lifecycle (continuous-batching lite: requests join
free slots, finish independently) and the logical->physical mirroring. The
``verify_paged()`` method recomputes one layer's decode attention from the
*physical pool* via the paged_decode Pallas kernel and asserts it matches
the logical path — the systems-level correctness check that theoretical
paging actually serves the right bytes.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.dual_cache import DualCache
from repro.kernels.ops import paged_decode_attention
from repro.models import inference as I
from repro.serving import paged
from repro.serving.sampling import sample


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class Engine:
    """Fixed-slot batched serving engine (slots = max concurrent requests)."""

    def __init__(self, params, cfg: ModelConfig, *, slots: int = 4,
                 capacity: int = 4096, opts: Optional[I.DecodeOptions] = None,
                 pool_pages: int = 4096, eos: Optional[int] = None,
                 temperature: float = 0.0, seed: int = 0,
                 mirror_paged: bool = True):
        assert cfg.has_attention_cache, "engine serves KV-cache archs"
        self.params, self.cfg = params, cfg
        self.slots = slots
        self.capacity = capacity
        self.opts = opts or I.DecodeOptions()
        self.eos = eos
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)
        self.requests: Dict[int, Request] = {}
        self.slot_rid: List[Optional[int]] = [None] * slots
        self._next_rid = 0
        self.caches = None
        self.mirror = mirror_paged
        if mirror_paged:
            self.pool = paged.PagedKVPool(pool_pages, cfg.head_dim)
        self._decode = jax.jit(functools.partial(
            I.decode_step, cfg=cfg, opts=self.opts))
        self.stats = {"steps": 0, "evict_triggers": 0.0}

    # ------------------------------------------------------------------
    def add_request(self, prompt: List[int], max_new: int = 32) -> int:
        rid = self._next_rid
        self._next_rid += 1
        self.requests[rid] = Request(rid, list(prompt), max_new)
        return rid

    def _free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slot_rid) if r is None]

    # ------------------------------------------------------------------
    def _prefill_one(self, prompt: List[int]):
        """Prefill a single request: budgeted vertical-slash prefill on the
        largest window-multiple prefix, then teacher-forced decode steps for
        the ragged tail (keeps arbitrary prompt lengths exact)."""
        cfg = self.cfg
        w_max = cfg.wgkv.w_local
        if any(bt == "local_attn" for bt in cfg.block_pattern + cfg.stem_pattern):
            w_max = max(w_max, cfg.sliding_window)
        n0 = (len(prompt) // w_max) * w_max
        budget = cfg.wgkv.global_budget(self.capacity)
        if n0 >= w_max:
            toks = jnp.asarray(prompt[:n0], jnp.int32)[None]
            _, caches = I.prefill(self.params, cfg, toks, budget=budget,
                                  max_len=self.capacity, opts=self.opts)
        else:
            from repro.launch.specs import build_decode_caches
            caches = build_decode_caches(cfg, 1, self.capacity,
                                         use_wgkv=True, prefilled=0)
            if self.opts.evict_hard_budget is not None:
                caches["obs"] = I._init_obs_tree(cfg, 1, self.opts)
        for tok in prompt[n0:]:
            _, caches, _ = self._decode(
                self.params, token=jnp.asarray([tok], jnp.int32),
                caches=caches)
        return caches

    def _prefill_slot(self, slot: int, req: Request) -> None:
        """Prefill one request and splice its caches into the batch tree."""
        caches = self._prefill_one(req.prompt)

        def _baxis(path) -> int:
            # stacked per-superblock caches carry [n_repeats, B, ...];
            # the eviction observation tree is [n_repeats, n_attn, B, ...]
            keys = [getattr(k, "key", None) for k in path]
            if "obs" in keys:
                return 2
            return 1 if "blocks" in keys else 0

        if self.caches is None:
            self.caches = jax.tree_util.tree_map_with_path(
                lambda p, x: jnp.repeat(jnp.zeros_like(x), self.slots,
                                        axis=_baxis(p)),
                caches)
        self.caches = jax.tree_util.tree_map_with_path(
            lambda p, full, one: jax.lax.dynamic_update_index_in_dim(
                full, jnp.take(one, 0, axis=_baxis(p)), slot, _baxis(p)),
            self.caches, caches)
        if self.mirror:
            self._mirror_prefill(slot, caches)

    def _mirror_prefill(self, slot: int, caches) -> None:
        """Copy the logical dual caches into the physical paged pool."""
        for lkey, dc in self._iter_dual(caches):
            for h in range(self.cfg.n_kv_heads):
                gkey = (slot, lkey, h, "global")
                self.pool.free_stream(gkey)
                cnt = int(dc.gcnt[0, h])
                self.pool.bulk_append(
                    gkey, np.asarray(dc.gk[0, h, :cnt], np.float32),
                    np.asarray(dc.gv[0, h, :cnt], np.float32))
                lkey_ = (slot, lkey, h, "local")
                self.pool.free_stream(lkey_)
                w = dc.w_local
                self.pool.bulk_append(
                    lkey_, np.asarray(dc.lk[0, h], np.float32),
                    np.asarray(dc.lv[0, h], np.float32))

    def _iter_dual(self, caches) -> List[Tuple[Tuple, DualCache]]:
        """Yield (layer-key, DualCache[batch=...]) pairs from a cache tree."""
        out = []
        blocks = caches["blocks"]
        for i, bt in enumerate(self.cfg.block_pattern):
            node = blocks[f"b{i}"]
            if isinstance(node, dict) and "self" in node:
                node = node["self"]
            if isinstance(node, DualCache):
                for r in range(node.gk.shape[0] if node.gk.ndim == 5 else 1):
                    if node.gk.ndim == 5:  # stacked [n_repeats, B, ...]
                        out.append(((r, i), jax.tree.map(lambda x: x[r], node)))
                    else:
                        out.append(((0, i), node))
        return out

    def _mirror_decode(self, before, after) -> None:
        """Apply one decode step's logical cache delta to the pool."""
        for (lkey, dcb), (_, dca) in zip(self._iter_dual(before),
                                         self._iter_dual(after)):
            for slot, rid in enumerate(self.slot_rid):
                if rid is None:
                    continue
                for h in range(self.cfg.n_kv_heads):
                    # promotion: gcnt increased -> append promoted token page
                    cb, ca = int(dcb.gcnt[slot, h]), int(dca.gcnt[slot, h])
                    if ca > cb:
                        self.pool.append(
                            (slot, lkey, h, "global"),
                            np.asarray(dca.gk[slot, h, ca - 1], np.float32),
                            np.asarray(dca.gv[slot, h, ca - 1], np.float32))
                    # ring write: slot ptr_before overwritten
                    p = int(dcb.ptr[slot])
                    self.pool.overwrite(
                        (slot, lkey, h, "local"), p,
                        np.asarray(dca.lk[slot, h, p], np.float32),
                        np.asarray(dca.lv[slot, h, p], np.float32))

    # ------------------------------------------------------------------
    def step(self) -> Dict[int, int]:
        """Admit pending requests, run one decode step, return {rid: token}."""
        pending = [r for r in self.requests.values()
                   if not r.done and r.rid not in self.slot_rid]
        for slot in self._free_slots():
            if not pending:
                break
            req = pending.pop(0)
            self.slot_rid[slot] = req.rid
            self._prefill_slot(slot, req)
        if all(r is None for r in self.slot_rid) or self.caches is None:
            return {}
        # last token per slot (prompt tail or last generated)
        toks = []
        for rid in self.slot_rid:
            if rid is None:
                toks.append(0)
            else:
                r = self.requests[rid]
                toks.append(r.out[-1] if r.out else r.prompt[-1])
        before = self.caches
        logits, self.caches, st = self._decode(
            self.params, token=jnp.asarray(toks, jnp.int32),
            caches=self.caches)
        self.stats["steps"] += 1
        self.stats["evict_triggers"] += float(st["evict_triggers"])
        if self.mirror:
            self._mirror_decode(before, self.caches)
        self.key, sk = jax.random.split(self.key)
        nxt = sample(sk, logits, temperature=self.temperature)
        emitted: Dict[int, int] = {}
        for slot, rid in enumerate(self.slot_rid):
            if rid is None:
                continue
            req = self.requests[rid]
            tok = int(nxt[slot])
            req.out.append(tok)
            emitted[rid] = tok
            if len(req.out) >= req.max_new or (self.eos is not None
                                               and tok == self.eos):
                req.done = True
                self.slot_rid[slot] = None
                if self.mirror:
                    for lkey, _ in self._iter_dual(self.caches):
                        for h in range(self.cfg.n_kv_heads):
                            self.pool.free_stream((slot, lkey, h, "global"))
                            self.pool.free_stream((slot, lkey, h, "local"))
        return emitted

    def run(self, max_steps: int = 256) -> None:
        for _ in range(max_steps):
            self.step()
            if all(r.done for r in self.requests.values()):
                break

    # ------------------------------------------------------------------
    def verify_paged(self, layer_repeat: int = 0, block: int = 0,
                     atol: float = 2e-3) -> float:
        """Recompute one layer's decode attention for all live slots from
        the PHYSICAL pool via the paged_decode kernel and compare with the
        logical dual-cache contents. Returns max abs deviation."""
        assert self.mirror and self.caches is not None
        live = [s for s, r in enumerate(self.slot_rid) if r is not None]
        if not live:
            return 0.0
        node = self.caches["blocks"][f"b{block}"]
        if isinstance(node, dict):
            node = node["self"]
        dc: DualCache = jax.tree.map(lambda x: x[layer_repeat], node)
        worst = 0.0
        for slot in live:
            for h in range(self.cfg.n_kv_heads):
                gk, gv = self.pool.gather((slot, (layer_repeat, block), h, "global"))
                cnt = int(dc.gcnt[slot, h])
                logical = np.asarray(dc.gk[slot, h, :cnt], np.float32)
                if cnt:
                    worst = max(worst, float(np.abs(gk[:cnt] - logical).max()))
                lk, _ = self.pool.gather((slot, (layer_repeat, block), h, "local"))
                worst = max(worst, float(
                    np.abs(lk - np.asarray(dc.lk[slot, h], np.float32)).max()))
        # kernel-level check: paged attention over global streams
        keys = [(s, (layer_repeat, block), h, "global")
                for s in live for h in range(self.cfg.n_kv_heads)]
        kp, vp, tbl, lens = self.pool.kernel_args(keys)
        if int(lens.max()) > 0:
            hd = self.cfg.head_dim
            q = jnp.ones((len(keys), hd), jnp.float32) / hd
            from repro.kernels.paged_decode import paged_decode
            out = paged_decode(q, kp, vp, tbl, lens)
            # oracle from logical cache
            i = 0
            for s in live:
                for h in range(self.cfg.n_kv_heads):
                    cnt = int(dc.gcnt[s, h])
                    if cnt:
                        kk = np.asarray(dc.gk[s, h, :cnt], np.float32)
                        vv = np.asarray(dc.gv[s, h, :cnt], np.float32)
                        lg = (np.ones(hd) / hd) @ kk.T / np.sqrt(hd)
                        w = np.exp(lg - lg.max())
                        w /= w.sum()
                        oracle = w @ vv
                        worst = max(worst, float(
                            np.abs(np.asarray(out[i]) - oracle).max()))
                    i += 1
        return worst
