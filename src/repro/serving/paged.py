"""Dual-Cache Paged Memory Management (paper §4.1, Fig. 6).

Physical layer: a unified KV Pool of fixed-size pages (16 tokens each,
matching the paper) shared by ALL (request x layer x kv-head) streams, plus
per-stream Page Tables mapping logical pages -> physical pages. This is
what turns the ragged per-head cache lengths (Fig. 4) into fragmentation-
free storage: a head's Global Cache grows by whole pages with no
contiguous reallocation.

The allocator is host-side (numpy free-list, like vLLM's block manager);
the pool tensors are device arrays consumed directly by the
``paged_decode`` Pallas kernel (kernels/paged_decode.py).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PAGE_SIZE = 16


class PoolExhausted(RuntimeError):
    pass


@dataclasses.dataclass
class StreamTable:
    """Page table of one logical stream (request, layer, kv-head, region)."""

    pages: List[int] = dataclasses.field(default_factory=list)
    length: int = 0  # tokens written

    def slot(self, pos: int) -> Tuple[int, int]:
        return self.pages[pos // PAGE_SIZE], pos % PAGE_SIZE


class PagedKVPool:
    """Unified physical pool + free-list allocator."""

    def __init__(self, num_pages: int, head_dim: int, dtype=jnp.float32):
        self.num_pages = num_pages
        self.head_dim = head_dim
        self.k = np.zeros((num_pages, PAGE_SIZE, head_dim), np.float32)
        self.v = np.zeros((num_pages, PAGE_SIZE, head_dim), np.float32)
        self._free: List[int] = list(range(num_pages - 1, 0, -1))
        # page 0 is reserved as the null page (masked in kernels)
        self.tables: Dict[Tuple, StreamTable] = {}
        # physical-page refcounts; pages absent from the dict are free.
        self._refs: Dict[int, int] = {}
        self.dtype = dtype

    # ---- allocator ------------------------------------------------------
    def alloc_page(self) -> int:
        if not self._free:
            raise PoolExhausted("KV pool exhausted")
        page = self._free.pop()
        self._refs[page] = 1
        return page

    def _decref(self, page: int) -> None:
        n = self._refs.get(page, 0)
        if n <= 1:
            self._refs.pop(page, None)
            self._free.append(page)
        else:
            self._refs[page] = n - 1

    def refcount(self, page: int) -> int:
        return self._refs.get(page, 0)

    def free_stream(self, key: Tuple) -> None:
        t = self.tables.pop(key, None)
        if t:
            for p in t.pages:
                self._decref(p)

    def share_stream(self, src: Tuple, dst: Tuple) -> None:
        """Alias ``dst`` to ``src``'s pages (incref, no copy).

        Subsequent writes through either key copy-on-write any shared page,
        so neither stream can observe the other's mutations.
        """
        s = self.tables[src]
        assert dst not in self.tables, f"share_stream: {dst} already exists"
        for p in s.pages:
            self._refs[p] = self._refs.get(p, 0) + 1
        self.tables[dst] = StreamTable(pages=list(s.pages), length=s.length)

    def _writable_page(self, t: StreamTable, idx: int) -> int:
        """Return ``t.pages[idx]``, copying it first if shared (COW)."""
        page = t.pages[idx]
        if self._refs.get(page, 0) > 1:
            fresh = self.alloc_page()
            self.k[fresh] = self.k[page]
            self.v[fresh] = self.v[page]
            self._decref(page)
            t.pages[idx] = fresh
            page = fresh
        return page

    def table(self, key: Tuple) -> StreamTable:
        if key not in self.tables:
            self.tables[key] = StreamTable()
        return self.tables[key]

    @property
    def pages_in_use(self) -> int:
        return self.num_pages - 1 - len(self._free)

    def utilization(self) -> float:
        """Fraction of allocated slots actually holding tokens (1 - internal
        fragmentation)."""
        used = self.pages_in_use * PAGE_SIZE
        toks = sum(t.length for t in self.tables.values())
        return toks / used if used else 1.0

    # ---- writes ---------------------------------------------------------
    def append(self, key: Tuple, k_vec: np.ndarray, v_vec: np.ndarray) -> None:
        t = self.table(key)
        if t.length % PAGE_SIZE == 0:
            t.pages.append(self.alloc_page())
        page = self._writable_page(t, t.length // PAGE_SIZE)
        off = t.length % PAGE_SIZE
        self.k[page, off] = np.asarray(k_vec, np.float32)
        self.v[page, off] = np.asarray(v_vec, np.float32)
        t.length += 1

    def bulk_append(self, key: Tuple, ks: np.ndarray, vs: np.ndarray) -> None:
        for i in range(ks.shape[0]):
            self.append(key, ks[i], vs[i])

    def overwrite(self, key: Tuple, pos: int, k_vec, v_vec) -> None:
        t = self.table(key)
        page = self._writable_page(t, pos // PAGE_SIZE)
        off = pos % PAGE_SIZE
        self.k[page, off] = np.asarray(k_vec, np.float32)
        self.v[page, off] = np.asarray(v_vec, np.float32)

    # ---- reads ----------------------------------------------------------
    def gather(self, key: Tuple) -> Tuple[np.ndarray, np.ndarray]:
        """Materialize a stream's tokens [len, hd] (verification/tests)."""
        t = self.table(key)
        if t.length == 0:
            return (np.zeros((0, self.head_dim), np.float32),) * 2
        pages = np.asarray(t.pages)
        k = self.k[pages].reshape(-1, self.head_dim)[: t.length]
        v = self.v[pages].reshape(-1, self.head_dim)[: t.length]
        return k, v

    def kernel_args(self, keys: List[Tuple], max_pages: Optional[int] = None
                    ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
        """Build (k_pool, v_pool, page_table [N, max_pages], lengths [N])
        device arrays for the paged_decode kernel over the given streams."""
        if max_pages is None:
            max_pages = max((len(self.table(k).pages) for k in keys), default=1)
        max_pages = max(max_pages, 1)
        tbl = np.zeros((len(keys), max_pages), np.int32)
        lens = np.zeros((len(keys),), np.int32)
        for i, key in enumerate(keys):
            t = self.table(key)
            tbl[i, : len(t.pages)] = t.pages
            lens[i] = t.length
        return (jnp.asarray(self.k, self.dtype), jnp.asarray(self.v, self.dtype),
                jnp.asarray(tbl), jnp.asarray(lens))
