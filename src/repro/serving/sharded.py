"""Mesh-sharded serving execution: one SPMD decode step over all slots.

The serving backends (engine.py / dense.py / static_admission.py) jit
one model entry point — the ragged ``prefill_extend_ragged`` scan — in
two dressings: the fused megabatch tick over the persistent batched
tree (with on-device sampling folded in) and the batched prefill
extend over per-task batch-1 trees. This module is the single place where a
``jax.sharding.Mesh`` enters that path, so every backend
(and therefore the whole A/B harness) scales across a data x model device
mesh without the orchestrator or scheduler changing at all:

  * **params** are placed once with ``param_shardings(...,
    replicate_fsdp=True)`` — weights replicated across "data" (decode is
    weights-stationary; no per-step FSDP all-gathers) and tensor-parallel
    over "model" where head/FFN dims divide.
  * **cache trees** are placed with ``cache_shardings``: decode slots
    batch over "data", KV heads over "model" (with the repo's
    divisibility fallback to replication — phi3's 10 KV heads on a
    model=4 mesh replicate rather than pad).
  * ``decode_step`` / ``prefill_extend_ragged`` are jitted with
    **explicit in/out shardings** (memoized per input structure, since
    the batched and batch-1 trees differ), so the cache layout is pinned
    across steps instead of drifting with whatever GSPMD infers.
  * ``insert`` splices a batch-1 prefix into the batched tree under jit
    with the prefix device-put row-wise and the output pinned back to
    the canonical batched shardings.

Unmeshed (``mesh=None``) every helper degrades to the exact pre-sharding
behavior: plain ``jax.jit`` and host-side splices.

Debug recipe (no accelerator needed)::

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m repro.launch.serve \
        --arch qwen3-0.6b --reduced --mesh 2x4 --requests 4
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.launch.specs import (alloc_batched_caches, extract_slot_caches,
                                splice_caches)
from repro.models import inference as I
from repro.serving.sampling import sample
from repro.sharding import rules


# ==========================================================================
# mesh construction from a CLI "dxm" spec
# ==========================================================================
def parse_mesh_shape(spec: str) -> Tuple[int, int]:
    """``"2x4"`` -> ``(2, 4)`` (data ways, model ways)."""
    try:
        d, m = spec.lower().split("x")
        shape = (int(d), int(m))
    except ValueError:
        raise ValueError(f"mesh spec must look like '2x4' (data x model), "
                         f"got {spec!r}") from None
    if shape[0] < 1 or shape[1] < 1:
        raise ValueError(f"mesh axes must be >= 1, got {spec!r}")
    return shape


def build_mesh(spec: Optional[str]) -> Optional[Mesh]:
    """Build a ("data", "model") mesh from a "dxm" spec (None -> None).

    Works on real accelerators and on host platform devices alike; for a
    headless debug mesh export
    ``XLA_FLAGS=--xla_force_host_platform_device_count=<d*m>`` before any
    jax import.
    """
    if not spec:
        return None
    shape = parse_mesh_shape(spec)
    need, have = shape[0] * shape[1], len(jax.devices())
    if have < need:
        raise RuntimeError(
            f"mesh {spec} needs {need} devices, found {have}; for a debug "
            "mesh on host devices set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={need} before jax "
            "imports")
    return jax.make_mesh(shape, ("data", "model"))


def _struct_key(tree: Any) -> Tuple:
    """Hashable (treedef, leaf shapes/dtypes) key for jit memoization."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return (treedef, tuple((tuple(np.shape(l)), str(jnp.result_type(l)))
                           for l in leaves))


class ShardedDecodeMixin:
    """Mesh-aware jitted decode/extend + cache placement for backends.

    Expects the host class to provide ``self.cfg`` and ``self.opts``
    before calling :meth:`_sharding_setup`, and ``self.params`` before
    the first decode/extend call. With ``mesh=None`` everything reduces
    to the unsharded single-device path.
    """

    mesh: Optional[Mesh] = None

    # ------------------------------------------------------------------
    # setup / placement
    # ------------------------------------------------------------------
    def _sharding_setup(self, params, mesh: Optional[Mesh]):
        """Record the mesh and place params on it; returns the (possibly
        device-put) params."""
        self.mesh = mesh
        self._fn_cache: Dict[Tuple, Any] = {}
        self._splice_cache: Dict[Tuple, Any] = {}
        if mesh is None:
            self._param_sh = None
            return params
        self._param_sh = rules.param_shardings(params, mesh, self.cfg,
                                               replicate_fsdp=True)
        return jax.device_put(params, self._param_sh)

    def _replicated(self) -> NamedSharding:
        return NamedSharding(self.mesh, P())

    def _row_sharding(self, b: int, ndim: int) -> NamedSharding:
        """Sharding for a batch-leading array: rows over "data" when the
        batch divides, else replicated."""
        bax = rules.pick(b, self.mesh, rules.batch_axes(self.mesh), "data")
        return NamedSharding(self.mesh, P(bax, *(None,) * (ndim - 1)))

    def cache_shardings_for(self, caches):
        """NamedSharding tree for a concrete cache tree (slots over
        "data", KV heads over "model", divisibility fallback)."""
        return rules.cache_shardings(caches, self.mesh, self.cfg)

    def place_caches(self, caches):
        """device_put a cache tree onto its canonical mesh shardings
        (identity when unmeshed)."""
        if self.mesh is None:
            return caches
        return jax.device_put(caches, self.cache_shardings_for(caches))

    # ------------------------------------------------------------------
    # jitted model steps
    # ------------------------------------------------------------------
    def _make_extend_batch(self) -> Callable:  # jaxlint: shapes(extend_batch=per-batch-width)
        """(params, (tokens [B, S], lengths [B]), caches) ->
        (last_logits [B, V], caches, per-row stats): the batched ragged
        prefill extend. Under a mesh the prefill rows shard over "data"
        (tokens, lengths, logits, and the batched cache tree all pinned
        with explicit in/out shardings via the same memoized-spec
        machinery as decode)."""

        def fn(params, feed, caches):
            tokens, lengths = feed
            return I.prefill_extend_ragged(params, self.cfg, tokens,
                                           lengths, caches, opts=self.opts)

        return jax.jit(fn) if self.mesh is None \
            else self._mesh_jit(fn, kind="extend_batch")

    # jaxlint: shapes(fused_step=2, fused_step_sel=1)
    def _make_fused_step(self, opts=None, *,
                         kind: str = "fused_step") -> Callable:
        """(params, feed, caches) -> (last_logits, caches, stats): the
        fused megabatch tick over the PERSISTENT batched cache tree.
        ``opts`` overrides ``self.opts`` for this build — the engine uses
        it to compile a second, selection-enabled variant of the same
        step (``DecodeOptions.selection_policy``) dispatched on
        decode-only ticks; ``kind`` keys the mesh-jit memo so the two
        variants never share a compiled entry.

        ``feed`` is ``(tokens [B, S], lengths [B], tok_dev [B],
        use_dev [B] bool, key [1, 2])``: prompt chunks arrive from the
        host left-aligned in ``tokens``; decode rows are length-1 ragged
        rows whose position-0 token is substituted from the ON-DEVICE
        sampled vector ``tok_dev`` (``use_dev`` marks them), so the
        decode feed never round-trips through the host between steps
        under the two-phase dispatch-ahead contract. Sampling happens
        INSIDE the same jitted
        call (``stats["sampled"]``), making a whole tick exactly one
        device dispatch: a decode row's next token and a finishing
        prefill row's first token come out together. Length-0 rows stay
        bit-identical via the ragged scan's per-leaf masked writes.
        Under a mesh, rows shard over "data" exactly like the batched
        extend (the [1, 2] key replicates)."""
        temperature = self.temperature
        opts = self.opts if opts is None else opts

        def fn(params, feed, caches):  # jaxlint: masked-scan-body
            tokens, lengths, tok_dev, use_dev, key = feed
            tokens = tokens.at[:, 0].set(
                jnp.where(use_dev, tok_dev, tokens[:, 0]))
            last_logits, caches, st = I.prefill_extend_ragged(
                params, self.cfg, tokens, lengths, caches, opts=opts)
            sampled = sample(key[0], last_logits, temperature=temperature)
            # per-row resident KV tokens computed IN-JIT from the post-step
            # tree and pulled with collect's one sync, so memory_snapshot
            # reads host state only (the PR 9 allow-sync debt is gone)
            kv_rows = self._kv_tokens_device(caches)
            return last_logits, caches, {**st, "sampled": sampled,
                                         "kv_tokens_rows": kv_rows}

        return jax.jit(fn) if self.mesh is None \
            else self._mesh_jit(fn, kind=kind)

    def _mesh_jit(self, fn: Callable, *, kind: str) -> Callable:
        """Wrap ``fn(params, tokens, caches)`` with explicit in/out
        shardings, memoized per (tokens, caches) structure — the batched
        decode, the batch-1 prefill tail, and the ragged batched extend
        (where ``tokens`` is a ``(tokens [B, S], lengths [B])`` feed
        tree) share one engine but need different placements."""

        def call(params, tokens, caches):
            key = (kind,) + _struct_key((tokens, caches))
            ent = self._fn_cache.get(key)
            if ent is None:
                ent = self._build_mesh_jit(fn, tokens, caches)
                self._fn_cache[key] = ent
            jfn, tok_sh, csh = ent
            # eager prefill / splice outputs may carry compiler-chosen
            # placements; pin them (no-op copy when already canonical)
            return jfn(params, jax.device_put(tokens, tok_sh),
                       jax.device_put(caches, csh))

        return call

    def _build_mesh_jit(self, fn, tokens, caches):  # jaxlint: shapes(mesh-jit=per-structure)
        mesh, cfg = self.mesh, self.cfg
        csh = self.cache_shardings_for(caches)
        # feed leaves with a batch-leading axis (tokens/lengths/device
        # feed) shard rows over "data"; anything else (the fused step's
        # [1, 2] PRNG key) replicates
        b = int(np.shape(jax.tree_util.tree_leaves(tokens)[0])[0])
        tok_sh = jax.tree.map(
            lambda x: (self._row_sharding(b, np.ndim(x))
                       if np.ndim(x) >= 1 and np.shape(x)[0] == b
                       else self._replicated()), tokens)
        out_struct = jax.eval_shape(fn, self.params, tokens, caches)
        logits_s, caches_s, stats_s = out_struct

        def row_or_repl(leaf):
            if leaf.ndim >= 1 and leaf.shape[0] == b:
                return self._row_sharding(b, leaf.ndim)
            return self._replicated()

        out_sh = (row_or_repl(logits_s),
                  rules.cache_shardings(caches_s, mesh, cfg),
                  jax.tree.map(row_or_repl, stats_s))
        jfn = jax.jit(fn, in_shardings=(self._param_sh, tok_sh, csh),
                      out_shardings=out_sh)
        return jfn, tok_sh, csh

    # ------------------------------------------------------------------
    # batched ragged prefill: stack / unstack around the one jitted call
    # ------------------------------------------------------------------
    def batched_prefill_stack(self, trees):  # jaxlint: shapes(stack=per-structure)
        """Stack B batch-1 prefill cache trees into one batch-B tree in a
        single jitted call (memoized per structure; under a mesh the
        result is pinned to the canonical batched shardings — prefill
        rows over "data", KV heads over "model").

        Rows are written with the same dynamic-update-slice splice the
        decode ``insert`` path uses, NOT a batch-axis concatenate: XLA
        CPU's SPMD partitioner miscomputes mixed-tiling concats (the
        PR-3 gate_features bug all over again — replicated batch-1
        inputs concatenated straight into a "data"-sharded batch axis
        come out permuted)."""
        trees = tuple(trees)
        n = len(trees)
        key = ("stack", n) + _struct_key(trees)
        ent = self._fn_cache.get(key)
        if ent is None:

            def fn(ts):
                out = alloc_batched_caches(ts[0], n)
                for i, t in enumerate(ts):
                    out = splice_caches(out, t, i)
                return out

            if self.mesh is None:
                ent = (jax.jit(fn), None)
            else:
                osh = rules.cache_shardings(
                    jax.eval_shape(fn, trees), self.mesh, self.cfg)
                ish = tuple(self.cache_shardings_for(t) for t in trees)
                ent = (jax.jit(fn, in_shardings=(ish,),
                               out_shardings=osh), ish)
            self._fn_cache[key] = ent
        jfn, ish = ent
        if ish is not None:
            trees = jax.device_put(trees, ish)
        return jfn(trees)

    def batched_prefill_unstack(self, batched, n: int):  # jaxlint: shapes(unstack=per-structure)
        """Slice a batch-``n`` prefill cache tree back into ``n`` batch-1
        trees in a single jitted call (inverse of
        :meth:`batched_prefill_stack`; bitwise row-preserving)."""
        key = ("unstack", n) + _struct_key(batched)
        ent = self._fn_cache.get(key)
        if ent is None:

            def fn(bt):
                return tuple(extract_slot_caches(bt, i) for i in range(n))

            if self.mesh is None:
                ent = jax.jit(fn)
            else:
                osh = tuple(rules.cache_shardings(t, self.mesh, self.cfg)
                            for t in jax.eval_shape(fn, batched))
                ent = jax.jit(fn, in_shardings=(
                    self.cache_shardings_for(batched),), out_shardings=osh)
            self._fn_cache[key] = ent
        return ent(batched)

    # ------------------------------------------------------------------
    # sharded slot splice (insert)
    # ------------------------------------------------------------------
    def sharded_splice(self, batch_tree, one_tree, slot: int):  # jaxlint: shapes(splice=per-structure)
        """``splice_caches`` with the batch-1 prefix device-put onto the
        mesh and the result pinned to the batched tree's canonical
        shardings (plain splice when unmeshed)."""
        if self.mesh is None:
            return splice_caches(batch_tree, one_tree, slot)
        key = _struct_key((batch_tree, one_tree))
        ent = self._splice_cache.get(key)
        if ent is None:
            bsh = self.cache_shardings_for(batch_tree)
            osh = self.cache_shardings_for(one_tree)
            jfn = jax.jit(splice_caches, static_argnums=2,
                          in_shardings=(bsh, osh), out_shardings=bsh)
            ent = (jfn, bsh, osh)
            self._splice_cache[key] = ent
        jfn, bsh, osh = ent
        return jfn(jax.device_put(batch_tree, bsh),
                   jax.device_put(one_tree, osh), slot)

    # ------------------------------------------------------------------
    # telemetry
    # ------------------------------------------------------------------
    def _per_shard_snapshot(self, snap: Dict[str, float],
                            leaf=None) -> Dict[str, float]:
        """Annotate a memory snapshot with mesh-level telemetry: the
        even-occupancy per-device share of the resident KV total
        (``kv_bytes`` stays global; with live slots concentrated on one
        data shard, that shard's devices hold proportionally more) and
        the mesh device count. ``leaf`` is a representative cache array
        whose sharding gives the per-device fraction."""
        if self.mesh is None:
            return snap
        snap["mesh_devices"] = float(self.mesh.size)
        frac = 1.0 / self.mesh.size
        if leaf is not None and hasattr(leaf, "sharding") and leaf.size:
            shard = int(np.prod(leaf.sharding.shard_shape(leaf.shape)))
            frac = shard / leaf.size
        snap["kv_bytes_per_shard"] = snap.get("kv_bytes", 0.0) * frac
        return snap
