"""Content-addressed prefix store: admission-gated shared-context reuse.

Multi-turn chat and agentic workloads resend a growing shared context on
every turn; re-prefilling it burns TTFT on tokens whose gated KV the
engine already computed.  This store caches the *post-admission* cache
tree (the WG-KV dual cache after the write gate filtered the prefix) at
chunk-boundary positions, keyed by a chained content hash of the token
prefix, and splices it back into a slot on the next request that shares
the prefix — the fused ragged scan then resumes at the suffix.

Design points
-------------

* **Chunk-quantised keys.**  The fused tick advances prefill in
  ``chunk_tokens`` quanta, so cache state is only capturable/resumable at
  positions ``N`` that are multiples of the scheduler chunk.  Hashes are
  chained per quantum — ``h_N = H(h_{N-Q} || tokens[N-Q:N])`` — so a
  lookup walks boundary hashes from the longest aligned prefix down and
  the store needs no trie.

* **Proper-prefix hits only.**  A hit at ``N == len(prompt)`` would leave
  no suffix token to produce last-position logits, so lookup requires
  ``N < len(prompt)`` (capture likewise targets the largest boundary
  strictly inside the prompt).

* **COW isolation.**  The stored device tree is immutable (splice copies
  it into the slot row); the host paged-pool mirror is shared by
  refcount with copy-on-write pages (:meth:`PagedKVPool.share_stream`),
  so a hit never aliases mutable decode state.

* **Refcounted LRU.**  Eviction under ``budget_bytes`` is deferred for
  entries still referenced by an admitted-but-not-yet-spliced request:
  they move to a zombie list and are freed when the last ref drops.
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = ["CachedPrefix", "PrefixCache", "chain_hashes"]


def chain_hashes(prompt: Sequence[int], quantum: int) -> List[Tuple[int, str]]:
    """Chained content hashes at every chunk boundary inside ``prompt``.

    Returns ``[(Q, h_Q), (2Q, h_2Q), ...]`` for boundaries strictly less
    than ``len(prompt)`` (a whole-prompt entry could never be resumed —
    see module docstring).  ``h_N`` commits to the entire prefix
    ``prompt[:N]`` via chaining, so equal hashes mean equal prefixes
    (modulo blake2b collisions, which we accept at 128 bits).
    """
    out: List[Tuple[int, str]] = []
    prev = b""
    for n in range(quantum, len(prompt), quantum):
        h = hashlib.blake2b(prev, digest_size=16)
        h.update(np.asarray(prompt[n - quantum:n], np.int32).tobytes())
        digest = h.hexdigest()
        out.append((n, digest))
        prev = digest.encode()
    return out


@dataclass
class CachedPrefix:
    """One stored prefix: the post-admission batch-1 cache tree plus the
    host-side paged-mirror bookkeeping needed to adopt it into a slot."""
    key: str                      # chained content hash of prompt[:n_tokens]
    n_tokens: int                 # prefix length (chunk-aligned)
    caches: Any                   # batch-1 device cache tree (immutable)
    adm_weighted: float = 0.0     # sum of admission probs over [0, n_tokens)
    meta: Dict[Any, Dict[str, Any]] = field(default_factory=dict)
    kv_tokens: int = 0            # logical KV entries summed over streams
    n_bytes: int = 0              # device + mirrored pool bytes (LRU budget)
    stream_keys: Tuple[Any, ...] = ()   # pool streams pinned by this entry
    refs: int = 0                 # admitted-but-not-spliced requests
    hits: int = 0


class PrefixCache:
    """LRU map ``hash -> CachedPrefix`` under a byte budget.

    ``quantum`` must equal the scheduler's ``chunk_tokens`` (the
    orchestrator validates this): capture happens at a collect whose row
    position is a chunk multiple, and a hit resumes the scan at exactly
    that position.

    ``free_fn`` (typically ``engine.release_prefix``) is invoked when an
    entry's storage is actually reclaimed — at eviction if unreferenced,
    else when the last in-flight reference is released.
    """

    def __init__(self, quantum: int, budget_bytes: int = 256 << 20, *,
                 free_fn: Optional[Callable[[CachedPrefix], None]] = None):
        assert quantum > 0, "quantum must be a positive chunk size"
        self.quantum = int(quantum)
        self.budget_bytes = int(budget_bytes)
        self._free_fn = free_fn
        self._entries: "OrderedDict[str, CachedPrefix]" = OrderedDict()
        self._zombies: List[CachedPrefix] = []   # evicted but still ref'd
        self._bytes = 0
        self.hits = 0
        self.misses = 0
        self.inserts = 0
        self.evictions = 0

    # ------------------------------------------------------------------
    @property
    def bytes_used(self) -> int:
        return self._bytes

    def __contains__(self, key: str) -> bool:
        return key in self._entries

    def __len__(self) -> int:
        return len(self._entries)

    def counters(self) -> Dict[str, float]:
        return {"prefix_hit": float(self.hits),
                "prefix_miss": float(self.misses),
                "prefix_evict": float(self.evictions),
                "prefix_bytes": float(self._bytes)}

    # ------------------------------------------------------------------
    def lookup(self, prompt: Sequence[int]) -> Optional[CachedPrefix]:
        """Longest stored aligned proper prefix of ``prompt``, or None.

        A returned entry is pinned (``refs`` incremented) until the
        caller's :meth:`release` — the orchestrator releases once the
        hitting request has been spliced into its slot (or cancelled
        before that).
        """
        best: Optional[CachedPrefix] = None
        for _, digest in chain_hashes(prompt, self.quantum):
            e = self._entries.get(digest)
            if e is not None:
                best = e          # boundaries ascend: later hit is longer
        if best is None:
            self.misses += 1
            return None
        best.refs += 1
        best.hits += 1
        self.hits += 1
        self._entries.move_to_end(best.key)
        return best

    def capture_target(self, prompt: Sequence[int]) -> Optional[Tuple[int, str]]:
        """Longest aligned proper boundary of ``prompt`` not yet stored:
        the ``(n_tokens, key)`` a finishing request should capture at.
        Returns None when the whole useful prefix is already cached (or
        the prompt is shorter than one quantum)."""
        boundaries = chain_hashes(prompt, self.quantum)
        if not boundaries:
            return None
        n, digest = boundaries[-1]
        if digest in self._entries:
            return None
        return (n, digest)

    # ------------------------------------------------------------------
    def insert(self, entry: CachedPrefix) -> None:
        """Store a captured prefix; evicts LRU entries over budget.

        Duplicate keys (two in-flight requests racing to capture the
        same prefix) keep the existing entry — it may already be pinned
        by a hit — and free the newcomer's storage.
        """
        if entry.key in self._entries:
            self._reclaim(entry)
            return
        self._entries[entry.key] = entry
        self._bytes += entry.n_bytes
        self.inserts += 1
        self._evict_over_budget()

    def release(self, entry: CachedPrefix) -> None:
        """Drop one in-flight reference; frees zombie storage at zero."""
        entry.refs -= 1
        assert entry.refs >= 0, f"over-released prefix entry {entry.key}"
        if entry.refs == 0 and entry in self._zombies:
            self._zombies.remove(entry)
            self._reclaim(entry)

    def clear(self) -> None:
        """Drop every unreferenced entry (referenced ones zombie)."""
        for key in list(self._entries):
            self._evict(key)

    # ------------------------------------------------------------------
    def _evict_over_budget(self) -> None:
        while self._bytes > self.budget_bytes and len(self._entries) > 1:
            key = next(iter(self._entries))   # LRU head
            self._evict(key)

    def _evict(self, key: str) -> None:
        entry = self._entries.pop(key)
        self._bytes -= entry.n_bytes
        self.evictions += 1
        if entry.refs > 0:
            self._zombies.append(entry)   # storage reclaimed at release()
        else:
            self._reclaim(entry)

    def _reclaim(self, entry: CachedPrefix) -> None:
        if self._free_fn is not None:
            self._free_fn(entry)
