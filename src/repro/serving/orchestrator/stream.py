"""Per-request token streaming with latency timestamps.

Each request gets a :class:`TokenStream`: the orchestrator pushes tokens
as the batched decode emits them, the stream timestamps every push
(TTFT = first push - arrival, TPOT = mean gap between pushes) and relays
to an optional user callback ``on_token(rid, token, is_last)`` — the
in-process analogue of an SSE/gRPC streaming response.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional

OnToken = Callable[[int, int, bool], None]


class TokenStream:
    """One request's ordered token stream + per-token wall-clock stamps."""

    def __init__(self, rid: int, arrival_t: float,
                 on_token: Optional[OnToken] = None,
                 clock: Callable[[], float] = time.monotonic):
        self.rid = rid
        self.arrival_t = arrival_t
        self.on_token = on_token
        self.clock = clock
        self.tokens: List[int] = []
        self.times: List[float] = []
        self.closed = False
        self.cancelled = False

    def emit(self, token: int, is_last: bool = False) -> None:
        assert not self.closed, f"stream {self.rid} already closed"
        self.tokens.append(int(token))
        self.times.append(self.clock())
        if is_last:
            self.closed = True
        if self.on_token is not None:
            self.on_token(self.rid, int(token), is_last)

    def close(self, *, cancelled: bool = False) -> None:
        """Terminate the stream without a final token (mid-stream
        cancellation / deadline expiry). Idempotent; late tokens for a
        closed stream are a bug `emit` refuses."""
        if not self.closed:
            self.closed = True
            self.cancelled = cancelled

    @property
    def ttft(self) -> Optional[float]:
        return self.times[0] - self.arrival_t if self.times else None

    @property
    def tpot(self) -> Optional[float]:
        """Mean inter-token gap after the first token."""
        if len(self.times) < 2:
            return None
        return (self.times[-1] - self.times[0]) / (len(self.times) - 1)


class StreamMux:
    """rid -> TokenStream registry the orchestrator emits through."""

    def __init__(self, clock: Callable[[], float] = time.monotonic):
        self.clock = clock
        self.streams: Dict[int, TokenStream] = {}

    def open(self, rid: int, arrival_t: float,
             on_token: Optional[OnToken] = None) -> TokenStream:
        st = TokenStream(rid, arrival_t, on_token=on_token, clock=self.clock)
        self.streams[rid] = st
        return st

    def emit(self, rid: int, token: int, is_last: bool = False) -> None:
        self.streams[rid].emit(token, is_last)

    def close(self, rid: int, *, cancelled: bool = False) -> None:
        if rid in self.streams:
            self.streams[rid].close(cancelled=cancelled)

    def tokens(self, rid: int) -> List[int]:
        return self.streams[rid].tokens
