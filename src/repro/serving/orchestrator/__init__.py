"""Continuous-batching serving orchestrator (JetStream-style).

Layering:
  queue.py     — arrival-ordered RequestQueue with backpressure
                 (typed QueueFull) and boundary validation (InvalidRequest)
  scheduler.py — Scheduler policy + Orchestrator loop interleaving
                 chunked prefill with batched decode; with
                 ``SchedulerConfig.dispatch_ahead >= 1`` decode runs
                 through the two-phase dispatch/collect surface so host
                 work overlaps the in-flight device step
  session.py   — ServeSession, the public client API: submit -> handle,
                 sync/async token iteration, mid-stream cancellation,
                 per-request deadlines
  stream.py    — per-request token streaming with TTFT/TPOT timestamps
  telemetry.py — throughput / latency percentiles / memory snapshots /
                 admission-rate aggregation, on top of the
                 repro.serving.obs metrics registry (tick-phase
                 wall-time breakdown + live windowed report line)

Observability (repro.serving.obs): pass ``tracer=Tracer(...)`` to the
Orchestrator/ServeSession to record per-request lifecycle spans and
per-tick phase spans into a ring buffer, exportable as Chrome-trace JSON
(``repro.serving.obs.export.write_chrome_trace``); pass
``metrics_interval_s=...`` for a live periodic metrics line.

The Orchestrator drives any backend implementing the
:class:`repro.serving.backend.EngineBackend` protocol through its
prefill / insert / step_batch / collect API — the concrete WG-KV
Engine, the dense full-KV baseline, or a static-admission baseline
(``repro.serving.backend.make_backend``). No concrete engine is imported
here: orchestrator code is protocol-only by construction.
"""
from repro.serving.backend import (BackendCapabilities, EngineBackend,
                                   InflightStep, make_backend)
from repro.serving.orchestrator.queue import (InvalidRequest, QueueFull,
                                              RequestQueue, ServeRequest)
from repro.serving.orchestrator.scheduler import (Orchestrator, Scheduler,
                                                  SchedulerConfig)
from repro.serving.orchestrator.session import RequestHandle, ServeSession
from repro.serving.orchestrator.stream import StreamMux, TokenStream
from repro.serving.orchestrator.telemetry import Telemetry

__all__ = ["BackendCapabilities", "EngineBackend", "InflightStep",
           "make_backend", "InvalidRequest", "QueueFull", "RequestQueue",
           "ServeRequest", "Orchestrator", "Scheduler", "SchedulerConfig",
           "RequestHandle", "ServeSession", "StreamMux", "TokenStream",
           "Telemetry"]
