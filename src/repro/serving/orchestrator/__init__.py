"""Continuous-batching serving orchestrator (JetStream-style).

Layering:
  queue.py     — arrival-ordered RequestQueue with backpressure
  scheduler.py — Scheduler policy + Orchestrator loop interleaving
                 chunked prefill with batched decode
  stream.py    — per-request token streaming with TTFT/TPOT timestamps
  telemetry.py — throughput / latency percentiles / memory snapshots /
                 admission-rate aggregation

The Orchestrator drives any backend implementing the
:class:`repro.serving.backend.EngineBackend` protocol through its
prefill / insert / generate API — the concrete WG-KV Engine, the dense
full-KV baseline, or a static-admission baseline
(``repro.serving.backend.make_backend``). No concrete engine is imported
here: orchestrator code is protocol-only by construction.
"""
from repro.serving.backend import (BackendCapabilities, EngineBackend,
                                   make_backend)
from repro.serving.orchestrator.queue import (QueueFull, RequestQueue,
                                              ServeRequest)
from repro.serving.orchestrator.scheduler import (Orchestrator, Scheduler,
                                                  SchedulerConfig)
from repro.serving.orchestrator.stream import StreamMux, TokenStream
from repro.serving.orchestrator.telemetry import Telemetry

__all__ = ["BackendCapabilities", "EngineBackend", "make_backend",
           "QueueFull", "RequestQueue", "ServeRequest", "Orchestrator",
           "Scheduler", "SchedulerConfig", "StreamMux", "TokenStream",
           "Telemetry"]
