"""Continuous-batching serving orchestrator (JetStream-style).

Layering:
  queue.py     — arrival-ordered RequestQueue with backpressure
  scheduler.py — Scheduler policy + Orchestrator loop interleaving
                 chunked prefill with batched decode
  stream.py    — per-request token streaming with TTFT/TPOT timestamps
  telemetry.py — throughput / latency percentiles / pool utilization /
                 admission-rate aggregation

The Orchestrator drives a serving Engine (serving/engine.py) through its
prefill / insert / generate backend API.
"""
from repro.serving.orchestrator.queue import (QueueFull, RequestQueue,
                                              ServeRequest)
from repro.serving.orchestrator.scheduler import (Orchestrator, Scheduler,
                                                  SchedulerConfig)
from repro.serving.orchestrator.stream import StreamMux, TokenStream
from repro.serving.orchestrator.telemetry import Telemetry

__all__ = ["QueueFull", "RequestQueue", "ServeRequest", "Orchestrator",
           "Scheduler", "SchedulerConfig", "StreamMux", "TokenStream",
           "Telemetry"]
