"""ServeSession: the public client surface over the serving orchestrator.

The Orchestrator (scheduler.py) is the *mechanism* — a tick loop over
queue/prefill/dispatch/collect. ServeSession is the *API* a frontend
programs against, in the spirit of JetStream's client-facing driver:

    session = ServeSession(make_backend("wgkv", params, cfg, slots=4))
    h = session.submit(prompt, max_new=64, deadline_s=2.0)
    for tok in h:                    # pumps the loop; yields as decoded
        emit(tok)
    session.cancel(h.rid)            # or: h.cancel() — mid-stream is fine
    session.close()                  # drain in-flight work, stop telemetry

Contract:

  * ``submit`` returns a :class:`RequestHandle` immediately, or raises
    the typed :class:`QueueFull` (backpressure: queue depth and bound
    attached; the request was NOT enqueued — shed load or retry) /
    :class:`InvalidRequest` (malformed: never retriable).
  * Tokens stream through the handle: ``for tok in handle`` (sync) or
    ``async for tok in handle.astream()`` (cooperative asyncio wrapper);
    both pump ``session.tick()`` only while output is pending, so many
    handles can be consumed concurrently.
  * ``cancel`` works at ANY stage — queued, mid-prefill, mid-decode.
    Mid-decode the slot is freed and its paged-pool pages reclaimed on
    the spot; tokens an already-dispatched step produces for the freed
    row are discarded by the engine's generation guard, so surviving
    streams are byte-identical to an uncancelled run.
  * The session defaults to ``dispatch_ahead=1`` (the two-phase
    dispatch/collect driver): host work for decode step t overlaps
    device compute for step t+1. Pass a ``SchedulerConfig`` with
    ``dispatch_ahead=0`` for the synchronous baseline.
"""
from __future__ import annotations

import asyncio
import dataclasses
from typing import AsyncIterator, Iterator, List, Optional

from repro.serving.backend import EngineBackend
from repro.serving.orchestrator.scheduler import Orchestrator, SchedulerConfig
from repro.serving.orchestrator.stream import OnToken

# ticks tolerated without any work or token progress before an iterator
# concludes the loop is wedged (scheduler bug) instead of spinning forever
_STALL_TICKS = 10_000


@dataclasses.dataclass
class RequestHandle:
    """One submitted request: stream cursor + lifecycle view + cancel."""
    session: "ServeSession"
    rid: int

    # ---- lifecycle ---------------------------------------------------
    @property
    def state(self) -> str:
        """queued | prefill | decode | done | cancelled"""
        return self.session.orchestrator.queue.requests[self.rid].state

    @property
    def done(self) -> bool:
        return self.state == "done"

    @property
    def cancelled(self) -> bool:
        return self.state == "cancelled"

    def tokens(self) -> List[int]:
        """Tokens streamed so far (does not pump the loop)."""
        return list(self.session.orchestrator.tokens(self.rid))

    def cancel(self) -> bool:
        return self.session.cancel(self.rid)

    # ---- streaming ---------------------------------------------------
    def _pump(self) -> Iterator[Optional[int]]:
        """Shared token pump behind both iterators: yields each new token
        as it lands, and ``None`` after a scheduler tick that produced no
        token for this stream (the async adapter uses those gaps to yield
        control). Ends when the stream closes; raises if the loop makes
        no progress for _STALL_TICKS ticks (scheduler wedge, not EOS)."""
        stream = self.session.orchestrator.mux.streams[self.rid]
        i, stalled = 0, 0
        while True:
            while i < len(stream.tokens):
                stalled = 0
                yield stream.tokens[i]
                i += 1
            if stream.closed:
                return
            worked = self.session.tick()
            stalled = 0 if worked else stalled + 1
            if stalled > _STALL_TICKS:
                raise RuntimeError(
                    f"request {self.rid} stalled: no scheduler progress for "
                    f"{_STALL_TICKS} ticks (state={self.state})")
            yield None

    def __iter__(self) -> Iterator[int]:
        """Yield tokens as the serving loop produces them, pumping
        ``session.tick()`` whenever the stream is dry. Ends when the
        request finishes or is cancelled (partial stream)."""
        return (tok for tok in self._pump() if tok is not None)

    async def astream(self) -> AsyncIterator[int]:
        """``async for`` adapter over the same pump: yields control to
        the event loop between scheduler ticks so other coroutines (e.g.
        other handles' astream consumers) interleave."""
        for tok in self._pump():
            if tok is None:
                await asyncio.sleep(0)
            else:
                yield tok

    def result(self) -> List[int]:
        """Pump until terminal and return the full (possibly partial, if
        cancelled) token list."""
        for _ in self:
            pass
        return self.tokens()


class ServeSession:
    """Client session over one engine backend: submit / stream / cancel.

    ``sched`` defaults to the dispatch-ahead driver
    (``dispatch_ahead=1``); everything else (chunking, backpressure
    bound) is the orchestrator's. Pass ``prefix_cache=PrefixCache(
    quantum=sched.chunk_tokens, free_fn=engine.release_prefix)`` to
    enable content-addressed shared-context reuse across requests
    (serving/prefix_cache.py) — the store outlives the session, so
    multi-turn drivers reuse prefixes across rounds."""

    def __init__(self, engine: EngineBackend, *,
                 sched: Optional[SchedulerConfig] = None,
                 max_pending: Optional[int] = None, **orch_kw):
        if sched is None:
            sched = SchedulerConfig(dispatch_ahead=1)
        self.orchestrator = Orchestrator(engine, sched=sched,
                                         max_pending=max_pending, **orch_kw)
        self._closed = False

    # ---- submission --------------------------------------------------
    def submit(self, prompt: List[int], max_new: int = 32, *,
               deadline_s: Optional[float] = None,
               on_token: Optional[OnToken] = None) -> RequestHandle:
        """Enqueue a request and return its handle. Raises the typed
        :class:`repro.serving.orchestrator.queue.QueueFull` under
        backpressure and :class:`InvalidRequest` for malformed requests."""
        assert not self._closed, "session is closed"
        rid = self.orchestrator.submit(prompt, max_new, on_token=on_token,
                                       deadline_s=deadline_s)
        return RequestHandle(self, rid)

    def cancel(self, rid: int) -> bool:
        """Cancel a request at any stage (mid-stream included): its slot
        is freed and paged-pool pages reclaimed immediately; its stream
        closes with ``cancelled=True``."""
        return self.orchestrator.cancel(rid)

    # ---- loop control ------------------------------------------------
    def tick(self) -> bool:
        return self.orchestrator.tick()

    def run(self, max_ticks: int = 10_000) -> None:
        """Drive until every submitted request is terminal."""
        self.orchestrator.run(max_ticks)

    def close(self) -> None:
        """Drain in-flight device work and stop telemetry. Idempotent;
        the session rejects new submissions afterwards."""
        if not self._closed:
            self.orchestrator.drain()
            self.orchestrator.telemetry.stop()
            self._closed = True

    def __enter__(self) -> "ServeSession":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ---- views -------------------------------------------------------
    @property
    def engine(self) -> EngineBackend:
        return self.orchestrator.engine

    @property
    def telemetry(self):
        return self.orchestrator.telemetry

    @property
    def tracer(self):
        """The orchestrator's span tracer (pass ``tracer=Tracer(...)`` at
        construction; defaults to the no-op NULL_TRACER). Export with
        :func:`repro.serving.obs.export.write_chrome_trace`."""
        return self.orchestrator.tracer

    def report(self) -> str:
        return self.orchestrator.telemetry.report()
