"""Scheduler + Orchestrator: continuous batching over any EngineBackend.

The orchestrator depends only on the :class:`EngineBackend` protocol
(serving/backend.py) — never on a concrete engine — so the same scheduler,
queue, streams, and telemetry serve the WG-KV dual cache, the dense
full-KV baseline, and the static-admission baselines interchangeably
(pick one with ``repro.serving.backend.make_backend``).

Each tick interleaves three kinds of work:

  1. **admit** — pop arrival-ordered requests from the queue into free
     slots (a slot is reserved while its prefill is in flight);
  2. **chunked prefill** — advance in-flight prefill tasks by one
     ``chunk_tokens`` chunk (``w_local``-aligned inside the engine), so a
     long prompt never blocks the batched decode for more than a chunk;
     when a task completes it is inserted and its first token streams
     immediately (TTFT ends here, JetStream-style);
  3. **batched decode** — one ``generate`` step over all live slots,
     streaming one token per request; finished requests free their slot
     and paged-pool pages on the spot so the next arrival can join.

The Scheduler is the pure policy (how many to admit, how many prefill
tasks to advance, whether to decode); the Orchestrator executes the plan
against the engine, streams, and telemetry.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

from repro.serving.backend import EngineBackend, PrefillTask
from repro.serving.orchestrator.queue import (QueueFull, RequestQueue,
                                              ServeRequest)
from repro.serving.orchestrator.stream import OnToken, StreamMux
from repro.serving.orchestrator.telemetry import Telemetry


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    chunk_tokens: int = 64        # prefill tokens per task per tick
    prefill_concurrency: int = 1  # prefill tasks advanced per tick
    decode_while_prefill: bool = True  # decode between prefill chunks
    # ticks between backend memory_snapshot() samples. Snapshots sync a few
    # small device counters per layer to host; the default samples every
    # tick so kv/pool peaks are exact (the A/B memory axis). Raise it to
    # lighten the tick loop on deep models — at the cost of possibly
    # missing a short-lived peak between samples.
    memory_sample_every: int = 1

    def __post_init__(self):
        if self.chunk_tokens < 1:
            raise ValueError(f"chunk_tokens must be >= 1, got {self.chunk_tokens}")
        if self.prefill_concurrency < 1:
            raise ValueError("prefill_concurrency must be >= 1")
        if self.memory_sample_every < 1:
            raise ValueError("memory_sample_every must be >= 1")


@dataclasses.dataclass(frozen=True)
class Plan:
    admit: int            # queued requests to move into reserved slots
    advance_prefills: int  # in-flight prefill tasks to advance one chunk
    decode: bool          # run one batched decode step


class Scheduler:
    """Pure per-tick scheduling policy."""

    def __init__(self, cfg: SchedulerConfig = SchedulerConfig()):
        self.cfg = cfg

    def plan(self, *, free_slots: int, queue_depth: int,
             active_prefills: int, live_decodes: int) -> Plan:
        admit = min(free_slots, queue_depth)
        advance = min(active_prefills + admit, self.cfg.prefill_concurrency)
        decode = live_decodes > 0 and (
            self.cfg.decode_while_prefill or (active_prefills + admit) == 0)
        return Plan(admit=admit, advance_prefills=advance, decode=decode)


class Orchestrator:
    """Continuous-batching serving loop over any EngineBackend."""

    def __init__(self, engine: EngineBackend, *,
                 sched: SchedulerConfig = SchedulerConfig(),
                 max_pending: Optional[int] = None,
                 clock: Callable[[], float] = time.monotonic):
        self.engine = engine
        self.scheduler = Scheduler(sched)
        self.clock = clock
        self.queue = RequestQueue(max_pending, clock)
        self.mux = StreamMux(clock)
        self.telemetry = Telemetry(clock)
        self.slot_req: List[Optional[ServeRequest]] = [None] * engine.slots
        # rid -> (request, prefill task), in admission order
        self._prefills: Dict[int, "tuple[ServeRequest, PrefillTask]"] = {}
        # engines are reusable (e.g. benchmark warmup); report stat deltas
        # relative to this orchestrator's birth, not engine lifetime totals
        self._stats0 = dict(engine.stats)

    # ------------------------------------------------------------------
    def submit(self, prompt: List[int], max_new: int = 32,
               on_token: Optional[OnToken] = None) -> int:
        """Enqueue a request (raises QueueFull under backpressure) and
        open its token stream."""
        try:
            rid = self.queue.submit(prompt, max_new)
        except QueueFull:
            # keep shed-load telemetry fresh even if no tick follows
            self.telemetry.counters["rejected"] = float(self.queue.rejected)
            raise
        req = self.queue.requests[rid]
        self.mux.open(rid, req.arrival_t, on_token)
        return rid

    def _free_slots(self) -> List[int]:
        return [s for s, r in enumerate(self.slot_req) if r is None]

    # ------------------------------------------------------------------
    def tick(self) -> bool:
        """One scheduling round; returns True if any work was done."""
        self.telemetry.start()
        self.telemetry.bump("ticks")
        plan = self.scheduler.plan(
            free_slots=len(self._free_slots()),
            queue_depth=self.queue.depth,
            active_prefills=len(self._prefills),
            live_decodes=sum(self.engine.live))
        worked = False

        # 1) admit: queued request -> reserved slot + prefill task
        for _ in range(plan.admit):
            req = self.queue.pop()
            if req is None:
                break
            slot = self._free_slots()[0]
            req.slot, req.state = slot, "prefill"
            self.slot_req[slot] = req
            self._prefills[req.rid] = (req, self.engine.start_prefill(req.prompt))
            worked = True

        # 2) chunked prefill: advance the oldest in-flight tasks
        for rid in list(self._prefills)[:plan.advance_prefills]:
            req, task = self._prefills[rid]
            pos0 = task.pos
            done = self.engine.prefill_step(
                task, self.scheduler.cfg.chunk_tokens)
            self.telemetry.bump("prefill_chunks")
            self.telemetry.bump("prefill_tokens", task.pos - pos0)
            worked = True
            if done:
                prefix = self.engine.finish_prefill(task, emit_first=True)
                self.engine.insert(prefix, req.slot)
                req.state = "decode"
                req.mean_admission = prefix.mean_admission
                del self._prefills[rid]
                self._deliver(req, prefix.first_token)

        # 3) batched decode over live slots
        if plan.decode:
            out = self.engine.generate()
            if out:
                self.telemetry.bump("decode_steps")
                worked = True
            for slot, tok in out.items():
                req = self.slot_req[slot]
                if req is not None and req.state == "decode":
                    self._deliver(req, tok)

        if (self.telemetry.counters["ticks"] - 1) % \
                self.scheduler.cfg.memory_sample_every == 0:
            self.telemetry.sample_memory(self.engine.memory_snapshot())
        self.telemetry.counters["rejected"] = float(self.queue.rejected)
        for k in ("evict_triggers", "decode_adm_sum"):
            self.telemetry.counters[k] = \
                self.engine.stats.get(k, 0.0) - self._stats0.get(k, 0.0)
        return worked

    def _deliver(self, req: ServeRequest, token: int) -> None:
        """Stream one token to a request; retire it when finished."""
        req.out.append(int(token))
        now = self.clock()
        is_last = (len(req.out) >= req.max_new
                   or (self.engine.eos is not None
                       and int(token) == self.engine.eos))
        self.mux.emit(req.rid, int(token), is_last)
        if is_last:
            req.state = "done"
            req.finish_t = now
            self.engine.free_slot(req.slot)
            self.slot_req[req.slot] = None
            st = self.mux.streams[req.rid]
            self.telemetry.record_request(
                rid=req.rid, prompt_len=len(req.prompt), n_out=len(req.out),
                ttft=st.ttft, tpot=st.tpot,
                e2e=req.finish_t - req.arrival_t,
                mean_admission=req.mean_admission)

    # ------------------------------------------------------------------
    def run(self, max_ticks: int = 10_000) -> None:
        """Tick until every submitted request has completed."""
        self.telemetry.start()
        for _ in range(max_ticks):
            if self.queue.all_done():
                break
            self.tick()
        self.telemetry.stop()

    def tokens(self, rid: int) -> List[int]:
        return self.mux.tokens(rid)
