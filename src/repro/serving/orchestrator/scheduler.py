"""Scheduler + Orchestrator: continuous batching over any EngineBackend.

The orchestrator depends only on the :class:`EngineBackend` protocol
(serving/backend.py) — never on a concrete engine — so the same scheduler,
queue, streams, and telemetry serve the WG-KV dual cache, the dense
full-KV baseline, and the static-admission baselines interchangeably
(pick one with ``repro.serving.backend.make_backend``).

Every tick runs the FUSED megabatch step: ONE ``step_batch`` call — a
single jitted ragged device call advancing every live row of the
engine's persistent batched cache tree, whatever its phase: first-chunk
opens (spliced in empty, scanned from position 0), mid-prefill chunk
extends, and piggybacked length-1 decode rows, with sampling inside the
same call. A row whose prompt completes delivers its FIRST token at that
step's collect (state prefill -> decode with no separate
finish_prefill/insert — the row is already resident and live), and
dispatch-ahead keeps fused steps in flight exactly like decode steps.
(The unfused phase-per-phase tick and its ``fused_step`` /
``batched_prefill`` toggles served their deprecation cycle and are
gone.) On a selection-configured backend (``make_backend(...,
selection="quest:K")``) the decode-only top-up dispatches run gathered
top-K page selection; ticks carrying prompt chunks stay on the full
path.

Each tick interleaves three kinds of work:

  1. **admit** — pop arrival-ordered requests from the queue into free
     slots (a slot is reserved while its prefill is in flight), after
     cancelling any request whose deadline has passed;
  2. **fused dispatch** — ONE ``step_batch`` call advances every live
     row (chunks capped at ``chunk_tokens`` per task, Sarathi-style
     piggybacked chunking, so a long prompt never blocks decode for
     more than a chunk); with ``dispatch_ahead >= 1``, extra
     decode-only steps top the in-flight window up WITHOUT
     synchronizing (the on-device sampled-token feed lets step t+1
     queue behind step t — JetStream's driver-thread overlap without
     threads);
  3. **collect** — synchronize the OLDEST in-flight step (host
     mirroring, sampling pull, stats) and stream one token per live
     request; finished requests free their slot and paged-pool pages on
     the spot so the next arrival can join. With ``dispatch_ahead=0``
     the step dispatched this tick is collected this tick.

The Scheduler is the pure policy (how many to admit, how many prefill
tasks to advance, whether to decode); the Orchestrator executes the plan
against the engine, streams, and telemetry. :class:`ServeSession`
(session.py) is the public client surface over this loop.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable, Deque, Dict, List, Optional

from repro.serving.backend import EngineBackend, FusedStep
from repro.serving.obs.trace import (CAT_ENGINE, CAT_REQUEST, LANE_REQ,
                                     LANE_TICK, NULL_TRACER, Tracer)
from repro.serving.orchestrator.queue import (InvalidRequest, QueueFull,
                                              RequestQueue, ServeRequest)
from repro.serving.orchestrator.stream import OnToken, StreamMux
from repro.serving.orchestrator.telemetry import Telemetry

# engine-side stat counters mirrored into telemetry as deltas relative to
# the orchestrator's birth (engines are reusable across replays):
# eviction/admission plus the prefill sub-phase counters (extend_* for
# the coalesced ragged advances of the offline prefill wrapper)
_ENGINE_STAT_KEYS = ("evict_triggers", "decode_adm_sum",
                     "extend_time_s", "extend_tokens",
                     # fused megabatch ticks: dispatch->collect wall and
                     # the prefill-stage share (the compile-free
                     # prefill tokens/s numerator bench_serving reports)
                     "fused_steps", "fused_time_s",
                     "fused_prefill_time_s", "fused_prefill_tokens",
                     # fixed-shape padding accounting (active vs padded
                     # rows per fused dispatch -> fused_padding_frac)
                     "fused_slot_rows", "fused_active_rows",
                     # decode-time page selection (gathered top-K ticks)
                     "selected_pages", "selection_time_s")


class _Phase:
    """Times one tick phase against the orchestrator's clock, folding the
    duration into a telemetry counter AND emitting an engine-lane tracer
    span. With the default :data:`NULL_TRACER` the span add is a no-op
    branch, so always-on phase accounting costs two clock reads."""
    __slots__ = ("orch", "name", "counter", "args", "t0")

    def __init__(self, orch: "Orchestrator", name: str, counter: str,
                 args: Optional[Dict]):
        self.orch = orch
        self.name = name
        self.counter = counter
        self.args = args

    def __enter__(self) -> "_Phase":
        self.t0 = self.orch.clock()
        return self

    def __exit__(self, *exc) -> bool:
        t1 = self.orch.clock()
        self.orch.telemetry.bump(self.counter, t1 - self.t0)
        self.orch.tracer.add(self.name, self.t0, t1, cat=CAT_ENGINE,
                             lane=(LANE_TICK, 0), args=self.args)
        return False


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    chunk_tokens: int = 64        # prefill tokens per task per tick
    # prefill tasks advanced per tick — in ONE batched ragged device call
    # when the backend supports it. None = every in-flight prefill, every
    # tick (bounded by the slot count, since each task holds a reserved
    # slot); set a cap to bound the batched call's latency on deep models.
    # (Replaces the retired ``prefill_concurrency`` knob, whose "how many
    # separate batch-1 calls per tick" semantics the batched path made
    # vacuous. The ``batched_prefill`` / ``fused_step`` fallback toggles
    # served their deprecation cycle and are gone — every tick is ONE
    # fused jitted ragged step_batch call.)
    max_prefill_batch: Optional[int] = None
    decode_while_prefill: bool = True  # decode between prefill chunks
    # decode steps kept in flight on the device (two-phase
    # dispatch/collect; backend.py). 0 = one synchronous dispatch+collect
    # per tick (the pre-async behavior, the parity/regression baseline);
    # >= 1 dispatches step t+1 before step t's result touches the host,
    # so per-tick host work (paged-pool mirroring, sampling pulls,
    # chunked prefill) overlaps device compute.
    dispatch_ahead: int = 0
    # ticks between backend memory_snapshot() samples. Snapshots sync a few
    # small device counters per layer to host; the default samples every
    # tick so kv/pool peaks are exact (the A/B memory axis). Raise it to
    # lighten the tick loop on deep models — at the cost of possibly
    # missing a short-lived peak between samples. (Sampling waits on the
    # newest dispatched step, so under dispatch_ahead it runs at the top
    # of the tick, before new work is enqueued behind the in-flight step.)
    memory_sample_every: int = 1

    def __post_init__(self):
        if self.chunk_tokens < 1:
            raise ValueError(f"chunk_tokens must be >= 1, got {self.chunk_tokens}")
        if self.max_prefill_batch is not None and self.max_prefill_batch < 1:
            raise ValueError("max_prefill_batch must be >= 1 or None")
        if self.dispatch_ahead < 0:
            raise ValueError("dispatch_ahead must be >= 0")
        if self.memory_sample_every < 1:
            raise ValueError("memory_sample_every must be >= 1")


@dataclasses.dataclass(frozen=True)
class Plan:
    admit: int            # queued requests to move into reserved slots
    advance_prefills: int  # in-flight prefill tasks to advance one chunk
    decode: bool          # run one batched decode step


class Scheduler:
    """Pure per-tick scheduling policy."""

    def __init__(self, cfg: SchedulerConfig = SchedulerConfig()):
        self.cfg = cfg

    def plan(self, *, free_slots: int, queue_depth: int,
             active_prefills: int, live_decodes: int) -> Plan:
        admit = min(free_slots, queue_depth)
        advance = active_prefills + admit
        if self.cfg.max_prefill_batch is not None:
            advance = min(advance, self.cfg.max_prefill_batch)
        decode = live_decodes > 0 and (
            self.cfg.decode_while_prefill or (active_prefills + admit) == 0)
        return Plan(admit=admit, advance_prefills=advance, decode=decode)


class Orchestrator:
    """Continuous-batching serving loop over any EngineBackend."""

    def __init__(self, engine: EngineBackend, *,
                 sched: SchedulerConfig = SchedulerConfig(),
                 max_pending: Optional[int] = None,
                 clock: Callable[[], float] = time.monotonic,
                 tracer: Optional[Tracer] = None,
                 metrics_interval_s: Optional[float] = None,
                 on_metrics: Callable[[str], None] = print,
                 prefix_cache=None):
        self.engine = engine
        # content-addressed prefix store (serving/prefix_cache.py): cache
        # state is only capturable/resumable at chunk boundaries, so the
        # store's hash quantum must BE the scheduler chunk
        self.prefix_cache = prefix_cache
        if prefix_cache is not None and \
                prefix_cache.quantum != sched.chunk_tokens:
            raise ValueError(
                f"prefix_cache.quantum={prefix_cache.quantum} must equal "
                f"sched.chunk_tokens={sched.chunk_tokens}: prefixes are "
                "only capturable/resumable at chunk boundaries")
        # id(step) -> [(req, task, n_tokens, key)] capture obligations
        # that mature when that in-flight step is collected
        self._captures: Dict[int, List] = {}
        self.scheduler = Scheduler(sched)
        self.clock = clock
        # observability: the tracer records request-lifecycle and
        # tick-phase spans (NULL_TRACER = disabled, branch-cheap); the
        # engine gets the same handle so its fused_open/extend_ragged
        # sub-phases land on the same timeline
        self.tracer = tracer if tracer is not None else NULL_TRACER
        engine.tracer = self.tracer
        self._metrics_interval = metrics_interval_s
        self._on_metrics = on_metrics
        self.queue = RequestQueue(max_pending, clock)
        self.mux = StreamMux(clock)
        self.telemetry = Telemetry(clock)
        self.slot_req: List[Optional[ServeRequest]] = [None] * engine.slots
        # rid -> (request, prefill task), in admission order
        self._prefills: Dict[int, "tuple[ServeRequest, PrefillTask]"] = {}
        # dispatched-but-uncollected fused steps, oldest first
        self._inflight: Deque[FusedStep] = collections.deque()
        # requests with a live deadline (rid -> request): the per-tick
        # expiry check stays O(active deadlines), not O(every request
        # ever submitted to this long-lived session)
        self._deadlined: Dict[int, ServeRequest] = {}
        # engines are reusable (e.g. benchmark warmup); report stat deltas
        # relative to this orchestrator's birth, not engine lifetime totals
        self._stats0 = dict(engine.stats)

    # ------------------------------------------------------------------
    def submit(self, prompt: List[int], max_new: int = 32,
               on_token: Optional[OnToken] = None,
               deadline_s: Optional[float] = None) -> int:
        """Enqueue a request and open its token stream. Raises the typed
        :class:`QueueFull` under backpressure (request not enqueued;
        retry after draining) and :class:`InvalidRequest` for requests
        that can never be served. With ``deadline_s`` the request is
        cancelled — mid-stream if need be — once that many seconds have
        passed since arrival."""
        try:
            rid = self.queue.submit(prompt, max_new, deadline_s=deadline_s)
        except QueueFull:
            # keep shed-load telemetry fresh even if no tick follows
            self.telemetry.counters["rejected"] = float(self.queue.rejected)
            raise
        req = self.queue.requests[rid]
        if req.deadline_t is not None:
            self._deadlined[rid] = req
        self.mux.open(rid, req.arrival_t, on_token)
        return rid

    def _free_slots(self) -> List[int]:
        return [s for s, r in enumerate(self.slot_req) if r is None]

    # ------------------------------------------------------------------
    # cancellation (explicit via ServeSession.cancel, or deadline expiry)
    # ------------------------------------------------------------------
    def cancel(self, rid: int, *, reason: str = "cancelled") -> bool:
        """Cancel a request at any lifecycle stage: drop it from the
        pending queue, abandon its in-flight prefill, or — mid-stream —
        free its decode slot and reclaim its paged-pool pages on the
        spot. The engine's per-slot generation guard discards any token
        an already-dispatched step produces for the freed row, so
        surviving requests' streams are untouched. Returns False when the
        request is unknown or already finished."""
        req = self.queue.requests.get(rid)
        if req is None or req.state in ("done", "cancelled"):
            return False
        was = req.state
        if req.state == "queued":
            self.queue.remove(rid)
        elif req.state == "prefill":
            # drop the task and release the reservation; under the fused
            # tick the task's state is RESIDENT in the engine's batched
            # tree (and may already be live if its last chunk was
            # dispatched), so the row must be freed too — the per-slot
            # generation guard discards anything in-flight steps still
            # produce for it
            ent = self._prefills.pop(rid, None)
            if (ent is not None and self.prefix_cache is not None
                    and ent[1].prefix_entry is not None):
                # admitted on a prefix hit but cancelled before its first
                # dispatch spliced the entry in: drop the store pin so a
                # pending eviction can reclaim the entry
                self.prefix_cache.release(ent[1].prefix_entry)
                ent[1].prefix_entry = None
            with self._phase("evict", counter="evict_time_s",
                             slot=req.slot, rid=rid):
                self.engine.free_slot(req.slot)
            self.slot_req[req.slot] = None
        elif req.state == "decode":
            with self._phase("evict", counter="evict_time_s",
                             slot=req.slot, rid=rid):
                self.engine.free_slot(req.slot)
            self.slot_req[req.slot] = None
        req.state = "cancelled"
        req.finish_t = self.clock()
        self._close_request_spans(req)
        self.tracer.instant(reason, cat=CAT_REQUEST, lane=(LANE_REQ, rid),
                            rid=rid, was=was)
        self.mux.close(rid, cancelled=True)
        self.telemetry.bump("cancelled")
        if reason == "deadline":
            self.telemetry.bump("deadline_expired")
        return True

    # ------------------------------------------------------------------
    # observability helpers (tick phases + request-lane spans)
    # ------------------------------------------------------------------
    def _phase(self, name: str, *, counter: Optional[str] = None,
               **args) -> _Phase:
        """Engine-lane phase timer: accumulates into the telemetry
        counter (default ``<name>_time_s``) and traces a span."""
        return _Phase(self, name, counter or f"{name}_time_s",
                      args or None)

    def _close_request_spans(self, req: ServeRequest) -> None:
        """Emit the request's terminal lifecycle span: the decode phase
        runs from insert to finish/cancel (prefill/queued spans were
        emitted at their own transitions)."""
        if req.insert_t is not None and req.finish_t is not None:
            self.tracer.add("decode", req.insert_t, req.finish_t,
                            cat=CAT_REQUEST, lane=(LANE_REQ, req.rid),
                            args={"rid": req.rid, "slot": req.slot,
                                  "n_out": len(req.out)})

    def _dispatch_is_useful(self) -> bool:
        """True while some decoding request still wants a token beyond
        the steps already in flight. Each in-flight step yields at most
        one token per live row, so once ``len(_inflight)`` covers every
        live request's remaining ``max_new`` budget, a further dispatch
        can only produce discarded tokens. (EOS can still finish a
        request earlier — that waste is bounded by the window depth and
        unknowable in advance.)"""
        ahead = len(self._inflight)

        def wants_more(req) -> bool:
            if req is None:
                return False
            if req.state == "decode":
                return req.max_new - len(req.out) > ahead
            # fused path: a request whose last chunk was dispatched is
            # live and decoding, but stays state=="prefill" until its
            # first token is collected
            if req.state == "prefill" and req.rid in self._prefills:
                task = self._prefills[req.rid][1]
                return task.done and req.max_new - len(req.out) > ahead
            return False

        return any(wants_more(req) for req in self.slot_req)

    def _expire_deadlines(self) -> None:
        if not self._deadlined:
            return
        now = self.clock()
        for rid, req in list(self._deadlined.items()):
            if req.state in ("done", "cancelled"):
                del self._deadlined[rid]
            elif now > req.deadline_t:
                self.cancel(rid, reason="deadline")
                self._deadlined.pop(rid, None)

    # ------------------------------------------------------------------
    # content-addressed prefix cache (serving/prefix_cache.py): hit at
    # admission -> splice-and-resume; capture at the collect of the step
    # whose row position lands on the target chunk boundary
    # ------------------------------------------------------------------
    def _prefix_admit(self, req: ServeRequest, task) -> None:
        """Try the store at admission: on a hit the task starts at the
        entry's boundary (step_batch splices the cached tree instead of
        an empty one — the fused scan resumes at the suffix); on a miss
        (or a shorter-than-ideal hit) plan a capture at the longest
        unstored aligned boundary of this prompt."""
        pc = self.prefix_cache
        entry = pc.lookup(req.prompt)
        if entry is not None:
            task.prefix_entry = entry
            task.pos = entry.n_tokens
            task.adm_weighted = entry.adm_weighted
            req.prefix_hit = True
            req.prefix_tokens = entry.n_tokens
            self.telemetry.bump("prefix_hit")
            self.tracer.instant("prefix_hit", cat=CAT_REQUEST,
                                lane=(LANE_REQ, req.rid), rid=req.rid,
                                tokens=entry.n_tokens, key=entry.key)
        else:
            self.telemetry.bump("prefix_miss")
        plan = pc.capture_target(req.prompt)
        if plan is not None and (entry is None or plan[0] > entry.n_tokens):
            task.capture_plan = plan

    def _prefix_after_dispatch(self, step, pairs) -> None:
        """Post-dispatch bookkeeping for the tasks just advanced: drop
        admission pins (the splice copied the entry's device tree into
        the slot row and the pool mirror is shared by COW refcount, so
        the slot no longer depends on the entry) and register capture
        obligations against the step whose ``after`` tree holds the row
        at exactly the target boundary."""
        if step is None:
            return
        pc = self.prefix_cache
        for req, task in pairs:
            if task.prefix_entry is not None:
                pc.release(task.prefix_entry)
                task.prefix_entry = None
            if task.capture_plan is not None:
                n, key = task.capture_plan
                if task.pos == n:
                    self._captures.setdefault(id(step), []).append(
                        (req, task, n, key))
                if task.pos >= n:
                    task.capture_plan = None

    def _run_captures(self, step) -> None:
        """Mature this collected step's capture obligations: snapshot the
        slot's post-admission cache state (``capture_prefix`` is a
        sanctioned host sync, like the collect that just ran) and insert
        it into the store. FIFO collect means the task's ``adm_weighted``
        covers exactly the captured prefix here."""
        jobs = self._captures.pop(id(step), None)
        if not jobs:
            return
        pc = self.prefix_cache
        for req, task, n, key in jobs:
            if self._prefills.get(req.rid, (None, None))[1] is not task:
                continue   # cancelled while the step was in flight
            if key in pc:
                continue   # another request already captured this prefix
            with self._phase("prefix_capture",
                             counter="prefix_capture_time_s",
                             rid=req.rid, slot=task.slot, tokens=n):
                entry = self.engine.capture_prefix(
                    step, task.slot, key, adm_weighted=task.adm_weighted)
            pc.insert(entry)
            self.tracer.instant("prefix_capture", cat=CAT_REQUEST,
                                lane=(LANE_REQ, req.rid), rid=req.rid,
                                tokens=n)
        self.telemetry.counters["prefix_evict"] = float(pc.evictions)
        self.telemetry.counters["prefix_bytes"] = float(pc.bytes_used)

    # ------------------------------------------------------------------
    def tick(self) -> bool:
        """One scheduling round; returns True if any work was done."""
        self.telemetry.start()
        self.telemetry.bump("ticks")
        tick_no = int(self.telemetry.counters["ticks"])
        t_tick0 = self.clock()
        self._expire_deadlines()
        depth = self.scheduler.cfg.dispatch_ahead
        # sample BEFORE dispatching: the snapshot syncs small per-layer
        # counters, so taken later it would wait on the step dispatched
        # this tick and forfeit the overlap dispatch-ahead buys
        if (tick_no - 1) % self.scheduler.cfg.memory_sample_every == 0:
            with self._phase("memory_sample", tick=tick_no):
                self.telemetry.sample_memory(self.engine.memory_snapshot())
        plan = self.scheduler.plan(
            free_slots=len(self._free_slots()),
            queue_depth=self.queue.depth,
            active_prefills=len(self._prefills),
            live_decodes=sum(self.engine.live))
        worked = False

        # 1) admit: queued request -> reserved slot + prefill task
        if plan.admit:
            with self._phase("admit", tick=tick_no, n=plan.admit):
                for _ in range(plan.admit):
                    req = self.queue.pop()
                    if req is None:
                        break
                    slot = self._free_slots()[0]
                    req.slot, req.state = slot, "prefill"
                    now = self.clock()
                    req.admit_t = now
                    # request-lane lifecycle: the queued wait ends here
                    self.tracer.add("queued", req.arrival_t, now,
                                    cat=CAT_REQUEST,
                                    lane=(LANE_REQ, req.rid),
                                    args={"rid": req.rid, "slot": slot,
                                          "prompt_len": len(req.prompt)})
                    self.slot_req[slot] = req
                    task = self.engine.start_prefill(req.prompt)
                    # fused path: the task's row IS the reserved slot
                    # (spliced in empty on its first step_batch)
                    task.slot = slot
                    if self.prefix_cache is not None:
                        self._prefix_admit(req, task)
                    self._prefills[req.rid] = (req, task)
                    worked = True

        # 2) fused dispatch: ONE jitted ragged device call advances every
        # live row — first-chunk opens, mid-prefill extends, and
        # piggybacked decode rows together. The step is dispatched
        # WITHOUT synchronizing and joins the in-flight window; extra
        # decode-only fused steps (where gathered top-K page selection
        # applies, when configured) top the window up to depth + 1. A
        # step is only dispatched while some live request's remaining
        # max_new budget exceeds the tokens already in flight — past
        # that the step is provably wasted.
        adv = list(self._prefills)[:plan.advance_prefills]
        pairs = [self._prefills[rid] for rid in adv]
        tasks = [task for _, task in pairs]
        pos0 = [task.pos for task in tasks]
        chunk = self.scheduler.cfg.chunk_tokens
        with self._phase("fused_step", counter="dispatch_time_s",
                         tick=tick_no, batch=len(tasks),
                         width=sum(self.engine.live)) as ph:
            step = self.engine.step_batch(tasks, chunk,
                                          decode=plan.decode)
            if step is not None:
                self._inflight.append(step)
                self.telemetry.bump("dispatched_steps")
                worked = True
            while (depth > 0 and plan.decode
                   and len(self._inflight) < depth + 1
                   and self._dispatch_is_useful()):
                extra = self.engine.step_batch([], decode=True)
                if extra is None:
                    break
                self._inflight.append(extra)
                self.telemetry.bump("dispatched_steps")
                worked = True
        # per-task chunk accounting at dispatch (positions advance
        # teacher-forced inside step_batch; first tokens arrive at
        # collect via _route_tokens)
        t_adv1 = self.clock()
        advanced = 0
        for rid, (req, task), p0 in zip(adv, pairs, pos0):
            took = task.pos - p0
            if took <= 0:
                continue
            advanced += 1
            self.telemetry.bump("prefill_chunks")
            self.telemetry.bump("prefill_tokens", took)
            req.prefill_chunks += 1
            self.tracer.add(f"prefill[chunk {req.prefill_chunks - 1}]",
                            ph.t0, t_adv1, cat=CAT_REQUEST,
                            lane=(LANE_REQ, rid),
                            args={"rid": rid, "tokens": took,
                                  "pos": task.pos, "batch": len(tasks),
                                  "fused": True})
        if advanced:
            self.telemetry.bump("prefill_batches")
        if self.prefix_cache is not None:
            self._prefix_after_dispatch(step, pairs)

        # 3) collect the OLDEST in-flight step (the host sync point); at
        # depth 0 that is the step dispatched just above
        out: Dict[int, int] = {}
        step = None
        if self._inflight:
            step = self._inflight.popleft()
            with self._phase("collect", tick=tick_no,
                             width=sum(step.live)):
                out = self.engine.collect(step)
            if self._is_decode_step(step):
                self.telemetry.bump("decode_steps")
            worked = True
        self._route_tokens(step, out)
        if self.prefix_cache is not None and step is not None:
            self._run_captures(step)

        self.telemetry.counters["rejected"] = float(self.queue.rejected)
        for k in _ENGINE_STAT_KEYS:
            self.telemetry.counters[k] = \
                self.engine.stats.get(k, 0.0) - self._stats0.get(k, 0.0)
        self.telemetry.bump("tick_time_s", self.clock() - t_tick0)
        if self._metrics_interval is not None:
            line = self.telemetry.live_line(self._metrics_interval)
            if line:
                self._on_metrics(line)
        return worked

    @staticmethod
    def _is_decode_step(step) -> bool:
        """Did this collected step advance any decode row? A fused step
        can be pure prefill; counting it as a decode step would skew the
        per-step decode-admission mean."""
        if isinstance(step, FusedStep):
            return bool(step.decode_rows)
        return True

    def _route_tokens(self, step, out: Dict[int, int]) -> None:
        """Deliver one collected step's tokens. For a fused step, a row
        whose prompt completed in that step delivers its FIRST token here
        — the prefill -> decode transition with no separate
        finish_prefill/insert, since the row is already resident and
        live; everything else is an ordinary decode token."""
        if isinstance(step, FusedStep):
            for task, fin in zip(step.tasks, step.finishing):
                if not fin or task.slot is None:
                    continue
                tok = out.pop(task.slot, None)
                req = self.slot_req[task.slot]
                if (tok is None or req is None or req.state != "prefill"
                        or self._prefills.get(req.rid,
                                              (None, None))[1] is not task):
                    continue  # cancelled / slot re-owned while in flight
                req.state = "decode"
                req.insert_t = self.clock()
                self.tracer.instant("insert", cat=CAT_REQUEST,
                                    lane=(LANE_REQ, req.rid), rid=req.rid,
                                    slot=task.slot, fused=True)
                req.mean_admission = task.adm_weighted / max(task.pos, 1)
                del self._prefills[req.rid]
                self._deliver(req, tok)
        for slot, tok in out.items():
            req = self.slot_req[slot]
            if req is not None and req.state == "decode":
                self._deliver(req, tok)

    def _deliver(self, req: ServeRequest, token: int) -> None:
        """Stream one token to a request; retire it when finished."""
        req.out.append(int(token))
        now = self.clock()
        is_last = (len(req.out) >= req.max_new
                   or (self.engine.eos is not None
                       and int(token) == self.engine.eos))
        self.mux.emit(req.rid, int(token), is_last)
        if is_last:
            req.state = "done"
            req.finish_t = now
            if req.slot is not None and self.slot_req[req.slot] is req:
                with self._phase("evict", counter="evict_time_s",
                                 slot=req.slot, rid=req.rid):
                    self.engine.free_slot(req.slot)
                self.slot_req[req.slot] = None
            self._close_request_spans(req)
            self.tracer.instant("finish", cat=CAT_REQUEST,
                                lane=(LANE_REQ, req.rid), rid=req.rid,
                                n_out=len(req.out))
            st = self.mux.streams[req.rid]
            self.telemetry.record_request(
                rid=req.rid, prompt_len=len(req.prompt), n_out=len(req.out),
                ttft=st.ttft, tpot=st.tpot,
                e2e=req.finish_t - req.arrival_t,
                mean_admission=req.mean_admission,
                prefill_chunks=req.prefill_chunks,
                prefix_hit=req.prefix_hit,
                prefix_tokens=req.prefix_tokens)

    # ------------------------------------------------------------------
    def drain(self) -> None:
        """Collect every still-in-flight decode step (run() calls this
        once the queue drains so engine stats and the paged mirror are
        settled; tokens for freed rows are discarded by the engine)."""
        while self._inflight:
            # drain iterations are mini-ticks for phase accounting: their
            # collect time counts toward tick_time_s so the phase-sum <=
            # tick-wall invariant holds over whole runs
            t0 = self.clock()
            step = self._inflight.popleft()
            with self._phase("collect", drain=True, width=sum(step.live)):
                out = self.engine.collect(step)
            if self._is_decode_step(step):
                self.telemetry.bump("decode_steps")
            self._route_tokens(step, out)
            if self.prefix_cache is not None:
                self._run_captures(step)
            # collect folded this step's eviction/admission stats into
            # engine.stats after the last tick's counter sync ran
            for k in _ENGINE_STAT_KEYS:
                self.telemetry.counters[k] = \
                    self.engine.stats.get(k, 0.0) - self._stats0.get(k, 0.0)
            self.telemetry.bump("tick_time_s", self.clock() - t0)

    def run(self, max_ticks: int = 10_000) -> None:
        """Tick until every submitted request has completed (or been
        cancelled), then drain the in-flight window."""
        self.telemetry.start()
        for _ in range(max_ticks):
            if self.queue.all_done():
                break
            self.tick()
        self.drain()
        self.telemetry.stop()

    def tokens(self, rid: int) -> List[int]:
        return self.mux.tokens(rid)


# re-exported for callers that treat the orchestrator package as the
# serving API surface
__all__ = ["SchedulerConfig", "Plan", "Scheduler", "Orchestrator",
           "QueueFull", "InvalidRequest"]
