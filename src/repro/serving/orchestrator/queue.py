"""Arrival-ordered request queue with backpressure.

Requests enter in submission order (FIFO); ``max_pending`` bounds the
number of requests waiting for a slot — once full, ``submit`` raises
:class:`QueueFull` so an upstream frontend can shed load or retry with
backoff (the serving-system analogue of a bounded inbox; rejected
arrivals are counted for telemetry).
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable, Deque, Dict, List, Optional


class QueueFull(RuntimeError):
    """Backpressure signal: the pending queue is at ``max_pending``."""


@dataclasses.dataclass
class ServeRequest:
    """One request's full serving lifecycle record."""
    rid: int
    prompt: List[int]
    max_new: int
    arrival_t: float
    state: str = "queued"            # queued -> prefill -> decode -> done
    slot: Optional[int] = None
    out: List[int] = dataclasses.field(default_factory=list)
    finish_t: Optional[float] = None
    mean_admission: Optional[float] = None
    # TTFT/TPOT live on the request's TokenStream (stream.py), the single
    # source of truth for per-token timing


class RequestQueue:
    """FIFO arrival queue with bounded pending depth."""

    def __init__(self, max_pending: Optional[int] = None,
                 clock: Callable[[], float] = time.monotonic):
        self.max_pending = max_pending
        self.clock = clock
        self._pending: Deque[ServeRequest] = collections.deque()
        self.requests: Dict[int, ServeRequest] = {}
        self._next_rid = 0
        self.rejected = 0

    def submit(self, prompt: List[int], max_new: int = 32) -> int:
        """Enqueue a request; raises QueueFull when at max_pending."""
        if self.max_pending is not None and len(self._pending) >= self.max_pending:
            self.rejected += 1
            raise QueueFull(
                f"pending queue at max_pending={self.max_pending}")
        rid = self._next_rid
        self._next_rid += 1
        req = ServeRequest(rid=rid, prompt=list(prompt), max_new=max_new,
                           arrival_t=self.clock())
        self._pending.append(req)
        self.requests[rid] = req
        return rid

    def pop(self) -> Optional[ServeRequest]:
        """Dequeue the oldest pending request (None when empty)."""
        return self._pending.popleft() if self._pending else None

    def __len__(self) -> int:
        return len(self._pending)

    @property
    def depth(self) -> int:
        return len(self._pending)

    def all_done(self) -> bool:
        return not self._pending and all(
            r.state == "done" for r in self.requests.values())
