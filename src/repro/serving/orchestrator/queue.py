"""Arrival-ordered request queue with backpressure and typed rejections.

Requests enter in submission order (FIFO); ``max_pending`` bounds the
number of requests waiting for a slot — once full, ``submit`` raises
:class:`QueueFull`, a *typed* backpressure response carrying the queue
state so an upstream frontend can shed load or retry with backoff (the
serving-system analogue of a bounded inbox; rejected arrivals are
counted for telemetry). Malformed requests (empty prompt, ``max_new <
1``) raise :class:`InvalidRequest` at the queue boundary instead of
failing deep inside the backend's ``start_prefill``.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable, Deque, Dict, List, Optional


class QueueFull(RuntimeError):
    """Backpressure signal: the pending queue is at ``max_pending``.

    Typed response for frontends: ``depth`` is the pending depth at
    rejection time, ``max_pending`` the configured bound. Retry after
    draining (the request was NOT enqueued)."""

    def __init__(self, depth: int, max_pending: int):
        super().__init__(
            f"pending queue at max_pending={max_pending} (depth={depth})")
        self.depth = depth
        self.max_pending = max_pending


class InvalidRequest(ValueError):
    """The request can never be served: empty prompt or ``max_new < 1``."""


@dataclasses.dataclass
class ServeRequest:
    """One request's full serving lifecycle record."""
    rid: int
    prompt: List[int]
    max_new: int
    arrival_t: float
    state: str = "queued"  # queued -> prefill -> decode -> done | cancelled
    slot: Optional[int] = None
    out: List[int] = dataclasses.field(default_factory=list)
    finish_t: Optional[float] = None
    mean_admission: Optional[float] = None
    # chunks THIS request's prefill advanced by (batched advances still
    # count one chunk per task per tick; the per-request view dashboards
    # keep when the global prefill_chunks/prefill_batches split changed)
    prefill_chunks: int = 0
    # absolute wall-clock deadline (arrival_t + deadline_s); the
    # orchestrator cancels the request when the clock passes it
    deadline_t: Optional[float] = None
    # lifecycle transition timestamps for the request-lane trace spans:
    # queued ends at admit_t, decode runs insert_t -> finish_t
    admit_t: Optional[float] = None
    insert_t: Optional[float] = None
    # prefix-cache outcome at admission: did a stored shared-context
    # prefix splice in, and how many prompt tokens did it cover
    prefix_hit: bool = False
    prefix_tokens: int = 0
    # TTFT/TPOT live on the request's TokenStream (stream.py), the single
    # source of truth for per-token timing


class RequestQueue:
    """FIFO arrival queue with bounded pending depth."""

    def __init__(self, max_pending: Optional[int] = None,
                 clock: Callable[[], float] = time.monotonic):
        self.max_pending = max_pending
        self.clock = clock
        self._pending: Deque[ServeRequest] = collections.deque()
        self.requests: Dict[int, ServeRequest] = {}
        self._next_rid = 0
        self.rejected = 0

    def submit(self, prompt: List[int], max_new: int = 32, *,
               deadline_s: Optional[float] = None) -> int:
        """Enqueue a request. Raises :class:`InvalidRequest` for requests
        that can never be served and :class:`QueueFull` at
        ``max_pending`` (backpressure; the request is not enqueued)."""
        if not prompt:
            raise InvalidRequest("prompt must be non-empty")
        if max_new < 1:
            raise InvalidRequest(f"max_new must be >= 1, got {max_new}")
        if deadline_s is not None and deadline_s <= 0:
            raise InvalidRequest(f"deadline_s must be > 0, got {deadline_s}")
        if self.max_pending is not None and len(self._pending) >= self.max_pending:
            self.rejected += 1
            raise QueueFull(len(self._pending), self.max_pending)
        rid = self._next_rid
        self._next_rid += 1
        now = self.clock()
        req = ServeRequest(rid=rid, prompt=list(prompt), max_new=max_new,
                           arrival_t=now,
                           deadline_t=(None if deadline_s is None
                                       else now + deadline_s))
        self._pending.append(req)
        self.requests[rid] = req
        return rid

    def pop(self) -> Optional[ServeRequest]:
        """Dequeue the oldest pending request (None when empty)."""
        return self._pending.popleft() if self._pending else None

    def remove(self, rid: int) -> bool:
        """Drop a still-queued request (cancellation before admission).
        Returns False if the request is not in the pending queue."""
        for req in self._pending:
            if req.rid == rid:
                self._pending.remove(req)
                return True
        return False

    def __len__(self) -> int:
        return len(self._pending)

    @property
    def depth(self) -> int:
        return len(self._pending)

    def all_done(self) -> bool:
        return not self._pending and all(
            r.state in ("done", "cancelled") for r in self.requests.values())
