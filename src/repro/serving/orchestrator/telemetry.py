"""Serving telemetry: throughput, latency percentiles, paged-pool
utilization, eviction triggers, and mean write-gate admission rate.

The admission rate is the paper's headline memory knob surfaced as a
serving metric: a mean admission of ``a`` with local window ``W`` means
steady-state KV residency ~``a*t + W`` tokens instead of ``t`` — the
memory saving the gate buys is directly observable per request here.

Telemetry sits on top of the observability metrics registry
(:class:`repro.serving.obs.MetricsRegistry`): the public ``counters``
dict is a live :class:`repro.serving.obs.CounterView` over registry
counters, and every latency/memory observation also feeds a
rolling-window histogram — so the end-of-run ``summary()``/``report()``
(cumulative) and the live periodic ``live_line()`` (windowed; the
``--metrics-interval`` report in launch/serve.py) share one source of
truth instead of two bookkeeping paths that can drift.
"""
from __future__ import annotations

import dataclasses
import datetime
import json
import time
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.serving.obs.metrics import CounterView, MetricsRegistry

# summary()/to_json() artifact schema: bump on shape changes so BENCH /
# trace consumers across PRs can tell what they are reading
TELEMETRY_SCHEMA_VERSION = 4

# tick-phase wall-time counters (seconds), accumulated by the
# orchestrator's phase spans: where each tick's time goes. ``extend``
# is an engine-side sub-phase of the ``prefill`` stage (synced from
# engine stats), so the disjoint per-tick decomposition is
# prefill + dispatch + collect + evict + memory_sample + admit <= tick.
PHASE_TIME_KEYS = ("prefill_time_s", "dispatch_time_s", "collect_time_s",
                   "evict_time_s", "memory_sample_time_s", "admit_time_s",
                   "prefix_capture_time_s")


@dataclasses.dataclass
class RequestRecord:
    rid: int
    prompt_len: int
    n_out: int
    ttft: Optional[float]
    tpot: Optional[float]
    e2e: Optional[float]
    mean_admission: Optional[float]
    # chunks this request's prefill took (batched ticks count one chunk
    # per task, same as the per-request driver)
    prefill_chunks: int = 0
    # prefix-cache outcome: served off a stored shared-context prefix
    # (and how many prompt tokens the splice skipped re-prefilling)
    prefix_hit: bool = False
    prefix_tokens: int = 0


def _pct(xs: List[float], q: float) -> Optional[float]:
    return float(np.percentile(np.asarray(xs), q)) if xs else None


def _mean(xs: List[float]) -> Optional[float]:
    return float(np.mean(np.asarray(xs))) if xs else None


class Telemetry:
    """Aggregates counters, per-request latency records, and pool samples."""

    def __init__(self, clock: Callable[[], float] = time.monotonic,
                 window_s: float = 30.0):
        self.clock = clock
        self.t_start: Optional[float] = None
        self.t_end: Optional[float] = None
        self.metrics = MetricsRegistry(clock=clock, window_s=window_s)
        # live dict-like view over registry counters (historic contract:
        # telemetry.counters[...] reads/writes keep working everywhere)
        self.counters: Dict[str, float] = CounterView(self.metrics)
        for name, v in (
                ("ticks", 0), ("decode_steps", 0), ("prefill_chunks", 0),
                # prefill ADVANCE calls: one batched ragged call covers many
                # tasks, so prefill_batches <= prefill_chunks (equal only
                # under the per-request driver) — prefill_chunks keeps its
                # one-per-task-per-tick meaning. (A task's first aligned
                # chunk additionally runs its own batch-1 prefill inside the
                # call, so this is not an exact device-dispatch count.)
                ("prefill_batches", 0),
                # wall seconds spent in the tick loop's prefill-advance stage
                # (open + batched/per-task extend calls, incl. their device
                # sync): prefill_tokens / prefill_time_s is the prompt-ingest
                # rate the batched-prefill A/B compares
                ("prefill_time_s", 0.0),
                ("prefill_tokens", 0), ("generated_tokens", 0),
                ("completed", 0), ("rejected", 0), ("evict_triggers", 0.0),
                # async driver + client-surface lifecycle (scheduler/session)
                ("dispatched_steps", 0), ("cancelled", 0),
                ("deadline_expired", 0),
                # tick-phase wall-time breakdown (orchestrator phase spans)
                ("tick_time_s", 0.0), ("dispatch_time_s", 0.0),
                ("collect_time_s", 0.0), ("evict_time_s", 0.0),
                ("memory_sample_time_s", 0.0), ("admit_time_s", 0.0),
                # fused megabatch tick (engine stats, synced per tick):
                # fused_prefill_time_s/_tokens apportion the fused call's
                # wall time to its prefill rows for the prompt-ingest rate
                ("fused_steps", 0), ("fused_time_s", 0.0),
                ("fused_prefill_time_s", 0.0), ("fused_prefill_tokens", 0),
                # fixed-shape padding accounting of fused dispatches
                # (fused_padding_frac = 1 - active/slot rows)
                ("fused_slot_rows", 0), ("fused_active_rows", 0),
                # decode-time page selection (gathered top-K fused ticks)
                ("selected_pages", 0.0), ("selection_time_s", 0.0),
                # content-addressed prefix store (admission-gated
                # shared-context reuse): hit/miss at admission, LRU
                # evictions, and the store's current byte footprint
                ("prefix_hit", 0), ("prefix_miss", 0),
                ("prefix_evict", 0.0), ("prefix_bytes", 0.0),
                ("prefix_capture_time_s", 0.0)):
            self.counters[name] = v
        self.records: List[RequestRecord] = []
        self.pool_util_samples: List[float] = []
        self.pool_page_samples: List[int] = []
        self.kv_token_samples: List[float] = []
        self.kv_byte_samples: List[float] = []
        self.kv_byte_shard_samples: List[float] = []  # per-device, meshed
        # live_line() state: last cut (t, generated_tokens, completed)
        self._line_mark: Optional[tuple] = None

    # ---- lifecycle -------------------------------------------------------
    def start(self) -> None:
        if self.t_start is None:
            self.t_start = self.clock()

    def stop(self) -> None:
        self.t_end = self.clock()

    def bump(self, name: str, by: float = 1) -> None:
        self.metrics.counter(name).inc(by)

    def sample_memory(self, snapshot: Dict[str, float]) -> None:
        """Record one backend ``memory_snapshot()``: paged-pool occupancy
        (when the backend is physically paged) and resident KV tokens/bytes
        (every backend) — the serving-level memory axis of the A/B."""
        gauge = self.metrics.gauge
        if "pool_util" in snapshot:
            self.pool_util_samples.append(float(snapshot["pool_util"]))
            gauge("pool_util").set(snapshot["pool_util"])
        if "pool_pages" in snapshot:
            self.pool_page_samples.append(int(snapshot["pool_pages"]))
            gauge("pool_pages").set(snapshot["pool_pages"])
        if "kv_tokens" in snapshot:
            self.kv_token_samples.append(float(snapshot["kv_tokens"]))
            gauge("kv_tokens").set(snapshot["kv_tokens"])
        if "kv_bytes" in snapshot:
            self.kv_byte_samples.append(float(snapshot["kv_bytes"]))
            gauge("kv_bytes").set(snapshot["kv_bytes"])
        if "kv_bytes_per_shard" in snapshot:
            # sharded backends: even-occupancy per-device share of kv_bytes
            self.kv_byte_shard_samples.append(
                float(snapshot["kv_bytes_per_shard"]))
            gauge("kv_bytes_per_shard").set(snapshot["kv_bytes_per_shard"])

    def record_request(self, *, rid: int, prompt_len: int, n_out: int,
                       ttft: Optional[float], tpot: Optional[float],
                       e2e: Optional[float],
                       mean_admission: Optional[float],
                       prefill_chunks: int = 0,
                       prefix_hit: bool = False,
                       prefix_tokens: int = 0) -> None:
        self.records.append(RequestRecord(rid, prompt_len, n_out, ttft,
                                          tpot, e2e, mean_admission,
                                          prefill_chunks, prefix_hit,
                                          prefix_tokens))
        self.bump("completed")
        self.bump("generated_tokens", n_out)
        # rolling-window view of the same observations (live_line)
        if ttft is not None:
            self.metrics.observe("ttft_s", ttft)
        if tpot is not None:
            self.metrics.observe("tpot_s", tpot)
        if e2e is not None:
            self.metrics.observe("e2e_s", e2e)

    # ---- aggregation -----------------------------------------------------
    def summary(self) -> Dict[str, object]:
        wall = None
        if self.t_start is not None:
            wall = (self.t_end or self.clock()) - self.t_start
        ttfts = [r.ttft for r in self.records if r.ttft is not None]
        tpots = [r.tpot for r in self.records if r.tpot is not None]
        e2es = [r.e2e for r in self.records if r.e2e is not None]
        adms = [r.mean_admission for r in self.records
                if r.mean_admission is not None]
        n = len(self.records)
        toks = self.counters["generated_tokens"]
        steps = self.counters["decode_steps"]
        decode_adm = (self.counters.get("decode_adm_sum", 0.0) / steps
                      if steps else None)
        # fixed-shape padding of the fused dispatches: every compiled
        # step spans all slot rows, so on CPU-XLA the padded rows cost
        # real compute — this fraction makes stage-time ratios legible
        slot_rows = self.counters.get("fused_slot_rows", 0.0)
        pad_frac = (1.0 - self.counters.get("fused_active_rows", 0.0)
                    / slot_rows) if slot_rows else None
        # prefix-cache split: TTFT on hit vs miss is the store's win axis
        ttft_hit = [r.ttft for r in self.records
                    if r.prefix_hit and r.ttft is not None]
        ttft_miss = [r.ttft for r in self.records
                     if not r.prefix_hit and r.ttft is not None]
        return {
            # self-description: artifacts (BENCH json, committed
            # summaries) say what schema they carry and when they were cut
            "schema_version": TELEMETRY_SCHEMA_VERSION,
            "generated_at": datetime.datetime.now(
                datetime.timezone.utc).isoformat(),
            "mean_admission_decode": decode_adm,
            "fused_padding_frac": pad_frac,
            "requests": n,
            "wall_s": wall,
            "requests_per_s": (n / wall if wall else None),
            "tokens_per_s": (toks / wall if wall else None),
            "ttft_mean_s": _mean(ttfts),
            "ttft_p50_s": _pct(ttfts, 50),
            "ttft_p90_s": _pct(ttfts, 90),
            "ttft_p99_s": _pct(ttfts, 99),
            "tpot_mean_s": _mean(tpots),
            "tpot_p50_s": _pct(tpots, 50),
            "tpot_p90_s": _pct(tpots, 90),
            "tpot_p99_s": _pct(tpots, 99),
            "prefill_chunks_per_request_mean": _mean(
                [float(r.prefill_chunks) for r in self.records]),
            "prefix_hit_rate": (sum(1 for r in self.records if r.prefix_hit)
                                / n if n else None),
            "prefix_tokens_reused": float(sum(
                r.prefix_tokens for r in self.records)),
            "ttft_on_hit_p50_s": _pct(ttft_hit, 50),
            "ttft_on_miss_p50_s": _pct(ttft_miss, 50),
            "e2e_mean_s": _mean(e2es),
            "mean_admission": _mean(adms),
            "pool_util_mean": _mean(self.pool_util_samples),
            "pool_util_last": (self.pool_util_samples[-1]
                               if self.pool_util_samples else None),
            "pool_pages_peak": (max(self.pool_page_samples)
                                if self.pool_page_samples else None),
            "kv_tokens_peak": (max(self.kv_token_samples)
                               if self.kv_token_samples else None),
            "kv_tokens_mean": _mean(self.kv_token_samples),
            "kv_bytes_peak": (max(self.kv_byte_samples)
                              if self.kv_byte_samples else None),
            "kv_bytes_per_shard_peak": (max(self.kv_byte_shard_samples)
                                        if self.kv_byte_shard_samples
                                        else None),
            "counters": dict(self.counters),
        }

    def phase_times(self) -> Dict[str, float]:
        """Per-phase tick wall-time decomposition (seconds): the disjoint
        orchestrator phases plus the engine-side prefill sub-phase
        (``extend_time_s``, contained in ``prefill_time_s``) and the
        measured total ``tick_time_s``."""
        c = self.counters
        out = {k: float(c.get(k, 0.0)) for k in PHASE_TIME_KEYS}
        out["extend_time_s"] = float(c.get("extend_time_s", 0.0))
        # fused megabatch: one device call per tick covering prefill rows
        # and decode rows together — its wall time lands in
        # dispatch_time_s (already a PHASE_TIME_KEYS member), surfaced
        # here as its own lens plus the prefill-row apportionment and
        # the selection-enabled (gathered top-K) share
        out["fused_time_s"] = float(c.get("fused_time_s", 0.0))
        out["fused_prefill_time_s"] = float(c.get("fused_prefill_time_s", 0.0))
        out["selection_time_s"] = float(c.get("selection_time_s", 0.0))
        out["tick_time_s"] = float(c.get("tick_time_s", 0.0))
        out["phase_sum_s"] = sum(float(c.get(k, 0.0))
                                 for k in PHASE_TIME_KEYS)
        return out

    def report(self) -> str:
        s = self.summary()
        c = s["counters"]

        def f(x, unit="", scale=1.0, nd=2):
            return "-" if x is None else f"{x * scale:.{nd}f}{unit}"

        ph = self.phase_times()
        lines = [
            f"requests={s['requests']} "
            f"({c['rejected']:.0f} rejected by backpressure, "
            f"{c['cancelled']:.0f} cancelled, "
            f"{c['deadline_expired']:.0f} past deadline)  "
            f"wall={f(s['wall_s'], 's')}",
            f"throughput: {f(s['requests_per_s'])} req/s, "
            f"{f(s['tokens_per_s'])} tok/s "
            f"(decode_steps={c['decode_steps']:.0f}, "
            f"prefill_chunks={c['prefill_chunks']:.0f} "
            f"in {c['prefill_batches']:.0f} batches, "
            f"prefill_tokens={c['prefill_tokens']:.0f})",
            f"TTFT: mean={f(s['ttft_mean_s'], 'ms', 1e3)} "
            f"p50={f(s['ttft_p50_s'], 'ms', 1e3)} "
            f"p90={f(s['ttft_p90_s'], 'ms', 1e3)} "
            f"p99={f(s['ttft_p99_s'], 'ms', 1e3)}",
            # p99 included: --slo-tolerance gates on tpot_p99_s, so the
            # human-readable report must show the same tail it gates
            f"TPOT: mean={f(s['tpot_mean_s'], 'ms', 1e3)} "
            f"p50={f(s['tpot_p50_s'], 'ms', 1e3)} "
            f"p90={f(s['tpot_p90_s'], 'ms', 1e3)} "
            f"p99={f(s['tpot_p99_s'], 'ms', 1e3)}",
            f"tick phases: prefill={f(ph['prefill_time_s'], 's')} "
            f"(extend={f(ph['extend_time_s'], 's')}) "
            f"dispatch={f(ph['dispatch_time_s'], 's')} "
            f"(fused={f(ph['fused_time_s'], 's')} "
            f"of which prefill={f(ph['fused_prefill_time_s'], 's')} "
            f"over {c['fused_steps']:.0f} fused steps) "
            f"collect={f(ph['collect_time_s'], 's')} "
            f"evict={f(ph['evict_time_s'], 's')} "
            f"mem={f(ph['memory_sample_time_s'], 's')} "
            f"/ tick_total={f(ph['tick_time_s'], 's')}",
            f"admission: prefill_mean={f(s['mean_admission'], nd=3)} "
            f"decode_mean={f(s['mean_admission_decode'], nd=3)} "
            f"(evict_triggers={c['evict_triggers']:.0f})",
            f"fused padding_frac={f(s['fused_padding_frac'], nd=3)}  "
            f"selection: pages={c.get('selected_pages', 0.0):.0f} "
            f"time={f(ph['selection_time_s'], 's')}",
            f"prefix cache: hit_rate={f(s['prefix_hit_rate'], nd=3)} "
            f"(hits={c.get('prefix_hit', 0.0):.0f} "
            f"misses={c.get('prefix_miss', 0.0):.0f} "
            f"evictions={c.get('prefix_evict', 0.0):.0f}) "
            f"tokens_reused={s['prefix_tokens_reused']:.0f} "
            f"bytes={c.get('prefix_bytes', 0.0):.0f}  "
            f"ttft_on_hit_p50={f(s['ttft_on_hit_p50_s'], 'ms', 1e3)} "
            f"vs_miss_p50={f(s['ttft_on_miss_p50_s'], 'ms', 1e3)}",
            f"paged pool: util_mean={f(s['pool_util_mean'], nd=3)} "
            f"util_last={f(s['pool_util_last'], nd=3)} "
            f"pages_peak={s['pool_pages_peak']}",
            f"resident KV: tokens_peak={f(s['kv_tokens_peak'], nd=0)} "
            f"tokens_mean={f(s['kv_tokens_mean'], nd=0)} "
            f"bytes_peak={f(s['kv_bytes_peak'], nd=0)} "
            f"bytes_per_shard_peak={f(s['kv_bytes_per_shard_peak'], nd=0)}",
        ]
        return "\n".join(lines)

    # ---- live periodic reporting ----------------------------------------
    def live_line(self, interval_s: float) -> Optional[str]:
        """One-line rolling snapshot, at most once per ``interval_s``
        seconds (None between cuts): windowed token rate + windowed
        latency percentiles + instantaneous memory gauges. The
        orchestrator calls this every tick when a metrics interval is
        configured (launch/serve.py ``--metrics-interval``)."""
        now = self.clock()
        if self._line_mark is None:
            # first call opens the window; no line until it elapses
            self._line_mark = (now, self.counters["generated_tokens"],
                               self.counters["completed"])
            return None
        t0, toks0, done0 = self._line_mark
        if now - t0 < interval_s:
            return None
        dt = now - t0
        toks = self.counters["generated_tokens"]
        done = self.counters["completed"]
        self._line_mark = (now, toks, done)
        self.metrics.mark_counters()

        def fmt(x, unit="", scale=1.0, nd=1):
            return "-" if x is None else f"{x * scale:.{nd}f}{unit}"

        ttft = self.metrics.histogram("ttft_s").window_stats(now)
        tpot = self.metrics.histogram("tpot_s").window_stats(now)
        kv = self.metrics.gauge("kv_tokens").value
        util = self.metrics.gauge("pool_util").value
        wall = now - (self.t_start if self.t_start is not None else t0)
        return (f"[metrics +{wall:.1f}s] "
                f"done={done:.0f} (+{done - done0:.0f}) "
                f"tok/s={fmt((toks - toks0) / dt if dt > 0 else None)} "
                f"ttft_p50={fmt(ttft['p50'], 'ms', 1e3)} "
                f"ttft_p99={fmt(ttft['p99'], 'ms', 1e3)} "
                f"tpot_p50={fmt(tpot['p50'], 'ms', 1e3)} "
                f"kv_tokens={fmt(kv, nd=0)} "
                f"pool_util={fmt(util, nd=3)} "
                f"ticks={self.counters['ticks']:.0f}")

    def to_json(self, path: str) -> None:
        with open(path, "w") as fh:
            json.dump(self.summary(), fh, indent=2)
