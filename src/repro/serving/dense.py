"""DenseEngine: the full-KV (no admission) baseline serving backend.

Implements the same :class:`repro.serving.backend.EngineBackend` protocol
as the WG-KV Engine, but serves the uncompressed dense cache through the
non-gated decode path (models/inference.py dense branch). Every prompt and
generated token is written — admission is identically 1.0 — so replaying
one arrival trace through this backend and the WG-KV backend yields the
paper's comparative numbers (memory reduction, decode speedup) as a
serving-level A/B instead of a microbenchmark.

Shares the batched slot machinery (insert/dispatch-collect/free via
launch/specs.py splice helpers) with the Engine base class; only the
prefill path and the memory accounting differ:

  * prefill: every chunk — the first included — rides the shared batched
    ragged extend scan from an empty DENSE cache template (decode_step
    dispatches on the cache type); for full causal attention the scan is
    mathematically the one-shot ``I.prefill(use_wgkv=False)``, and
    sharing the per-token path with the fused tick keeps fused-vs-unfused
    streams byte-identical.
  * memory: no paged-pool mirror — the dense baseline's resident KV is
    exactly ``t`` tokens per (layer, kv-head) stream, reported logically
    via ``memory_snapshot`` for the A/B memory comparison.
"""
from __future__ import annotations

from typing import Any, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.launch.specs import build_decode_caches
from repro.models import inference as I
from repro.models.attention import DenseCache
from repro.serving.backend import BackendCapabilities, PrefillTask
from repro.serving.engine import Engine


class DenseEngine(Engine):
    """Full-KV baseline backend (admission == 1.0, linear cache growth)."""

    def __init__(self, params, cfg: ModelConfig, *, slots: int = 4,
                 capacity: int = 4096, opts: Optional[I.DecodeOptions] = None,
                 eos: Optional[int] = None, temperature: float = 0.0,
                 seed: int = 0, mesh=None, **_paged_kw):
        # dense caches are contiguous [B, H, capacity, hd] buffers; the
        # paged mirror (and pool_pages/mirror_paged kwargs) do not apply
        if opts is not None and opts.selection_policy is not None:
            raise ValueError(
                "selection_policy requires the paged dual cache; the dense "
                "full-KV baseline has no page metadata to select against")
        super().__init__(params, cfg, slots=slots, capacity=capacity,
                         opts=opts, eos=eos, temperature=temperature,
                         seed=seed, mirror_paged=False, mesh=mesh)
        # host-tracked per-slot sequence length: dense_cache_append past
        # ``capacity`` silently drops the write (JAX OOB scatter), so the
        # engine must fail loudly instead of serving a corrupted cache
        self._slot_len = [0] * slots

    # ------------------------------------------------------------------
    def capabilities(self) -> BackendCapabilities:
        return BackendCapabilities(
            name="dense", gated=False, paged=False,
            description="uncompressed full-KV cache (no admission)",
            sharded=self.mesh is not None)

    # memory_snapshot itself is inherited: the base reads the host-cached
    # per-row counts (fused stats / insert), so the dense baseline only
    # supplies its own in-jit counter and snapshot leaf
    def _kv_tokens_device(self, caches) -> jax.Array:
        total = None
        for dc in self._iter_dense(caches):
            per = dc.t * dc.k.shape[1]            # t tokens x kv heads
            total = per if total is None else total + per
        if total is None:
            b = int(np.shape(caches["t"])[0])
            return jnp.zeros((b,), jnp.int32)
        return total.astype(jnp.int32)

    def _snapshot_leaf(self):
        if self.caches is None:
            return None
        blocks = self.caches["blocks"]
        for i in range(len(self.cfg.block_pattern)):
            node = blocks[f"b{i}"]
            if isinstance(node, dict) and "self" in node:
                node = node["self"]
            if isinstance(node, DenseCache):
                return node.k
        return None

    def _iter_dense(self, caches) -> List[DenseCache]:
        """Batched DenseCache leaves, one per (repeat, block) layer."""
        out = []
        blocks = caches["blocks"]
        for i, bt in enumerate(self.cfg.block_pattern):
            node = blocks[f"b{i}"]
            if isinstance(node, dict) and "self" in node:
                node = node["self"]
            if isinstance(node, DenseCache):
                if node.k.ndim == 5:  # stacked [n_repeats, B, ...]
                    for r in range(node.k.shape[0]):
                        out.append(jax.tree.map(lambda x, r=r: x[r], node))
                else:
                    out.append(node)
        return out

    # ------------------------------------------------------------------
    # chunked prefill (dense: scan-from-empty like the base class; only
    # the capacity guard differs — the prompt must fit the dense buffer)
    # ------------------------------------------------------------------
    def start_prefill(self, prompt: List[int]) -> PrefillTask:
        # the first token is sampled from the prefill's own last-position
        # logits (no re-feed), so the prompt alone must fit the buffer
        assert len(prompt) < self.capacity, \
            f"prompt {len(prompt)} needs dense capacity > {len(prompt)}"
        return PrefillTask(prompt=list(prompt))

    def _extend_admission(self, adm_sum, take: int, full: bool) -> float:
        return 1.0 * take                  # dense admits every token

    def _build_empty_caches(self):
        # fused first-chunk open: an empty DENSE tree (t=0); the ragged
        # scan appends the chunk token-by-token, which for full causal
        # attention is mathematically the one-shot prefill
        return build_decode_caches(self.cfg, 1, self.capacity,
                                   use_wgkv=False, prefilled=0)

    # ------------------------------------------------------------------
    # capacity guard: a dense slot grows by one token per decode step
    # ------------------------------------------------------------------
    def insert(self, prefix, slot: int) -> None:
        super().insert(prefix, slot)
        self._slot_len[slot] = int(np.asarray(prefix.caches["t"])[0])

    def _pre_fused_dispatch(self, prefill, decode_rows) -> None:
        # same dispatch-time overflow guard for the fused step: a prefill
        # row grows by its chunk take, a decode row by one token
        for s, take in prefill:
            if self._slot_len[s] + take > self.capacity:
                raise RuntimeError(
                    f"dense cache overflow: slot {s} at t={self._slot_len[s]} "
                    f"+ chunk {take} > capacity {self.capacity}")
            self._slot_len[s] += take
        for s in decode_rows:
            if self._slot_len[s] >= self.capacity:
                raise RuntimeError(
                    f"dense cache overflow: slot {s} at t={self._slot_len[s]} "
                    f"== capacity {self.capacity}; raise capacity or lower "
                    "max_new")
            self._slot_len[s] += 1

    def free_slot(self, slot: int) -> None:
        super().free_slot(slot)
        self._slot_len[slot] = 0

    # ------------------------------------------------------------------
    # prefix store hooks: the dense baseline participates logically (the
    # stored artifact is its full-KV batch-1 tree; no pool streams)
    # ------------------------------------------------------------------
    def _adopt_prefix(self, slot: int, entry) -> None:
        super()._adopt_prefix(slot, entry)
        self._slot_len[slot] = entry.n_tokens

    def capture_prefix(self, step, slot: int, key: str, *,
                       adm_weighted: float = 0.0):
        from repro.launch.specs import cache_tree_bytes, extract_slot_caches
        from repro.serving.prefix_cache import CachedPrefix
        caches = extract_slot_caches(step.after, slot)
        n = int(jax.device_get(caches["t"])[0])
        layers = self._iter_dense(caches)
        heads = layers[0].k.shape[1] if layers else 0
        return CachedPrefix(key=key, n_tokens=n, caches=caches,
                            adm_weighted=adm_weighted, meta={},
                            kv_tokens=n * heads * len(layers),
                            n_bytes=cache_tree_bytes(caches))

    # ------------------------------------------------------------------
    def _decode_admission(self, st: Any, live_rows: List[int]) -> float:
        return 1.0  # the dense baseline writes everything
