"""Token sampling for the serving engine."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sample(key: jax.Array, logits: jax.Array, *, temperature: float = 0.0,
           top_k: int = 0) -> jax.Array:
    """logits: [B, V] -> tokens [B]."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    lg = logits.astype(jnp.float32) / temperature
    if top_k > 0:
        kth = jax.lax.top_k(lg, top_k)[0][..., -1:]
        lg = jnp.where(lg < kth, -1e30, lg)
    return jax.random.categorical(key, lg, axis=-1).astype(jnp.int32)
