from repro.serving import backend, engine, orchestrator, paged  # noqa: F401
from repro.serving import sampling, sharded  # noqa: F401
