from repro.serving import engine, orchestrator, paged, sampling  # noqa: F401
