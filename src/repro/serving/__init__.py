from repro.serving import engine, paged, sampling  # noqa: F401
