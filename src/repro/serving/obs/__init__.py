"""Serving observability: tracer + metrics registry + exporters.

Layering (all optional at runtime — the serving stack defaults to the
no-op :data:`NULL_TRACER` and pays one branch per instrumentation site):

  trace.py   — ring-buffer structured tracer: per-request lifecycle
               spans and per-tick engine-phase spans with monotonic
               timestamps and tick/rid/slot/batch attributes
  metrics.py — counter / gauge / rolling-window histogram registry;
               orchestrator Telemetry sits on top of it (its ``counters``
               dict is a live :class:`CounterView`)
  export.py  — Chrome-trace/Perfetto JSON exporter + structural
               validator (CI gates emitted artifacts through it), and
               the ``jax.profiler.TraceAnnotation`` device bridge lives
               on the tracer itself (``annotate_device=True``)

Wired in by: serving/orchestrator/scheduler.py (tick phases + request
lifecycle), serving/engine.py (prefill_open / prefill_extend_ragged /
decode dispatch sub-phases on every backend), launch/serve.py
(``--trace-out`` / ``--metrics-interval``), benchmarks/bench_serving.py
(per-backend trace artifacts + phase-time breakdown columns).
"""
from repro.serving.obs.export import (TRACE_SCHEMA_VERSION, chrome_trace,
                                      chrome_trace_events,
                                      validate_chrome_trace,
                                      write_chrome_trace)
from repro.serving.obs.metrics import (Counter, CounterView, Gauge,
                                       Histogram, MetricsRegistry)
from repro.serving.obs.trace import (CAT_ENGINE, CAT_REQUEST, LANE_REQ,
                                     LANE_TICK, NULL_TRACER, Span, Tracer)

__all__ = ["Tracer", "Span", "NULL_TRACER", "LANE_REQ", "LANE_TICK",
           "CAT_ENGINE", "CAT_REQUEST", "MetricsRegistry", "Counter",
           "CounterView", "Gauge", "Histogram", "chrome_trace",
           "chrome_trace_events", "write_chrome_trace",
           "validate_chrome_trace", "TRACE_SCHEMA_VERSION"]
