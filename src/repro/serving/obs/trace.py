"""Structured serving tracer: per-request lifecycle spans and per-tick
engine-phase spans over a ring buffer.

The serving stack is five PRs of pipelining (chunked prefill, batched
ragged extend, dispatch-ahead decode) — an end-of-run aggregate cannot
say *where a tick's wall time goes* or *where one request's TTFT was
spent*. The tracer answers both with two families of spans:

  * **request lane** (one lane per rid): ``queued -> prefill[chunk i]
    -> insert -> decode -> finish/cancel/deadline`` — the lifecycle the
    orchestrator drives;
  * **engine lane** (one lane per tick loop): ``memory_sample``,
    ``admit``, ``fused_step`` (the one jitted megabatch dispatch, with
    a ``selection`` sub-span when top-K page selection is active),
    ``collect``, ``evict`` — the per-tick phase decomposition the
    ROADMAP's fused megabatch / prefix-cache items need as evidence.

Design constraints:

  * **Always-on capable**: spans land in a bounded ring buffer
    (``collections.deque(maxlen=...)``) of plain tuples — no I/O, no
    serialization, no unbounded growth on a long-lived session. The
    oldest spans fall off; ``emitted`` counts everything ever recorded
    so exporters can report truncation.
  * **No-op-cheap when disabled**: the module-level :data:`NULL_TRACER`
    is the default everywhere. Its ``span()`` returns one shared
    pre-allocated context manager and ``add``/``instant`` return before
    touching the clock, so un-traced serving pays one attribute load +
    one branch per call site (asserted by the overhead test).
  * **Device bridge**: with ``annotate_device=True`` every ``span()``
    also enters a ``jax.profiler.TraceAnnotation`` scope, so host spans
    line up with device traces in a profiler timeline.

Timestamps come from the injected ``clock`` (monotonic by default) —
deterministic-clock tests drive the tracer and the orchestrator from the
same fake clock. Export to Chrome-trace JSON lives in
:mod:`repro.serving.obs.export`.
"""
from __future__ import annotations

import collections
import time
from typing import Callable, Deque, Dict, List, NamedTuple, Optional, Tuple

# lane kinds: spans are grouped into horizontal lanes by (kind, id) —
# ("req", rid) per request, ("tick", 0) for the engine tick loop
LANE_REQ = "req"
LANE_TICK = "tick"

# span categories (Chrome-trace "cat"): request lifecycle vs engine phase
CAT_REQUEST = "request"
CAT_ENGINE = "engine"


class Span(NamedTuple):
    """One completed span (or instant event, when ``t1 == t0``)."""
    name: str
    cat: str
    lane: Tuple[str, int]          # (kind, id): ("req", rid) | ("tick", 0)
    t0: float
    t1: float
    args: Optional[Dict[str, object]]


class _NullSpan:
    """Shared no-op context manager returned by a disabled tracer."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class _SpanCm:
    """Context manager recording one span on exit (enabled tracers)."""
    __slots__ = ("tracer", "name", "cat", "lane", "args", "t0", "_ann")

    def __init__(self, tracer: "Tracer", name: str, cat: str,
                 lane: Tuple[str, int], args: Optional[Dict]):
        self.tracer = tracer
        self.name = name
        self.cat = cat
        self.lane = lane
        self.args = args
        self._ann = None

    def __enter__(self):
        if self.tracer.annotate_device:
            from jax.profiler import TraceAnnotation
            self._ann = TraceAnnotation(self.name)
            self._ann.__enter__()
        self.t0 = self.tracer.clock()
        return self

    def __exit__(self, *exc):
        t1 = self.tracer.clock()
        if self._ann is not None:
            self._ann.__exit__(*exc)
        self.tracer.add(self.name, self.t0, t1, cat=self.cat,
                        lane=self.lane, args=self.args)
        return False


class Tracer:
    """Ring-buffer span recorder.

    ``capacity`` bounds resident spans (oldest dropped); ``enabled=False``
    short-circuits every entry point (see :data:`NULL_TRACER`);
    ``annotate_device`` additionally wraps ``span()`` bodies in
    ``jax.profiler.TraceAnnotation`` so a device profile shows the same
    phase names as the host trace."""

    def __init__(self, capacity: int = 1 << 16, *,
                 clock: Callable[[], float] = time.monotonic,
                 enabled: bool = True, annotate_device: bool = False):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.clock = clock
        self.enabled = enabled
        self.annotate_device = annotate_device
        self.spans: Deque[Span] = collections.deque(maxlen=capacity)
        self.emitted = 0        # total ever recorded (>= len(spans))

    # ---- recording -------------------------------------------------------
    def span(self, name: str, *, cat: str = CAT_ENGINE,
             lane: Tuple[str, int] = (LANE_TICK, 0), **args):
        """Context manager timing one phase; ``**args`` become span
        attributes (tick/rid/slot/batch width...)."""
        if not self.enabled:
            return _NULL_SPAN
        return _SpanCm(self, name, cat, lane, args or None)

    def add(self, name: str, t0: float, t1: float, *,
            cat: str = CAT_ENGINE, lane: Tuple[str, int] = (LANE_TICK, 0),
            args: Optional[Dict] = None) -> None:
        """Record an already-timed span (the orchestrator times phases
        with its own injected clock and reports [t0, t1] here)."""
        if not self.enabled:
            return
        self.spans.append(Span(name, cat, lane, t0, t1, args))
        self.emitted += 1

    def instant(self, name: str, *, cat: str = CAT_ENGINE,
                lane: Tuple[str, int] = (LANE_TICK, 0), **args) -> None:
        """Record a zero-duration marker (finish / cancel / deadline)."""
        if not self.enabled:
            return
        t = self.clock()
        self.spans.append(Span(name, cat, lane, t, t, args or None))
        self.emitted += 1

    def device_scope(self, name: str):
        """``jax.profiler.TraceAnnotation`` context when device
        annotation is on, else the shared no-op — engines wrap their
        jitted dispatches with this so device profiles carry the serving
        phase names without the host-span overhead per step."""
        if not (self.enabled and self.annotate_device):
            return _NULL_SPAN
        from jax.profiler import TraceAnnotation
        return TraceAnnotation(name)

    # ---- views -----------------------------------------------------------
    def drain(self) -> List[Span]:
        """Snapshot and clear the ring (exporters call this)."""
        out = list(self.spans)
        self.spans.clear()
        return out

    @property
    def dropped(self) -> int:
        return self.emitted - len(self.spans)

    def clear(self) -> None:
        self.spans.clear()
        self.emitted = 0


# the default tracer everywhere: disabled, shared, allocation-free on the
# hot path — serving code calls through it unconditionally
NULL_TRACER = Tracer(capacity=1, enabled=False)
