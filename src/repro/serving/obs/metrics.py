"""Serving metrics registry: counters, gauges, and rolling-window
histograms behind one namespace.

:class:`repro.serving.orchestrator.telemetry.Telemetry` sits on top of
this registry — its counter dict is a live view over registry counters,
and its latency/memory observations feed rolling histograms — so the
same numbers power both the end-of-run summary (cumulative) and the live
periodic report line (`--metrics-interval` in launch/serve.py, windowed).

Aggregation model:

  * :class:`Counter` — monotone-by-convention float; ``inc`` on the hot
    path, ``set`` for the scheduler's engine-stat delta sync. A counter
    remembers windowed rates via ``rate(window_s)`` using a small ring
    of (t, value) checkpoints taken on ``tick()``.
  * :class:`Gauge` — last-write-wins instantaneous value.
  * :class:`Histogram` — cumulative count/sum/min/max plus a bounded
    deque of (t, value) observations for rolling-window percentiles
    (pXX over the last ``window_s`` seconds, not over the whole run —
    the difference between "p99 since boot" and "p99 right now").

Everything takes its time from the injected ``clock`` so deterministic
tests can drive the windows.
"""
from __future__ import annotations

import collections
import time
from typing import Callable, Deque, Dict, List, Optional, Tuple

import numpy as np


class Counter:
    __slots__ = ("name", "value", "_marks")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0
        # (t, value) checkpoints for windowed rates, newest last
        self._marks: Deque[Tuple[float, float]] = collections.deque(maxlen=256)

    def inc(self, by: float = 1.0) -> None:
        self.value += by

    def set(self, v: float) -> None:
        self.value = float(v)

    def mark(self, now: float) -> None:
        """Checkpoint the current value (the registry marks every
        counter when a live report line is cut)."""
        self._marks.append((now, self.value))

    def rate(self, now: float, window_s: float) -> Optional[float]:
        """Mean increase per second over ~``window_s`` (None until two
        checkpoints at least partially cover the window)."""
        base = None
        for t, v in reversed(self._marks):
            base = (t, v)
            if now - t >= window_s:
                break
        if base is None or now <= base[0]:
            return None
        return (self.value - base[1]) / (now - base[0])


class Gauge:
    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value: Optional[float] = None

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Cumulative stats + rolling-window percentile support."""
    __slots__ = ("name", "count", "sum", "min", "max", "window_s", "_obs")

    def __init__(self, name: str, *, window_s: float = 30.0,
                 max_window_obs: int = 4096):
        self.name = name
        self.count = 0
        self.sum = 0.0
        self.min: Optional[float] = None
        self.max: Optional[float] = None
        self.window_s = window_s
        self._obs: Deque[Tuple[float, float]] = collections.deque(
            maxlen=max_window_obs)

    def observe(self, v: float, *, now: float) -> None:
        v = float(v)
        self.count += 1
        self.sum += v
        self.min = v if self.min is None else min(self.min, v)
        self.max = v if self.max is None else max(self.max, v)
        self._obs.append((now, v))

    @property
    def mean(self) -> Optional[float]:
        return self.sum / self.count if self.count else None

    def window_values(self, now: float) -> List[float]:
        t0 = now - self.window_s
        return [v for t, v in self._obs if t >= t0]

    def window_stats(self, now: float,
                     pcts: Tuple[float, ...] = (50, 90, 99)) -> Dict:
        vals = self.window_values(now)
        out: Dict[str, Optional[float]] = {
            "count": float(len(vals)),
            "mean": float(np.mean(vals)) if vals else None,
        }
        arr = np.asarray(vals) if vals else None
        for q in pcts:
            out[f"p{int(q)}"] = (float(np.percentile(arr, q))
                                 if arr is not None else None)
        return out


class MetricsRegistry:
    """Name -> metric map with get-or-create accessors.

    One registry per Telemetry (per orchestrator). Names are flat
    strings; the registry never forgets a metric, so ``snapshot()`` is a
    stable schema across a run."""

    def __init__(self, *, clock: Callable[[], float] = time.monotonic,
                 window_s: float = 30.0):
        self.clock = clock
        self.window_s = window_s
        self.counters: Dict[str, Counter] = {}
        self.gauges: Dict[str, Gauge] = {}
        self.histograms: Dict[str, Histogram] = {}

    # ---- get-or-create ---------------------------------------------------
    def counter(self, name: str) -> Counter:
        c = self.counters.get(name)
        if c is None:
            c = self.counters[name] = Counter(name)
        return c

    def gauge(self, name: str) -> Gauge:
        g = self.gauges.get(name)
        if g is None:
            g = self.gauges[name] = Gauge(name)
        return g

    def histogram(self, name: str,
                  window_s: Optional[float] = None) -> Histogram:
        h = self.histograms.get(name)
        if h is None:
            h = self.histograms[name] = Histogram(
                name, window_s=window_s or self.window_s)
        return h

    # ---- convenience hot-path entry points -------------------------------
    def inc(self, name: str, by: float = 1.0) -> None:
        self.counter(name).inc(by)

    def observe(self, name: str, v: float) -> None:
        self.histogram(name).observe(v, now=self.clock())

    # ---- aggregation -----------------------------------------------------
    def mark_counters(self) -> None:
        """Checkpoint all counters for windowed rate queries."""
        now = self.clock()
        for c in self.counters.values():
            c.mark(now)

    def snapshot(self) -> Dict[str, object]:
        """Point-in-time view: counter values, gauge values, histogram
        cumulative + rolling-window stats."""
        now = self.clock()
        return {
            "counters": {k: c.value for k, c in self.counters.items()},
            "gauges": {k: g.value for k, g in self.gauges.items()},
            "histograms": {
                k: {"count": float(h.count), "mean": h.mean,
                    "min": h.min, "max": h.max,
                    "window": h.window_stats(now)}
                for k, h in self.histograms.items()},
        }


class CounterView(collections.abc.MutableMapping):
    """Dict-like facade over a registry's counters.

    Telemetry's public ``counters`` attribute keeps its historical
    ``Dict[str, float]`` contract (``[]``, ``.get``, ``dict(...)``,
    ``in``) while every read/write lands in the registry — the refactor
    that lets the live metrics line and the end-of-run summary share one
    source of truth."""
    __slots__ = ("_reg",)

    def __init__(self, reg: MetricsRegistry):
        self._reg = reg

    def __getitem__(self, name: str) -> float:
        c = self._reg.counters.get(name)
        if c is None:
            raise KeyError(name)
        return c.value

    def __setitem__(self, name: str, v: float) -> None:
        self._reg.counter(name).set(v)

    def __delitem__(self, name: str) -> None:
        del self._reg.counters[name]

    def __iter__(self):
        return iter(self._reg.counters)

    def __len__(self) -> int:
        return len(self._reg.counters)

    def __repr__(self) -> str:
        return f"CounterView({dict(self)!r})"
