"""Chrome-trace / Perfetto JSON export for the serving tracer.

Converts :class:`repro.serving.obs.trace.Tracer` spans into the Chrome
Trace Event Format (the ``{"traceEvents": [...]}`` object form), viewable
in ``chrome://tracing``, https://ui.perfetto.dev, or Speedscope:

  * the engine tick loop is one process ("engine") with one lane of
    nested per-tick phase spans (``memory_sample`` / ``fused_step``
    with its ``selection`` sub-span / ``collect`` / ``evict`` ...);
  * requests are a second process ("requests") with one lane (tid) per
    rid showing the lifecycle ``queued -> prefill[chunk i] -> insert ->
    decode`` plus finish/cancel/deadline instants.

Timestamps are microseconds relative to the earliest span, so traces
from a monotonic clock (whose epoch is arbitrary) render from t=0.

``validate_chrome_trace`` is the structural checker CI runs on emitted
artifacts (also available as a CLI:
``python -m repro.serving.obs.export trace.json [...]`` exits nonzero on
the first invalid file).
"""
from __future__ import annotations

import datetime
import json
import sys
from typing import Dict, Iterable, List, Optional

from repro.serving.obs.trace import (CAT_ENGINE, CAT_REQUEST, LANE_REQ,
                                     LANE_TICK, Span, Tracer)

# artifact schema version: bump when the event layout changes shape
TRACE_SCHEMA_VERSION = 1

_LANE_PID = {LANE_TICK: 1, LANE_REQ: 2}
_PID_NAME = {1: "engine", 2: "requests"}


def _now_iso() -> str:
    return datetime.datetime.now(datetime.timezone.utc).isoformat()


def chrome_trace_events(spans: Iterable[Span]) -> List[Dict]:
    """Spans -> Chrome trace event dicts ("X" complete events, "i"
    instants, plus "M" metadata naming the process/thread lanes)."""
    spans = list(spans)
    if not spans:
        return []
    t_base = min(s.t0 for s in spans)
    events: List[Dict] = []
    seen_lanes = set()
    for s in spans:
        kind, lane_id = s.lane
        pid = _LANE_PID.get(kind, 0)
        tid = int(lane_id)
        if (pid, tid) not in seen_lanes:
            seen_lanes.add((pid, tid))
            events.append({"ph": "M", "name": "process_name", "pid": pid,
                           "tid": 0,
                           "args": {"name": _PID_NAME.get(pid, kind)}})
            tname = f"rid {tid}" if kind == LANE_REQ else "tick loop"
            events.append({"ph": "M", "name": "thread_name", "pid": pid,
                           "tid": tid, "args": {"name": tname}})
        ev = {
            "name": s.name,
            "cat": s.cat,
            "pid": pid,
            "tid": tid,
            "ts": (s.t0 - t_base) * 1e6,        # Chrome traces are in us
        }
        if s.t1 > s.t0:
            ev["ph"] = "X"
            ev["dur"] = (s.t1 - s.t0) * 1e6
        else:
            ev["ph"] = "i"
            ev["s"] = "t"                       # thread-scoped instant
        if s.args:
            ev["args"] = dict(s.args)
        events.append(ev)
    return events


def chrome_trace(tracer: Tracer, *, meta: Optional[Dict] = None) -> Dict:
    """Full Chrome-trace object for a tracer's resident spans (the ring
    is not drained). ``meta`` lands under ``otherData`` next to the
    self-description fields every artifact carries."""
    other = {
        "schema_version": TRACE_SCHEMA_VERSION,
        "generated_at": _now_iso(),
        "spans": len(tracer.spans),
        "spans_emitted": tracer.emitted,
        "spans_dropped": tracer.dropped,   # ring overflow, oldest lost
    }
    if meta:
        other.update(meta)
    return {
        "traceEvents": chrome_trace_events(tracer.spans),
        "displayTimeUnit": "ms",
        "otherData": other,
    }


def write_chrome_trace(tracer: Tracer, path: str, *,
                       meta: Optional[Dict] = None) -> Dict:
    """Serialize :func:`chrome_trace` to ``path``; returns the object."""
    obj = chrome_trace(tracer, meta=meta)
    with open(path, "w") as fh:
        json.dump(obj, fh)
    return obj


# ==========================================================================
# validation (CI gate: emitted artifacts must be structurally sound and
# actually contain both span families)
# ==========================================================================
_REQUIRED_KEYS = ("ph", "name", "pid", "tid")


def validate_chrome_trace(obj: Dict, *, require_lanes: bool = True
                          ) -> List[str]:
    """Structural check of a Chrome-trace object. Returns a list of
    human-readable problems (empty = valid). With ``require_lanes`` both
    a non-empty request lane and a non-empty engine-phase lane must be
    present — a trace missing either would mean the instrumentation
    silently fell off one side of the stack."""
    errs: List[str] = []
    if not isinstance(obj, dict):
        return [f"trace must be a JSON object, got {type(obj).__name__}"]
    events = obj.get("traceEvents")
    if not isinstance(events, list):
        return ["traceEvents missing or not a list"]
    cats = {CAT_ENGINE: 0, CAT_REQUEST: 0}
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            errs.append(f"event {i}: not an object")
            continue
        for k in _REQUIRED_KEYS:
            if k not in ev:
                errs.append(f"event {i}: missing {k!r}")
        ph = ev.get("ph")
        if ph == "X":
            if not isinstance(ev.get("ts"), (int, float)):
                errs.append(f"event {i}: 'X' event without numeric ts")
            if not isinstance(ev.get("dur"), (int, float)) or ev["dur"] < 0:
                errs.append(f"event {i}: 'X' event without numeric dur >= 0")
        if ev.get("cat") in cats and ph in ("X", "i"):
            cats[ev["cat"]] += 1
    if require_lanes:
        if not cats[CAT_ENGINE]:
            errs.append("no engine-phase spans (cat='engine')")
        if not cats[CAT_REQUEST]:
            errs.append("no request lifecycle spans (cat='request')")
    other = obj.get("otherData")
    if not isinstance(other, dict) or "schema_version" not in other \
            or "generated_at" not in other:
        errs.append("otherData.schema_version/generated_at missing "
                    "(artifact not self-describing)")
    return errs


def main(argv: Optional[List[str]] = None) -> int:
    """CLI validator: ``python -m repro.serving.obs.export t1.json ...``"""
    paths = list(sys.argv[1:] if argv is None else argv)
    if not paths:
        print("usage: python -m repro.serving.obs.export TRACE.json [...]",
              file=sys.stderr)
        return 2
    rc = 0
    for path in paths:
        try:
            with open(path) as fh:
                obj = json.load(fh)
        except (OSError, json.JSONDecodeError) as e:
            print(f"{path}: unreadable: {e}", file=sys.stderr)
            rc = 1
            continue
        errs = validate_chrome_trace(obj)
        n = len(obj.get("traceEvents", []) or [])
        if errs:
            rc = 1
            print(f"{path}: INVALID ({n} events)", file=sys.stderr)
            for e in errs[:20]:
                print(f"  - {e}", file=sys.stderr)
        else:
            cats: Dict[str, int] = {}
            for ev in obj["traceEvents"]:
                if ev.get("ph") in ("X", "i"):
                    cats[ev.get("cat", "?")] = cats.get(ev.get("cat", "?"),
                                                        0) + 1
            print(f"{path}: ok ({n} events: "
                  + ", ".join(f"{k}={v}" for k, v in sorted(cats.items()))
                  + ")")
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
