"""Quest-style read-time KV Selection (paper §5.4 composability).

Quest (Tang et al., 2024) keeps page-level key min/max metadata and, per
query, attends only to the top-B pages ranked by an upper bound on the
page's attention score:  ub(page) = sum_d max(q_d * kmin_d, q_d * kmax_d).

Here selection operates either on a dense full cache ("Quest only") or on
the WG-KV global cache ("WG-KV + Quest") — admission shrinks the candidate
pool, selection then focuses the read.

Two consumption modes:

  * **mask** (``select_pages`` + ``token_mask_from_pages``): the original
    offline-composability surface — the full attention runs and losing
    pages are masked out. Zero FLOPs saved; useful for accuracy studies.
  * **gather** (``topk_page_ids`` + ``gather_pages``): the serving decode
    path — only the top-K pages' K/V rows are materialized into the
    attention einsum, so decode cost scales with the selection budget.
    Page metadata for this path lives as ``pkmin``/``pkmax`` leaves on
    the DualCache and is maintained *incrementally*
    (``update_page_meta_on_write``: a touched-page delta per promotion,
    not an O(C) rebuild per step).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

PAGE_SIZE = 16

# Sentinel filling empty page-metadata lanes: pkmin=+META_BIG,
# pkmax=-META_BIG. Any real key strictly shrinks the interval, so the
# incremental update needs no separate "page initialized" flag — and a
# from-scratch ``build_page_meta`` rebuild (which masks invalid lanes with
# the same sentinel) lands on identical values, which is what the
# incremental-vs-rebuild parity tests pin. Fits bfloat16 (max ~3.39e38).
META_BIG = 3e38


class PageMeta(NamedTuple):
    kmin: jax.Array  # [B, H, P, hd]
    kmax: jax.Array  # [B, H, P, hd]
    valid: jax.Array  # [B, H, P] page has >= 1 valid token


def n_pages(n_tokens: int, page_size: int = PAGE_SIZE) -> int:
    """Number of metadata pages covering ``n_tokens`` slots (ceil)."""
    return -(-n_tokens // page_size)


def init_page_meta(batch: int, n_kv_heads: int, n_tokens: int, head_dim: int,
                   *, page_size: int = PAGE_SIZE,
                   dtype=jnp.float32) -> Tuple[jax.Array, jax.Array]:
    """Empty (pkmin, pkmax) leaves for a cache of ``n_tokens`` slots."""
    p = n_pages(n_tokens, page_size)
    big = jnp.asarray(META_BIG, dtype)
    return (jnp.full((batch, n_kv_heads, p, head_dim), big, dtype),
            jnp.full((batch, n_kv_heads, p, head_dim), -big, dtype))


def build_page_meta(k: jax.Array, valid: jax.Array,
                    page_size: int = PAGE_SIZE) -> PageMeta:
    """k: [B, H, S, hd]; valid: [B, H, S] -> page metadata. A ragged tail
    (S % page != 0) is padded internally with invalid lanes."""
    b, h, s, d = k.shape
    pad = (-s) % page_size
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        valid = jnp.pad(valid, ((0, 0), (0, 0), (0, pad)))
    p = (s + pad) // page_size
    kp = k.reshape(b, h, p, page_size, d)
    vp = valid.reshape(b, h, p, page_size)
    big = jnp.asarray(META_BIG, k.dtype)
    kmin = jnp.where(vp[..., None], kp, big).min(axis=3)
    kmax = jnp.where(vp[..., None], kp, -big).max(axis=3)
    return PageMeta(kmin, kmax, vp.any(axis=3))


def page_valid_from_count(count: jax.Array, p: int,
                          page_size: int = PAGE_SIZE) -> jax.Array:
    """Contiguous-cache page validity: page i holds >= 1 valid token iff
    its first slot index is < count. count: [B, H] -> [B, H, P] bool."""
    first = jnp.arange(p, dtype=count.dtype) * page_size
    return first[None, None] < count[..., None]


def update_page_meta_on_write(
    pkmin: jax.Array,   # [B, H, P, hd]
    pkmax: jax.Array,
    dest: jax.Array,    # [B, H] slot the appended entry lands in
    k_new: jax.Array,   # [B, H, hd] the appended key
    can_write: jax.Array,  # [B, H] bool: append actually happens
    *,
    page_size: int = PAGE_SIZE,
) -> Tuple[jax.Array, jax.Array]:
    """Incremental metadata maintenance for an append-only cache: fold one
    new key into the single page it touches (true scatter — O(hd) state
    touched per head, never an O(C) rebuild). A write at a page boundary
    starts the page fresh from the sentinel, so stale metadata from
    pre-eviction occupants can never widen the bound."""
    b, h = dest.shape
    pg = dest // page_size
    fresh = (dest % page_size) == 0
    bi = jnp.arange(b)[:, None].repeat(h, 1)
    hi = jnp.arange(h)[None, :].repeat(b, 0)
    old_lo = pkmin[bi, hi, pg]
    old_hi = pkmax[bi, hi, pg]
    big = jnp.asarray(META_BIG, pkmin.dtype)
    base_lo = jnp.where(fresh[..., None], big, old_lo)
    base_hi = jnp.where(fresh[..., None], -big, old_hi)
    kn = k_new.astype(pkmin.dtype)
    lo = jnp.where(can_write[..., None], jnp.minimum(base_lo, kn), old_lo)
    hi_ = jnp.where(can_write[..., None], jnp.maximum(base_hi, kn), old_hi)
    return pkmin.at[bi, hi, pg].set(lo), pkmax.at[bi, hi, pg].set(hi_)


def page_upper_bound(q: jax.Array, meta: PageMeta) -> jax.Array:
    """q: [B, Hq, hd] (Hq = G * Hkv); meta per kv head. Returns ub scores
    aggregated over the query group: [B, Hkv, P]."""
    b, hq, d = q.shape
    hkv = meta.kmin.shape[1]
    g = hq // hkv
    qg = q.reshape(b, hkv, g, d)
    lo = jnp.einsum("bhgd,bhpd->bhgp", qg, meta.kmin.astype(q.dtype))
    hi = jnp.einsum("bhgd,bhpd->bhgp", qg, meta.kmax.astype(q.dtype))
    ub = jnp.maximum(lo, hi).sum(axis=2) / g  # mean over group
    return jnp.where(meta.valid, ub, -jnp.inf)


def select_pages(q: jax.Array, meta: PageMeta, budget_pages: int) -> jax.Array:
    """Top-``budget_pages`` page mask per kv head: [B, Hkv, P] bool."""
    ub = page_upper_bound(q, meta)
    p = ub.shape[-1]
    budget_pages = min(budget_pages, p)
    thresh = jax.lax.top_k(ub, budget_pages)[0][..., -1:]
    return (ub >= thresh) & jnp.isfinite(ub)


def token_mask_from_pages(page_mask: jax.Array,
                          page_size: int = PAGE_SIZE) -> jax.Array:
    """[B, H, P] -> [B, H, P*page_size]."""
    return jnp.repeat(page_mask, page_size, axis=-1)


def topk_page_ids(q: jax.Array, meta: PageMeta,
                  budget_pages: int) -> Tuple[jax.Array, jax.Array]:
    """Top-``budget_pages`` page IDs per kv head, sorted ascending:
    (ids [B, Hkv, K] int32, n_selected [B, Hkv] int32 — selected pages
    with a finite ub, i.e. actually-valid pages in the gather).

    Ascending order matters: when K covers every page the ID list is the
    identity permutation, so the gathered attention reduces over the same
    lanes in the same order as the full path — greedy streams stay
    byte-identical to selection-off (the parity acceptance axis)."""
    ub = page_upper_bound(q, meta)
    k = min(budget_pages, ub.shape[-1])
    scores, idx = jax.lax.top_k(ub, k)
    n_sel = jnp.isfinite(scores).sum(axis=-1).astype(jnp.int32)
    return jnp.sort(idx, axis=-1).astype(jnp.int32), n_sel


def gather_pages(gk: jax.Array, gv: jax.Array, gcnt: jax.Array,
                 page_ids: jax.Array, *, page_size: int = PAGE_SIZE
                 ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Materialize only the selected pages' K/V rows for attention.

    gk/gv: [B, H, C, hd] contiguous cache; gcnt: [B, H] valid counts;
    page_ids: [B, H, K] (sorted). Returns (k [B, H, K*page, hd], v,
    valid [B, H, K*page]) — attention cost now scales with K, not C."""
    b, h, c, _ = gk.shape
    tok = (page_ids[..., None] * page_size
           + jnp.arange(page_size, dtype=page_ids.dtype)[None, None, None])
    tok = tok.reshape(b, h, -1)                       # [B, H, K*page]
    valid = tok < gcnt[..., None]
    tokc = jnp.minimum(tok, c - 1)                    # clamp ragged tail
    k = jnp.take_along_axis(gk, tokc[..., None], axis=2)
    v = jnp.take_along_axis(gv, tokc[..., None], axis=2)
    return k, v, valid
