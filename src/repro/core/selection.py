"""Quest-style read-time KV Selection (paper §5.4 composability).

Quest (Tang et al., 2024) keeps page-level key min/max metadata and, per
query, attends only to the top-B pages ranked by an upper bound on the
page's attention score:  ub(page) = sum_d max(q_d * kmin_d, q_d * kmax_d).

Here selection operates either on a dense full cache ("Quest only") or on
the WG-KV global cache ("WG-KV + Quest") — admission shrinks the candidate
pool, selection then focuses the read.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

PAGE_SIZE = 16


class PageMeta(NamedTuple):
    kmin: jax.Array  # [B, H, P, hd]
    kmax: jax.Array  # [B, H, P, hd]
    valid: jax.Array  # [B, H, P] page has >= 1 valid token


def build_page_meta(k: jax.Array, valid: jax.Array,
                    page_size: int = PAGE_SIZE) -> PageMeta:
    """k: [B, H, S, hd]; valid: [B, H, S] -> page metadata (S % page == 0
    required; pad upstream)."""
    b, h, s, d = k.shape
    p = s // page_size
    kp = k.reshape(b, h, p, page_size, d)
    vp = valid.reshape(b, h, p, page_size)
    big = jnp.asarray(3e38, k.dtype)
    kmin = jnp.where(vp[..., None], kp, big).min(axis=3)
    kmax = jnp.where(vp[..., None], kp, -big).max(axis=3)
    return PageMeta(kmin, kmax, vp.any(axis=3))


def page_upper_bound(q: jax.Array, meta: PageMeta) -> jax.Array:
    """q: [B, Hq, hd] (Hq = G * Hkv); meta per kv head. Returns ub scores
    aggregated over the query group: [B, Hkv, P]."""
    b, hq, d = q.shape
    hkv = meta.kmin.shape[1]
    g = hq // hkv
    qg = q.reshape(b, hkv, g, d)
    lo = jnp.einsum("bhgd,bhpd->bhgp", qg, meta.kmin.astype(q.dtype))
    hi = jnp.einsum("bhgd,bhpd->bhgp", qg, meta.kmax.astype(q.dtype))
    ub = jnp.maximum(lo, hi).sum(axis=2) / g  # mean over group
    return jnp.where(meta.valid, ub, -jnp.inf)


def select_pages(q: jax.Array, meta: PageMeta, budget_pages: int) -> jax.Array:
    """Top-``budget_pages`` page mask per kv head: [B, Hkv, P] bool."""
    ub = page_upper_bound(q, meta)
    p = ub.shape[-1]
    budget_pages = min(budget_pages, p)
    thresh = jax.lax.top_k(ub, budget_pages)[0][..., -1:]
    return (ub >= thresh) & jnp.isfinite(ub)


def token_mask_from_pages(page_mask: jax.Array,
                          page_size: int = PAGE_SIZE) -> jax.Array:
    """[B, H, P] -> [B, H, P*page_size]."""
    return jnp.repeat(page_mask, page_size, axis=-1)
