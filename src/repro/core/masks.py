"""Write-gated attention masks and log-space biases (paper §3.2, §4.2).

Training-time (differentiable):
    m_ij = 1                if i - j < W_local
         = g_j              otherwise
    bias B_ij = log(m_ij + eps), added to qk/sqrt(d) before softmax;
    causal positions i < j get -inf.

Inference-time (binary, vertical-slash):
    M_ij = (1[i - j < W_local] or 1[g_j >= tau]) and 1[i >= j]
"""
from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e30


def local_window_mask(s_q: int, s_k: int, w_local: int, q_offset: int = 0):
    """[s_q, s_k] bool: True where i - j < w_local (and causal i >= j).

    ``q_offset`` shifts query positions (query i corresponds to absolute
    position q_offset + i; keys are absolute 0..s_k-1).
    """
    qi = jnp.arange(s_q)[:, None] + q_offset
    kj = jnp.arange(s_k)[None, :]
    return (qi >= kj) & (qi - kj < w_local)


def causal_mask(s_q: int, s_k: int, q_offset: int = 0):
    qi = jnp.arange(s_q)[:, None] + q_offset
    kj = jnp.arange(s_k)[None, :]
    return qi >= kj


def write_gate_bias(g, s_q: int, w_local: int, eps: float = 1e-6, q_offset: int = 0):
    """Log-space additive bias for Write-Gated Attention.

    g: [..., s_k] gate scores per key (broadcast over query dim).
    Returns bias [..., s_q, s_k]: 0 inside the local window, log(g+eps)
    outside it, NEG_INF above the causal diagonal.
    """
    s_k = g.shape[-1]
    local = local_window_mask(s_q, s_k, w_local, q_offset)  # [s_q, s_k]
    causal = causal_mask(s_q, s_k, q_offset)
    logg = jnp.log(g + eps)[..., None, :]  # [..., 1, s_k]
    bias = jnp.where(local, 0.0, logg)
    return jnp.where(causal, bias, NEG_INF)


def vertical_slash_mask(g, tau: float, s_q: int, w_local: int, q_offset: int = 0,
                        sink: int = 0):
    """Binary inference mask M_ij (vertical-slash pattern).

    g: [..., s_k]; returns bool [..., s_q, s_k].
    """
    s_k = g.shape[-1]
    local = local_window_mask(s_q, s_k, w_local, q_offset)
    causal = causal_mask(s_q, s_k, q_offset)
    admitted = g >= tau  # [..., s_k]
    if sink > 0:
        admitted = admitted | (jnp.arange(s_k) < sink)
    return (local | admitted[..., None, :]) & causal
