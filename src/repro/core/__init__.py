"""WG-KV core: the paper's contribution (KV Admission) as composable JAX.

Modules:
  gate        — Write-Gate MLP (learned utility predictor)
  masks       — write-gated training bias / vertical-slash inference mask
  admission   — budgeted pre-write admission (global-cache selection)
  dual_cache  — Local ring + Global budgeted cache, Lazy Promotion
  losses      — distillation + sparsity objective
  baselines   — Local-Attention / DuoAttention static admission policies
  selection   — Quest-style read-time selection (composable)
  eviction    — SnapKV-style post-write eviction (composable)
"""
from repro.core import (  # noqa: F401
    admission,
    baselines,
    dual_cache,
    eviction,
    gate,
    losses,
    masks,
    selection,
)
