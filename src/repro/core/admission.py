"""Budgeted KV Admission (paper §2.2, §4.2 — "Initial Cache Population").

Inference-time admission binarizes the gate (g >= tau) and, under a memory
budget ``C_g`` per head, selects the admitted tokens to persist in the
Global Cache. Sink tokens (first ``sink`` positions) are always admitted as
a safety floor (StreamingLLM-style), matching the baseline configurations
in the paper's Appendix E.
"""
from __future__ import annotations

import warnings
from typing import NamedTuple

import jax
import jax.numpy as jnp


class GlobalSelection(NamedTuple):
    """Per-head admitted token set under a budget.

    idx:   [B, H, C] int32 token positions (ascending; padded with 0)
    valid: [B, H, C] bool
    count: [B, H] int32 number of valid entries
    """

    idx: jax.Array
    valid: jax.Array
    count: jax.Array


def select_global(
    g: jax.Array,
    *,
    budget: int,
    tau: float,
    sink: int = 0,
    exclude_from: int | None = None,
) -> GlobalSelection:
    """Pick up to ``budget`` admitted tokens per head.

    g: [B, H, S] gate scores. Tokens with position >= exclude_from (the
    final local window during prefill) are never globally admitted here —
    they live in the Local Cache and are lazily promoted later.
    Selection = sinks first, then highest-g admitted tokens.
    """
    b, h, s = g.shape
    pos = jnp.arange(s)
    eligible = g >= tau
    if sink > 0:
        eligible = eligible | (pos < sink)[None, None, :]
    if exclude_from is not None:
        eligible = eligible & (pos < exclude_from)[None, None, :]
    # score: sinks get +2 (always first), others their gate; ineligible -inf
    score = jnp.where(eligible, g, -jnp.inf)
    if sink > 0:
        score = jnp.where((pos < sink)[None, None, :] & eligible, 2.0, score)
    budget = min(budget, s)
    top_score, top_idx = jax.lax.top_k(score, budget)  # [B,H,C]
    valid = jnp.isfinite(top_score)
    count = valid.sum(-1).astype(jnp.int32)
    # ascending positions for causal-friendly layouts (invalid sorted last)
    sort_key = jnp.where(valid, top_idx, s + 1)
    order = jnp.argsort(sort_key, axis=-1)
    top_idx = jnp.take_along_axis(top_idx, order, axis=-1)
    valid = jnp.take_along_axis(valid, order, axis=-1)
    top_idx = jnp.where(valid, top_idx, 0)
    return GlobalSelection(top_idx.astype(jnp.int32), valid, count)


def tau_margin(g: jax.Array, tau: float) -> float:
    """Distance from tau to the nearest gate score: min |g - tau|.

    A margin near zero means the threshold sits inside the gate-score
    cluster, where two attention paths that differ only in float rounding
    (one-shot vs chunked prefill, fused vs unfused tick) can admit
    different token sets — the knife-edge class behind past parity flips.
    """
    return float(jnp.abs(g - tau).min())


def check_tau_margin(g: jax.Array, tau: float, *, eps: float = 1e-3) -> float:
    """Warn when tau is knife-edge relative to the observed gate scores.

    Returns the margin so parity tests can assert on it explicitly rather
    than relying on a silently-safe tau convention.
    """
    m = tau_margin(g, tau)
    if m < eps:
        warnings.warn(
            f"knife-edge admission threshold: min |g - tau| = {m:.2e} < "
            f"eps={eps:.0e} (tau={tau}); admission decisions may flip "
            "between numerically-equivalent attention paths. Move tau away "
            "from the gate-score cluster for parity-sensitive runs.",
            RuntimeWarning,
            stacklevel=2,
        )
    return m


def admission_rate(g: jax.Array, tau: float) -> jax.Array:
    """Fraction of tokens admitted per head: [B, H]."""
    return (g >= tau).mean(-1)


def normalized_cache_size(g: jax.Array, tau: float, w_local: int) -> jax.Array:
    """Paper's x-axis metric: (admitted + local window) / full, per head."""
    s = g.shape[-1]
    admitted = (g >= tau).sum(-1)
    return jnp.minimum((admitted + w_local) / s, 1.0)
