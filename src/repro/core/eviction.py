"""SnapKV-like post-write Eviction (paper §5.4, Appendix K.1).

Importance of key j is scored from the most recent W_obs queries:
  A^(h)  = softmax(Q_obs^(h) K^T / sqrt(d))          per query head in group
  S_raw_j = sum_i max_h A[i, j]                       aggregate
  S       = maxpool(S_raw, W_pool)                    local smoothing
When the (global) cache exceeds its hard budget, the bottom ``evict_frac``
of valid entries are dropped and the cache is compacted.

Composability: WG-KV admission flattens cache growth so eviction triggers
less often and prunes *obsolete* rather than *critical* context (Fig. 2b).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.dual_cache import DualCache
from repro.core.selection import build_page_meta


class ObsWindow(NamedTuple):
    """Ring buffer of recent query vectors (per q-head)."""

    q: jax.Array    # [B, Hq, W_obs, hd]
    n: jax.Array    # [B] valid count (saturates at W_obs)

    @property
    def w_obs(self) -> int:
        return self.q.shape[2]


def init_obs(batch: int, n_q_heads: int, head_dim: int, w_obs: int = 256,
             dtype=jnp.float32) -> ObsWindow:
    return ObsWindow(
        q=jnp.zeros((batch, n_q_heads, w_obs, head_dim), dtype),
        n=jnp.zeros((batch,), jnp.int32),
    )


def push_query(obs: ObsWindow, q: jax.Array) -> ObsWindow:
    """q: [B, Hq, hd] — append to ring."""
    w = obs.w_obs
    slot = obs.n % w
    sl = jnp.arange(w)[None] == slot[:, None]  # [B, W]
    qn = jnp.where(sl[:, None, :, None], q[:, :, None, :].astype(obs.q.dtype), obs.q)
    return ObsWindow(q=qn, n=obs.n + 1)


def snap_scores(obs: ObsWindow, k: jax.Array, valid: jax.Array,
                w_pool: int = 5) -> jax.Array:
    """k: [B, Hkv, N, hd]; valid: [B, Hkv, N]. Returns scores [B, Hkv, N]."""
    b, hq, w, d = obs.q.shape
    hkv = k.shape[1]
    g = hq // hkv
    qg = obs.q.reshape(b, hkv, g, w, d)
    logits = jnp.einsum("bhgwd,bhnd->bhgwn", qg, k.astype(obs.q.dtype))
    logits = logits / jnp.sqrt(d).astype(logits.dtype)
    qvalid = (jnp.arange(w)[None] < jnp.minimum(obs.n, w)[:, None])  # [B, W]
    mask = valid[:, :, None, None, :] & qvalid[:, None, None, :, None]
    logits = jnp.where(mask, logits, -1e30)
    a = jax.nn.softmax(logits, axis=-1)
    a = jnp.where(mask, a, 0.0)
    raw = a.max(axis=2).sum(axis=2)  # max over group heads, sum over window
    # local smoothing: max-pool width w_pool along N
    pads = w_pool // 2
    padded = jnp.pad(raw, ((0, 0), (0, 0), (pads, pads)), constant_values=-jnp.inf)
    pooled = jnp.max(
        jnp.stack([padded[..., i:i + raw.shape[-1]] for i in range(w_pool)], 0), 0
    )
    return jnp.where(valid, pooled, -jnp.inf)


def evict_global(cache: DualCache, scores: jax.Array, *,
                 evict_frac: float = 0.10) -> DualCache:
    """Drop the bottom ``evict_frac`` of *valid* global entries per head and
    compact. scores: [B, Hkv, C] (−inf on invalid)."""
    b, h, c, d = cache.gk.shape
    n_evict = jnp.maximum((cache.gcnt * evict_frac).astype(jnp.int32), 1)
    n_evict = jnp.where(cache.gcnt > 0, n_evict, 0)
    # rank: keep highest-score entries, preserve relative position order
    keep_n = cache.gcnt - n_evict  # [B, H]
    order = jnp.argsort(-scores, axis=-1)  # descending score
    rank_of_slot = jnp.argsort(order, axis=-1)  # rank per original slot
    keep = rank_of_slot < keep_n[..., None]  # [B, H, C] keep mask
    # compact: stable-sort slots by (kept? position : +inf)
    poskey = jnp.where(keep, cache.gpos, jnp.iinfo(jnp.int32).max)
    perm = jnp.argsort(poskey, axis=-1)  # kept entries first, ascending pos
    take = lambda x: jnp.take_along_axis(x, perm[..., None], axis=2)
    newcnt = keep.sum(-1).astype(jnp.int32)
    valid = jnp.arange(c)[None, None] < newcnt[..., None]
    newgk = jnp.where(valid[..., None], take(cache.gk), 0)
    # compaction permutes every slot, so the Quest page metadata is rebuilt
    # here from scratch — eviction is the rare O(C log C) event already; the
    # per-step decode path stays delta-only (see lazy_promote_and_write)
    meta = build_page_meta(newgk, valid)
    return cache._replace(
        gk=newgk,
        gv=jnp.where(valid[..., None], take(cache.gv), 0),
        gpos=jnp.where(valid, jnp.take_along_axis(cache.gpos, perm, axis=2), 0),
        gcnt=newcnt,
        pkmin=meta.kmin.astype(cache.pkmin.dtype),
        pkmax=meta.kmax.astype(cache.pkmax.dtype),
    )


def maybe_evict(cache: DualCache, obs: ObsWindow, *, hard_budget: int,
                evict_frac: float = 0.10) -> tuple[DualCache, jax.Array]:
    """Trigger eviction when any head's global count reaches ``hard_budget``.
    Returns (cache, triggered [B, Hkv] bool)."""
    gvalid = jnp.arange(cache.budget)[None, None] < cache.gcnt[..., None]
    trig = cache.gcnt >= hard_budget  # [B, H]
    scores = snap_scores(obs, cache.gk, gvalid)
    evicted = evict_global(cache, scores, evict_frac=evict_frac)
    pick = lambda new, old: jnp.where(
        trig[..., None, None] if old.ndim == 4 else
        (trig[..., None] if old.ndim == 3 else trig), new, old)
    merged = cache._replace(
        gk=pick(evicted.gk, cache.gk),
        gv=pick(evicted.gv, cache.gv),
        gpos=pick(evicted.gpos, cache.gpos),
        gcnt=jnp.where(trig, evicted.gcnt, cache.gcnt),
        pkmin=pick(evicted.pkmin, cache.pkmin),
        pkmax=pick(evicted.pkmax, cache.pkmax),
    )
    return merged, trig
