"""Static admission baselines (paper §5.2, Appendix E).

Both are *input-independent* admission policies re-contextualized into the
same gate interface as WG-KV (g per (head, token)), so they reuse the
identical vertical-slash / dual-cache machinery:

* Local Attention (StreamingLLM): admit only attention sinks; everything
  else lives (transiently) in the sliding local window.
* DuoAttention: a per-head static split into "retrieval heads" (admit all)
  and "streaming heads" (sinks + local window only).
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp


def local_attention_gates(batch: int, n_kv_heads: int, seq: int,
                          sink: int = 128) -> jax.Array:
    """g = 1 for sink tokens, 0 elsewhere. [B, H, S]."""
    g = (jnp.arange(seq) < sink).astype(jnp.float32)
    return jnp.broadcast_to(g[None, None], (batch, n_kv_heads, seq))


def duo_attention_gates(batch: int, head_is_retrieval: jax.Array, seq: int,
                        sink: int = 128) -> jax.Array:
    """head_is_retrieval: [H] bool. Retrieval heads admit everything;
    streaming heads admit only sinks. [B, H, S]."""
    h = head_is_retrieval.shape[0]
    sinks = (jnp.arange(seq) < sink).astype(jnp.float32)[None, :]
    g = jnp.where(head_is_retrieval[:, None], 1.0, sinks)  # [H, S]
    return jnp.broadcast_to(g[None], (batch, h, seq))


def identify_retrieval_heads(gate_scores: jax.Array, ratio: float) -> jax.Array:
    """Profile-based head identification (DuoAttention-style): rank heads by
    mean admission of a *learned* gate on calibration data and flag the top
    ``ratio`` fraction as retrieval heads. gate_scores: [B, H, S] -> [H]."""
    per_head = gate_scores.mean(axis=(0, 2))  # [H]
    h = per_head.shape[0]
    k = max(1, int(round(ratio * h)))
    thresh = jnp.sort(per_head)[h - k]
    return per_head >= thresh


def full_attention_gates(batch: int, n_kv_heads: int, seq: int) -> jax.Array:
    """The no-admission upper baseline: admit everything."""
    return jnp.ones((batch, n_kv_heads, seq), jnp.float32)


def gates_from_positions(policy: str, positions: jax.Array, n_kv_heads: int,
                         *, sink: int,
                         retrieval_heads: Sequence[int] = ()) -> jax.Array:
    """Static admission gates at arbitrary absolute positions.

    The serving-time form of the baselines above: instead of a [B, H, S]
    prefill grid, gates are evaluated at the given absolute ``positions``
    ([B] for one decode step, [B, S] for a prefill chunk) so chunked
    prefill and decode writes see position-consistent admission.
    Returns [B, H] or [B, H, S] matching ``positions`` with a head axis
    inserted at dim 1.
    """
    g = (positions < sink).astype(jnp.float32)            # [B] or [B, S]
    out_shape = g.shape[:1] + (n_kv_heads,) + g.shape[1:]
    g = jnp.broadcast_to(jnp.expand_dims(g, 1), out_shape)
    if policy == "streaming_llm":
        return g
    if policy == "duo":
        retr = jnp.zeros((n_kv_heads,), bool)
        if len(retrieval_heads):
            retr = retr.at[jnp.asarray(retrieval_heads, jnp.int32)].set(True)
        retr = retr.reshape((1, n_kv_heads) + (1,) * (g.ndim - 2))
        return jnp.where(retr, 1.0, g)
    raise ValueError(f"unknown static admission policy {policy!r}")
