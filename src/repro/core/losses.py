"""Training objective for the admission policy (paper §3.3).

    L_total = L_distill + lambda * L_sparsity
    L_distill  = mean || h_student_final - h_teacher_final ||^2
    L_sparsity = mean_{l,h,t} ( g + g * (1 - g) )

The backbone is frozen; only Write-Gate MLP parameters receive gradients.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp


def distill_loss(h_student: jax.Array, h_teacher: jax.Array,
                 loss_mask: jax.Array | None = None) -> jax.Array:
    """L2 on final-layer hidden states. h: [B, S, D]; mask: [B, S]."""
    d = jnp.square(h_student.astype(jnp.float32) - h_teacher.astype(jnp.float32))
    d = d.mean(-1)
    if loss_mask is not None:
        return (d * loss_mask).sum() / jnp.maximum(loss_mask.sum(), 1.0)
    return d.mean()


def sparsity_loss(gates: jax.Array, loss_mask: jax.Array | None = None) -> jax.Array:
    """gates: [..., T] stacked over layers/heads. First term drives admission
    down; second penalizes non-binary values (pushes g toward {0, 1})."""
    g = gates.astype(jnp.float32)
    per = g + g * (1.0 - g)
    if loss_mask is not None:
        # gates: [L, B, H, T]; mask: [B, T] -> [1, B, 1, T]
        m = loss_mask[None, :, None, :] if per.ndim == 4 else loss_mask
        w = jnp.broadcast_to(m, per.shape)
        return (per * w).sum() / jnp.maximum(w.sum(), 1.0)
    return per.mean()


def total_loss(h_student, h_teacher, gates, lam: float,
               loss_mask=None) -> tuple[jax.Array, Dict[str, jax.Array]]:
    ld = distill_loss(h_student, h_teacher, loss_mask)
    ls = sparsity_loss(gates, loss_mask)
    aux = {
        "distill": ld,
        "sparsity": ls,
        "mean_gate": gates.mean(),
        "admission_rate@0.1": (gates >= 0.1).mean(),
    }
    return ld + lam * ls, aux
