"""Dual-cache (Local ring + budgeted Global) with Lazy Promotion (paper §4).

The logical view per attention layer and kv-head is:
  * Local Cache — ring buffer of the last ``W_local`` tokens (k, v, g, pos);
    unconditional retention (grace period for "transient utility").
  * Global Cache — budgeted region of admitted tokens; grows via Lazy
    Promotion: when the ring overwrites a victim, the victim is promoted
    iff its stored gate score g >= tau.

All shapes are static (XLA-friendly): the Global Cache has fixed capacity
``C`` with a per-head valid count ``gcnt`` (ragged lengths across heads,
exactly the paper's Fig. 4 problem, handled logically here and physically
by serving/paged.py). Overflowing promotions are counted in ``overflow``
and are what the composable SnapKV eviction (core/eviction.py) relieves.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.admission import select_global
from repro.core.selection import (
    build_page_meta, init_page_meta, update_page_meta_on_write,
)


class DualCache(NamedTuple):
    lk: jax.Array      # [B, H, W, hd] local keys (post-RoPE)
    lv: jax.Array      # [B, H, W, hd]
    lg: jax.Array      # [B, H, W]    gate score of local entries
    lpos: jax.Array    # [B, W] int32 absolute positions (-1 = empty slot)
    gk: jax.Array      # [B, H, C, hd]
    gv: jax.Array      # [B, H, C, hd]
    gpos: jax.Array    # [B, H, C] int32
    gcnt: jax.Array    # [B, H] int32 valid entries in global cache
    t: jax.Array       # [B] int32 next absolute position
    ptr: jax.Array     # [B] int32 ring pointer (next victim slot)
    overflow: jax.Array  # [B, H] int32 promotions dropped for lack of budget
    # Quest page metadata over the global cache (ceil(C/PAGE_SIZE) pages),
    # maintained incrementally: delta-folded on promote, recomputed only at
    # the (rare) eviction compaction. Empty lanes hold ±META_BIG sentinels.
    pkmin: jax.Array   # [B, H, P, hd]
    pkmax: jax.Array   # [B, H, P, hd]

    @property
    def w_local(self) -> int:
        return self.lk.shape[2]

    @property
    def budget(self) -> int:
        return self.gk.shape[2]

    def memory_tokens(self) -> jax.Array:
        """Current per-head resident token count: [B, H]."""
        local = jnp.minimum(self.t, self.w_local)[:, None]
        return self.gcnt + local


def init_dual_cache(
    batch: int,
    n_kv_heads: int,
    head_dim: int,
    *,
    w_local: int,
    budget: int,
    dtype=jnp.float32,
) -> DualCache:
    b, h, w, c, d = batch, n_kv_heads, w_local, budget, head_dim
    pkmin, pkmax = init_page_meta(b, h, c, d, dtype=dtype)
    return DualCache(
        pkmin=pkmin,
        pkmax=pkmax,
        lk=jnp.zeros((b, h, w, d), dtype),
        lv=jnp.zeros((b, h, w, d), dtype),
        lg=jnp.zeros((b, h, w), jnp.float32),
        lpos=jnp.full((b, w), -1, jnp.int32),
        gk=jnp.zeros((b, h, c, d), dtype),
        gv=jnp.zeros((b, h, c, d), dtype),
        gpos=jnp.zeros((b, h, c), jnp.int32),
        gcnt=jnp.zeros((b, h), jnp.int32),
        t=jnp.zeros((b,), jnp.int32),
        ptr=jnp.zeros((b,), jnp.int32),
        overflow=jnp.zeros((b, h), jnp.int32),
    )


def prefill_populate(
    cache: DualCache,
    k: jax.Array,  # [B, H, S, hd] post-RoPE keys
    v: jax.Array,
    g: jax.Array,  # [B, H, S]
    *,
    tau: float,
    sink: int = 0,
) -> DualCache:
    """Initial cache population (paper §4.2): final W tokens -> Local Cache
    (ring layout: token at absolute pos p occupies slot p % W); earlier
    tokens -> Global Cache iff admitted (g >= tau), up to the budget."""
    b, h, s, d = k.shape
    w = cache.w_local
    # ---- local: last min(W, S) tokens at slots pos % W -------------------
    n_local = min(w, s)
    local_pos = jnp.arange(s - n_local, s)  # absolute positions
    slots = local_pos % w
    lk = cache.lk.at[:, :, slots].set(k[:, :, s - n_local:])
    lv = cache.lv.at[:, :, slots].set(v[:, :, s - n_local:])
    lg = cache.lg.at[:, :, slots].set(g[:, :, s - n_local:].astype(jnp.float32))
    lpos = cache.lpos.at[:, slots].set(local_pos[None].astype(jnp.int32))
    # ---- global: admitted tokens before the local window -----------------
    sel = select_global(
        g, budget=cache.budget, tau=tau, sink=sink, exclude_from=s - n_local
    )
    bidx = jnp.arange(b)[:, None, None]
    hidx = jnp.arange(h)[None, :, None]
    gk = jnp.where(sel.valid[..., None], k[bidx, hidx, sel.idx], 0).astype(cache.gk.dtype)
    gv = jnp.where(sel.valid[..., None], v[bidx, hidx, sel.idx], 0).astype(cache.gv.dtype)
    gpos = jnp.where(sel.valid, sel.idx, 0)
    if gk.shape[2] < cache.budget:
        # short prefill (S < capacity): pad to the static budget
        pad = cache.budget - gk.shape[2]
        gk = jnp.pad(gk, ((0, 0), (0, 0), (0, pad), (0, 0)))
        gv = jnp.pad(gv, ((0, 0), (0, 0), (0, pad), (0, 0)))
        gpos = jnp.pad(gpos, ((0, 0), (0, 0), (0, pad)))
    # page metadata: one O(C) rebuild at population time (the per-step
    # decode path only ever delta-updates it — see lazy_promote_and_write)
    gvalid = jnp.arange(cache.budget)[None, None] < sel.count[..., None]
    meta = build_page_meta(gk, gvalid)
    return cache._replace(
        lk=lk, lv=lv, lg=lg, lpos=lpos,
        gk=gk, gv=gv, gpos=gpos, gcnt=sel.count,
        pkmin=meta.kmin.astype(cache.pkmin.dtype),
        pkmax=meta.kmax.astype(cache.pkmax.dtype),
        t=jnp.full_like(cache.t, s),
        ptr=jnp.full_like(cache.ptr, s % w),
    )


def lazy_promote_and_write(
    cache: DualCache,
    k_new: jax.Array,  # [B, H, hd] post-RoPE key of the freshly generated token
    v_new: jax.Array,
    g_new: jax.Array,  # [B, H]
    *,
    tau: float,
) -> DualCache:
    """Decode-phase cache update (paper Fig. 6d):

    1. inspect the victim at the ring pointer;
    2. promote it to the Global Cache iff its stored g >= tau (per head);
    3. overwrite the slot with the new token; advance the pointer.
    """
    b, h, w, d = cache.lk.shape
    c = cache.budget
    barange = jnp.arange(b)
    # ---- victim ----------------------------------------------------------
    vk = cache.lk[barange, :, cache.ptr]              # [B, H, hd]
    vv = cache.lv[barange, :, cache.ptr]
    vg = cache.lg[barange, :, cache.ptr]              # [B, H]
    vpos = cache.lpos[barange, cache.ptr]             # [B]
    victim_valid = vpos >= 0                          # [B]
    promote = victim_valid[:, None] & (vg >= tau)     # [B, H]
    can_write = promote & (cache.gcnt < c)
    # ---- promotion: true scatter (touches one slot per head, not the
    # whole cache — the jnp analogue of the paged in-place page write;
    # §Perf P3 iteration: the previous one-hot `where` rewrote the entire
    # global cache every step, tripling decode HBM traffic) --------------
    dest = jnp.minimum(cache.gcnt, c - 1)             # [B, H]
    bi = barange[:, None].repeat(h, 1)                # [B, H]
    hi = jnp.arange(h)[None, :].repeat(b, 0)
    old_k = cache.gk[bi, hi, dest]
    old_v = cache.gv[bi, hi, dest]
    old_p = cache.gpos[bi, hi, dest]
    up_k = jnp.where(can_write[..., None], vk.astype(cache.gk.dtype), old_k)
    up_v = jnp.where(can_write[..., None], vv.astype(cache.gv.dtype), old_v)
    up_p = jnp.where(can_write, vpos[:, None], old_p)
    gk = cache.gk.at[bi, hi, dest].set(up_k)
    gv = cache.gv.at[bi, hi, dest].set(up_v)
    gpos = cache.gpos.at[bi, hi, dest].set(up_p)
    gcnt = cache.gcnt + can_write.astype(jnp.int32)
    overflow = cache.overflow + (promote & ~can_write).astype(jnp.int32)
    # incremental Quest metadata: fold the promoted key into the one page
    # its append lands in (same touched-slot discipline as the gk scatter)
    pkmin, pkmax = update_page_meta_on_write(
        cache.pkmin, cache.pkmax, dest, vk, can_write)
    # ---- write the new token into the ring (scatter at ptr) --------------
    lk = cache.lk.at[barange, :, cache.ptr].set(k_new.astype(cache.lk.dtype))
    lv = cache.lv.at[barange, :, cache.ptr].set(v_new.astype(cache.lv.dtype))
    lg = cache.lg.at[barange, :, cache.ptr].set(g_new.astype(jnp.float32))
    lpos = cache.lpos.at[barange, cache.ptr].set(cache.t)
    return cache._replace(
        lk=lk, lv=lv, lg=lg, lpos=lpos,
        gk=gk, gv=gv, gpos=gpos, gcnt=gcnt, overflow=overflow,
        pkmin=pkmin, pkmax=pkmax,
        t=cache.t + 1, ptr=(cache.ptr + 1) % w,
    )


def cache_kv_for_attention(cache: DualCache) -> Tuple[jax.Array, ...]:
    """Concatenate [global | local] K/V with validity mask for decode
    attention. Returns (k [B,H,C+W,hd], v, valid [B,H,C+W])."""
    k = jnp.concatenate([cache.gk, cache.lk], axis=2)
    v = jnp.concatenate([cache.gv, cache.lv], axis=2)
    c = cache.budget
    gvalid = jnp.arange(c)[None, None] < cache.gcnt[..., None]       # [B,H,C]
    lvalid = (cache.lpos >= 0)[:, None, :]                           # [B,1,W]
    lvalid = jnp.broadcast_to(lvalid, cache.lg.shape)
    return k, v, jnp.concatenate([gvalid, lvalid], axis=2)
