"""Write-Gate MLP (paper §3.2).

Per (layer, kv-head) two-layer MLP predicting the future utility
``g in [0,1]`` of a token *before* its KV pair enters the cache:

    x = [RMSNorm(k_pre_rope); RMSNorm(k_post_rope)]       (2*head_dim,)
    g = sigmoid(W2 @ gelu(W1 @ x + b1) + b2)

Weights are stored per-head: W1 [H, 2*hd, hidden], b1 [H, hidden],
W2 [H, hidden, 1], b2 [H, 1]. Layer stacking happens outside (the layer
scan stacks a leading n_repeats axis).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

Params = Dict[str, jax.Array]


def init_gate(key: jax.Array, cfg: ModelConfig) -> Params:
    h = cfg.n_kv_heads
    fin = 2 * cfg.head_dim
    hid = cfg.wgkv.gate_hidden
    dt = jnp.dtype(cfg.param_dtype)
    k1, k2 = jax.random.split(key)
    scale1 = 1.0 / jnp.sqrt(fin)
    scale2 = 1.0 / jnp.sqrt(hid)
    return {
        "w1": (jax.random.normal(k1, (h, fin, hid)) * scale1).astype(dt),
        "b1": jnp.zeros((h, hid), dt),
        "w2": (jax.random.normal(k2, (h, hid, 1)) * scale2).astype(dt),
        # positive bias => gates start near "admit" (~0.73) so early training
        # matches the teacher; the sparsity loss then pushes them down.
        "b2": jnp.full((h, 1), 1.0, dt),
    }


def _rmsnorm_nowt(x: jax.Array, eps: float = 1e-6) -> jax.Array:
    return x * jax.lax.rsqrt(jnp.mean(jnp.square(x), axis=-1, keepdims=True) + eps)


def gate_features(k_pre: jax.Array, k_post: jax.Array) -> jax.Array:
    """[..., H, T, hd] x2 -> [..., H, T, 2*hd] (both inputs RMS-normalized)."""
    return jnp.concatenate([_rmsnorm_nowt(k_pre), _rmsnorm_nowt(k_post)], axis=-1)


def gate_scores(params: Params, k_pre: jax.Array, k_post: jax.Array) -> jax.Array:
    """Compute g for keys.

    k_pre, k_post: [B, H_kv, T, hd] (pre-/post-RoPE keys).
    Returns g: [B, H_kv, T] in (0, 1).
    """
    x = gate_features(k_pre, k_post)  # [B,H,T,2hd]
    x = x.astype(params["w1"].dtype)
    h = jnp.einsum("bhtf,hfm->bhtm", x, params["w1"]) + params["b1"][None, :, None]
    h = jax.nn.gelu(h)
    y = jnp.einsum("bhtm,hmo->bhto", h, params["w2"]) + params["b2"][None, :, None]
    return jax.nn.sigmoid(y[..., 0]).astype(jnp.float32)


def gate_param_count(cfg: ModelConfig) -> int:
    h, fin, hid = cfg.n_kv_heads, 2 * cfg.head_dim, cfg.wgkv.gate_hidden
    return h * (fin * hid + hid + hid + 1)
