# NOTE: do not import repro.launch.dryrun here — it sets XLA_FLAGS at import
# time and must only be imported as the FIRST jax-touching module.
from repro.launch import mesh, specs, steps  # noqa: F401
