"""Production mesh builders.

A FUNCTION (not module-level constant) so importing this module never
touches jax device state. The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import to materialize the placeholder devices.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(shape=(2, 4), axes=("data", "model")):
    """Small mesh for tests (requires >= prod(shape) host devices)."""
    return jax.make_mesh(shape, axes)
