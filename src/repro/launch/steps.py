"""Step builders for pjit lowering: (fn, example-arg structs, shardings).

Used by dryrun.py (lower + compile on the production mesh), roofline
analysis (L1/L2 unrolled-diff accounting), and the real train/serve
drivers on small meshes.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig
from repro.launch import specs as S
from repro.models import inference as I
from repro.models import registry as R
from repro.models import transformer as T
from repro.sharding import rules
from repro.training import trainer as TR
from repro.training.optimizer import cosine_schedule


def _with_act_sharding(fn, mesh: Mesh, batch: int, cfg=None):
    """Pin the residual-stream batch sharding inside the step (stabilizes
    SPMD propagation across depths — required for L1/L2 roofline diffs)."""
    bax = rules.pick(batch, mesh, rules.batch_axes(mesh), "data")
    e_ax = None
    if cfg is not None and cfg.moe is not None:
        e_ax = rules.pick(cfg.moe.n_experts, mesh, "model")

    def wrapped(*args, **kw):
        with rules.activation_sharding(bax, expert_ax=e_ax):
            return fn(*args, **kw)

    return wrapped


class StepBundle(NamedTuple):
    fn: Any                 # python callable (to be jit'ed by caller)
    args: Tuple             # ShapeDtypeStruct pytrees
    in_shardings: Tuple
    donate_argnums: Tuple[int, ...]
    knobs: Dict[str, Any]


# ==========================================================================
# execution knobs per (arch, shape)
# ==========================================================================
def exec_knobs(cfg: ModelConfig, shape: InputShape, mesh: Mesh) -> Dict[str, Any]:
    s = shape.seq_len
    k: Dict[str, Any] = {"q_chunk": None, "block_chunk": None,
                         "moe_groups": 1, "remat": False}
    seq_for_attn = cfg.dec_max_len if cfg.arch_type == "audio" else s
    if shape.kind == "train":
        k["remat"] = True
        if seq_for_attn >= 2048:
            k["q_chunk"] = 512
    if shape.kind == "prefill" and seq_for_attn >= 8192:
        w = cfg.wgkv.w_local
        nb = seq_for_attn // w
        k["block_chunk"] = max(1, min(8, nb))
        while nb % k["block_chunk"]:
            k["block_chunk"] -= 1
        k["q_chunk"] = 512  # baseline full-attention path
    if cfg.moe is not None:
        tokens = shape.global_batch * (seq_for_attn if shape.kind != "decode" else 1)
        groups = 1
        for cand in (rules._axsize(mesh, rules.batch_axes(mesh)),
                     mesh.shape.get("data", 1), 1):
            if tokens % cand == 0 and shape.global_batch % cand == 0:
                groups = cand
                break
        k["moe_groups"] = groups
    return k


def _named(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def _replicated_tree(tree, mesh: Mesh):
    return jax.tree.map(lambda _: _named(mesh, P()), tree)


def param_structs(cfg: ModelConfig):
    return jax.eval_shape(
        functools.partial(T.init_model, cfg=cfg), jax.random.PRNGKey(0))


def _input_shardings(inputs: Dict[str, Any], mesh: Mesh, batch: int):
    out = {}
    for k, v in inputs.items():
        if k == "positions":          # [3, B, S]
            out[k] = _named(mesh, P(None, rules.pick(batch, mesh, rules.batch_axes(mesh)), None))
        else:
            nd = len(v.shape)
            out[k] = _named(mesh, rules.tokens_spec(mesh, batch, nd - 1))
    return out


# ==========================================================================
# train step
# ==========================================================================
def make_train_bundle(cfg: ModelConfig, shape: InputShape, mesh: Mesh,
                      *, scan_unroll: bool = False) -> StepBundle:
    knobs = exec_knobs(cfg, shape, mesh)
    pstruct = param_structs(cfg)
    inputs = S.train_inputs(cfg, shape)
    lr = cosine_schedule(1e-3, 7500)

    def _vlm_fix(params, batch):
        batch = dict(batch)
        if cfg.arch_type == "vlm":
            embeds, pos3 = R.build_vlm_embeds(
                params, cfg, batch.pop("tokens"), batch.pop("patch_embeds"),
                S.VLM_GRID)
            batch["tokens"] = None
            batch["embeds"] = embeds
            batch["positions"] = pos3
        return batch

    if cfg.wgkv.enabled and cfg.wgkv_applicable():
        # the paper's training: gate-only distillation, frozen backbone
        state_struct = jax.eval_shape(TR.init_train_state, pstruct)

        def fn(state, params, batch):
            batch = _vlm_fix(params, batch)
            return TR.train_step(
                state, params, cfg, batch, lr=lr,
                moe_groups=knobs["moe_groups"], q_chunk=knobs["q_chunk"],
                remat=knobs["remat"], scan_unroll=scan_unroll)

        in_sh = (
            _replicated_tree(state_struct, mesh),
            rules.param_shardings(pstruct, mesh, cfg),
            _input_shardings(inputs, mesh, shape.global_batch),
        )
        return StepBundle(_with_act_sharding(fn, mesh, shape.global_batch, cfg),
                          (state_struct, pstruct, inputs), in_sh, (0,), knobs)

    # WG-KV-inapplicable arch (xlstm): standard full-parameter LM training
    state_struct = jax.eval_shape(TR.init_lm_train_state, pstruct)
    psh = rules.param_shardings(pstruct, mesh, cfg)
    state_sh = TR.LMTrainState(
        psh, TR.AdamWState(_named(mesh, P()), psh, psh))

    def fn(state, batch):
        batch = _vlm_fix(state.params, batch)
        return TR.lm_train_step(
            state, cfg, batch, lr=lr, moe_groups=knobs["moe_groups"],
            q_chunk=knobs["q_chunk"], remat=knobs["remat"],
            scan_unroll=scan_unroll)

    in_sh = (state_sh, _input_shardings(inputs, mesh, shape.global_batch))
    return StepBundle(_with_act_sharding(fn, mesh, shape.global_batch, cfg),
                      (state_struct, inputs), in_sh, (0,), knobs)


# ==========================================================================
# prefill step
# ==========================================================================
def make_prefill_bundle(cfg: ModelConfig, shape: InputShape, mesh: Mesh, *,
                        use_wgkv: bool, scan_unroll: bool = False) -> StepBundle:
    knobs = exec_knobs(cfg, shape, mesh)
    pstruct = param_structs(cfg)
    inputs = S.prefill_inputs(cfg, shape)

    def fn(params, batch):
        batch = dict(batch)
        kw: Dict[str, Any] = {}
        if cfg.arch_type == "vlm":
            batch.pop("positions", None)  # rebuilt as 3D M-RoPE ids below
            embeds, pos3 = R.build_vlm_embeds(
                params, cfg, batch.pop("tokens"), batch.pop("patch_embeds"),
                S.VLM_GRID)
            kw["embeds"] = embeds
            kw["positions"] = pos3
        out, caches = I.prefill(
            params, cfg, batch.pop("tokens", None), use_wgkv=use_wgkv,
            budget=cfg.wgkv.global_budget(shape.seq_len),
            max_len=shape.seq_len + 64,
            moe_groups=knobs["moe_groups"], block_chunk=knobs["block_chunk"],
            q_chunk=knobs["q_chunk"], scan_unroll=scan_unroll, **batch, **kw)
        return out.logits, out.mean_admission, caches

    in_sh = (
        rules.param_shardings(pstruct, mesh, cfg),
        _input_shardings(inputs, mesh, shape.global_batch),
    )
    return StepBundle(_with_act_sharding(fn, mesh, shape.global_batch, cfg),
                      (pstruct, inputs), in_sh, (), knobs)


# ==========================================================================
# decode (serve) step
# ==========================================================================
def make_decode_bundle(cfg: ModelConfig, shape: InputShape, mesh: Mesh, *,
                       use_wgkv: bool, scan_unroll: bool = False) -> StepBundle:
    knobs = exec_knobs(cfg, shape, mesh)
    pstruct = param_structs(cfg)
    cstruct = S.decode_cache_structs(cfg, shape, use_wgkv=use_wgkv)
    inputs = S.decode_inputs(cfg, shape)
    seq_shard = shape.global_batch < rules._axsize(mesh, rules.batch_axes(mesh))
    # decode §Perf: weights-stationary when the model-sharded params fit
    # HBM alongside the cache — kills the per-step FSDP all-gathers
    model_ways = mesh.shape.get("model", 1)
    per_chip_param_gb = cfg.param_count() * 2 / model_ways / 2**30
    replicate = knobs.setdefault(
        "replicate_params", per_chip_param_gb <= 4.0)

    def fn(params, caches, batch):
        logits, new_caches, stats = I.decode_step(
            params, cfg, batch["token"], caches,
            moe_groups=knobs["moe_groups"], scan_unroll=scan_unroll)
        return logits, new_caches

    in_sh = (
        rules.param_shardings(pstruct, mesh, cfg, replicate_fsdp=replicate),
        rules.cache_shardings(cstruct, mesh, cfg, seq_shard=seq_shard),
        _input_shardings(inputs, mesh, shape.global_batch),
    )
    return StepBundle(_with_act_sharding(fn, mesh, shape.global_batch, cfg),
                      (pstruct, cstruct, inputs), in_sh, (1,), knobs)


def make_bundle(cfg: ModelConfig, shape: InputShape, mesh: Mesh, *,
                use_wgkv: bool, scan_unroll: bool = False,
                knob_overrides: Optional[Dict[str, Any]] = None) -> StepBundle:
    if knob_overrides:
        orig = exec_knobs

        def patched(cfg_, shape_, mesh_):
            k = orig(cfg_, shape_, mesh_)
            k.update(knob_overrides)
            return k

        globals()["exec_knobs"], restore = patched, orig
        try:
            return make_bundle(cfg, shape, mesh, use_wgkv=use_wgkv,
                               scan_unroll=scan_unroll)
        finally:
            globals()["exec_knobs"] = restore
    if shape.kind == "train":
        return make_train_bundle(cfg, shape, mesh, scan_unroll=scan_unroll)
    if shape.kind == "prefill":
        return make_prefill_bundle(cfg, shape, mesh, use_wgkv=use_wgkv,
                                   scan_unroll=scan_unroll)
    return make_decode_bundle(cfg, shape, mesh, use_wgkv=use_wgkv,
                              scan_unroll=scan_unroll)
