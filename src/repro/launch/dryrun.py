import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))
# ^ MUST precede every other import: jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (arch x input-shape) on the
production mesh and record memory/cost/collective evidence.

    PYTHONPATH=src python -m repro.launch.dryrun \
        --arch qwen3-moe-235b-a22b --shape train_4k --mesh single

Proves the distribution config is coherent without hardware: sharding
mismatches, compile-time OOM, and unsupported collectives all fail here.
Results append to benchmarks/artifacts/dryrun.json (one record per run).
"""
import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax

from repro.configs import ARCH_NAMES, get_config, get_shape, shape_applicable
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import make_bundle
from repro.roofline.hlo_parse import parse_collectives

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                         "benchmarks", "artifacts")


def run_dryrun(arch: str, shape_name: str, *, multi_pod: bool = False,
               use_wgkv: Optional[bool] = None, scan_unroll: bool = False,
               n_repeats_override: Optional[int] = None,
               collect_hlo: bool = False, mesh=None,
               knob_overrides: Optional[Dict[str, Any]] = None,
               cfg_override=None, lower_only: bool = False) -> Dict[str, Any]:
    cfg = cfg_override if cfg_override is not None else get_config(arch)
    shape = get_shape(shape_name)
    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "skipped": True,
                "reason": reason}
    if use_wgkv is None:
        use_wgkv = cfg.wgkv.enabled
    if n_repeats_override is not None:
        over = {"n_repeats": n_repeats_override, "stem_pattern": ()}
        if cfg.is_encdec:
            over["n_enc_repeats"] = n_repeats_override
        cfg = cfg.replace(**over)
    if mesh is None:
        mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    bundle = make_bundle(cfg, shape, mesh, use_wgkv=use_wgkv,
                         scan_unroll=scan_unroll,
                         knob_overrides=knob_overrides)
    t0 = time.time()
    with mesh:
        jitted = jax.jit(bundle.fn, in_shardings=bundle.in_shardings,
                         donate_argnums=bundle.donate_argnums)
        lowered = jitted.lower(*bundle.args)
        t_lower = time.time() - t0
        if lower_only:
            # pre-optimization analysis: GLOBAL (unpartitioned) flops/bytes,
            # linear in depth (no fusion/propagation noise) — the roofline
            # FLOP source (roofline/analysis.py)
            cost = lowered.cost_analysis()
            return {
                "arch": arch, "shape": shape_name,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "devices": n_dev, "wgkv": bool(use_wgkv),
                "kind": shape.kind, "lower_only": True,
                "n_repeats_override": n_repeats_override,
                "knobs": bundle.knobs, "lower_s": round(t_lower, 1),
                "cost_global": {
                    "flops": cost.get("flops"),
                    "bytes_accessed": cost.get("bytes accessed"),
                    "transcendentals": cost.get("transcendentals"),
                },
            }
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll_bytes, coll_detail = parse_collectives(hlo, n_dev)
    rec: Dict[str, Any] = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "devices": n_dev,
        "wgkv": bool(use_wgkv),
        "kind": shape.kind,
        "n_repeats_override": n_repeats_override,
        "scan_unroll": scan_unroll,
        "knobs": bundle.knobs,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        },
        "cost": {
            "flops": cost.get("flops"),
            "bytes_accessed": cost.get("bytes accessed"),
            "transcendentals": cost.get("transcendentals"),
        },
        "collectives": {"per_chip_bytes": coll_bytes, "detail": coll_detail},
    }
    if collect_hlo:
        rec["hlo_text"] = hlo
    return rec


def append_record(rec: Dict[str, Any], path: Optional[str] = None) -> None:
    path = path or os.path.join(ARTIFACTS, "dryrun.json")
    os.makedirs(os.path.dirname(path), exist_ok=True)
    records = []
    if os.path.exists(path):
        with open(path) as f:
            records = json.load(f)
    key = (rec["arch"], rec["shape"], rec.get("mesh"), rec.get("wgkv"),
           rec.get("n_repeats_override"))
    records = [r for r in records
               if (r["arch"], r["shape"], r.get("mesh"), r.get("wgkv"),
                   r.get("n_repeats_override")) != key]
    records.append(rec)
    with open(path, "w") as f:
        json.dump(records, f, indent=1, default=str)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True,
                    choices=list(ARCH_NAMES) + ["all"])
    ap.add_argument("--shape", required=True,
                    choices=["train_4k", "prefill_32k", "decode_32k",
                             "long_500k", "all"])
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--wgkv", default="auto", choices=["auto", "on", "off"])
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    archs = list(ARCH_NAMES) if args.arch == "all" else [args.arch]
    shapes = (["train_4k", "prefill_32k", "decode_32k", "long_500k"]
              if args.shape == "all" else [args.shape])
    wg = None if args.wgkv == "auto" else (args.wgkv == "on")
    mesh = make_production_mesh(multi_pod=args.mesh == "multi")
    for arch in archs:
        for shp in shapes:
            try:
                rec = run_dryrun(arch, shp, multi_pod=args.mesh == "multi",
                                 use_wgkv=wg, mesh=mesh)
            except Exception as e:  # record failures — they are bugs to fix
                rec = {"arch": arch, "shape": shp,
                       "mesh": "2x16x16" if args.mesh == "multi" else "16x16",
                       "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-2000:]}
            append_record(rec, args.out)
            status = ("SKIP " + rec.get("reason", "")[:40] if rec.get("skipped")
                      else ("ERROR " + rec.get("error", "")[:80] if "error" in rec
                            else f"ok mem={rec['memory']['peak_bytes']}"))
            print(f"[dryrun] {arch} x {shp} ({rec.get('mesh')}): {status}",
                  flush=True)


if __name__ == "__main__":
    main()
