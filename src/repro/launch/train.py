"""Gate-distillation training driver (the paper's training recipe).

Works at two scales:
  * real run on this CPU container with --reduced (smoke/e2e examples)
  * production lowering on the 16x16 / 2x16x16 mesh via dryrun.py

    PYTHONPATH=src python -m repro.launch.train \
        --arch smollm-360m --reduced --steps 200 --batch 8 --seq 512 \
        --lam 0.08 --out /tmp/gates.npz
"""
from __future__ import annotations

import argparse
import json
import time

import jax

from repro.configs import ARCH_NAMES, get_config, get_reduced_config
from repro.data.synthetic import DistillStream
from repro.models import transformer as T
from repro.training import checkpoint
from repro.training import trainer as TR
from repro.training.optimizer import cosine_schedule


def run_training(cfg, *, steps: int, batch: int, seq: int, lam: float,
                 peak_lr: float = 1e-3, seed: int = 0, log_every: int = 10,
                 out: str | None = None, params=None, verbose: bool = True):
    key = jax.random.PRNGKey(seed)
    if params is None:
        params = T.init_model(key, cfg)
    state = TR.init_train_state(params)
    lr = cosine_schedule(peak_lr, steps)
    step_fn = TR.make_train_step(cfg, lr=lr, lam=lam)
    stream = DistillStream(seed + 1, batch, seq, cfg.vocab_size)
    history = []
    t0 = time.time()
    for i, batch_data in zip(range(steps), stream):
        state, m = step_fn(state, params, batch=batch_data)
        if i % log_every == 0 or i == steps - 1:
            rec = {k: float(v) for k, v in m.items()}
            rec["step"] = i
            rec["wall_s"] = round(time.time() - t0, 1)
            history.append(rec)
            if verbose:
                print(f"step {i:5d} loss={rec['loss']:.4f} "
                      f"distill={rec['distill']:.4f} "
                      f"admission={rec['admission_rate@0.1']:.3f} "
                      f"({rec['wall_s']}s)", flush=True)
    params = TR.set_gates(params, state.gates)
    if out:
        checkpoint.save(out, state.gates,
                        meta={"arch": cfg.name, "lam": lam, "steps": steps,
                              "history": history})
    return params, state, history


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_NAMES)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--lam", type=float, default=0.08)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    cfg = get_reduced_config(args.arch) if args.reduced else get_config(args.arch)
    cfg = cfg.replace(dtype="float32")
    if not (cfg.wgkv.enabled and cfg.wgkv_applicable()):
        raise SystemExit(f"{args.arch}: WG-KV inapplicable (no KV cache); "
                         "see DESIGN.md §4")
    _, state, history = run_training(
        cfg, steps=args.steps, batch=args.batch, seq=args.seq, lam=args.lam,
        peak_lr=args.lr, seed=args.seed, out=args.out)
    print(json.dumps(history[-1], indent=1))


if __name__ == "__main__":
    main()
