"""Serving driver: the ServeSession client API over any registered
engine backend — WG-KV dual cache (default), dense full-KV, or a static
admission baseline — with chunked prefill, dispatch-ahead decode
(two-phase dispatch/collect), per-request token streaming, mid-stream
cancellation, deadlines, and admission-aware telemetry (plus optional
Quest / SnapKV composition).

    PYTHONPATH=src python -m repro.launch.serve \
        --arch qwen3-0.6b --reduced --requests 8 --max-new 16 --quest-pages 4
    PYTHONPATH=src python -m repro.launch.serve \
        --arch qwen3-0.6b --reduced --backend dense --requests 4
    PYTHONPATH=src python -m repro.launch.serve \
        --arch qwen3-0.6b --reduced --dispatch-ahead 0     # sync baseline
    PYTHONPATH=src python -m repro.launch.serve \
        --arch qwen3-0.6b --reduced --selection quest:4  # top-K decode
    PYTHONPATH=src python -m repro.launch.serve \
        --arch qwen3-0.6b --reduced --trace-out trace.json \
        --metrics-interval 5                               # observability
    XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
        python -m repro.launch.serve --arch qwen3-0.6b --reduced --mesh 2x4
"""
from __future__ import annotations

import argparse
import sys
import warnings

import jax

from repro.configs import ARCH_NAMES, get_config, get_reduced_config
from repro.core import admission as A
from repro.models import inference as I
from repro.models import transformer as T
from repro.serving.backend import BACKEND_NAMES, make_backend
from repro.serving.obs import Tracer, write_chrome_trace
from repro.serving.orchestrator import (QueueFull, SchedulerConfig,
                                        ServeSession)
from repro.serving.sharded import build_mesh


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_NAMES)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--backend", default="wgkv", choices=BACKEND_NAMES,
                    help="serving engine backend (protocol implementation)")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--capacity", type=int, default=512)
    ap.add_argument("--chunk-tokens", type=int, default=64,
                    help="prefill chunk per scheduler tick (w_local-aligned)")
    ap.add_argument("--max-prefill-batch", type=int, default=None,
                    help="cap on prefill tasks advanced per tick in the one "
                         "batched ragged device call (default: all in-flight "
                         "prefills, bounded by --slots)")
    ap.add_argument("--selection", default=None, metavar="quest:K",
                    help="decode-time page selection: on decode-only fused "
                         "ticks, attend over only the top-K global pages "
                         "per (row, kv head), scored query-aware from "
                         "incremental per-page key min/max metadata "
                         "(dual-cache backends only)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="enable the content-addressed prefix store: "
                         "requests sharing a chunk-aligned prompt prefix "
                         "splice the cached post-admission KV instead of "
                         "re-prefilling it (multi-turn / shared-context "
                         "TTFT win)")
    ap.add_argument("--prefix-cache-mb", type=int, default=256,
                    help="prefix store LRU byte budget in MiB")
    ap.add_argument("--dispatch-ahead", type=int, default=1,
                    help="decode steps kept in flight on the device "
                         "(0 = synchronous one-step-per-tick baseline)")
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="per-request latency deadline; overdue requests "
                         "are cancelled mid-stream")
    ap.add_argument("--max-pending", type=int, default=None,
                    help="queue backpressure bound (default unbounded)")
    ap.add_argument("--mesh", default=None, metavar="DxM",
                    help="run decode/extend SPMD over a data x model mesh, "
                         "e.g. 2x4 (debug recipe: XLA_FLAGS="
                         "--xla_force_host_platform_device_count=8)")
    ap.add_argument("--quest-pages", type=int, default=None)
    ap.add_argument("--evict-budget", type=int, default=None)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--quiet-stream", action="store_true",
                    help="suppress per-token stream prints")
    # observability (repro.serving.obs): lifecycle + tick-phase tracing
    ap.add_argument("--trace-out", default=None, metavar="TRACE.json",
                    help="record request-lifecycle and tick-phase spans and "
                         "write a Chrome-trace/Perfetto JSON on exit "
                         "(open in chrome://tracing or ui.perfetto.dev)")
    ap.add_argument("--trace-capacity", type=int, default=1 << 16,
                    help="tracer ring-buffer span capacity (oldest dropped)")
    ap.add_argument("--device-annotations", action="store_true",
                    help="also wrap traced phases in jax.profiler."
                         "TraceAnnotation so device profiles show them")
    ap.add_argument("--metrics-interval", type=float, default=None,
                    metavar="SECONDS",
                    help="print a live rolling metrics line (windowed tok/s "
                         "+ latency percentiles + memory gauges) at most "
                         "every SECONDS while serving")
    args = ap.parse_args()
    if args.max_pending is not None and args.max_pending < 1:
        ap.error("--max-pending must be >= 1")
    if args.chunk_tokens < 1:
        ap.error("--chunk-tokens must be >= 1")
    if args.dispatch_ahead < 0:
        ap.error("--dispatch-ahead must be >= 0")
    if args.max_prefill_batch is not None and args.max_prefill_batch < 1:
        ap.error("--max-prefill-batch must be >= 1")
    if args.trace_capacity < 1:
        ap.error("--trace-capacity must be >= 1")
    if args.metrics_interval is not None and args.metrics_interval <= 0:
        ap.error("--metrics-interval must be > 0")
    if args.prefix_cache_mb < 1:
        ap.error("--prefix-cache-mb must be >= 1")
    cfg = get_reduced_config(args.arch) if args.reduced else get_config(args.arch)
    cfg = cfg.replace(dtype="float32")
    if not cfg.has_attention_cache:
        raise SystemExit(f"{args.arch} has no KV cache; engine serves "
                         "attention archs (SSM decode via examples/)")
    if cfg.is_encdec:
        raise SystemExit("enc-dec serving requires audio frontends; see "
                         "examples/ for whisper decode")
    params = T.init_model(jax.random.PRNGKey(args.seed), cfg)
    if args.backend == "wgkv" and cfg.wgkv.enabled:
        # PR 8 knife-edge tau guard, now at startup: probe the gate-score
        # cluster with one short forward and surface check_tau_margin's
        # RuntimeWarning as a one-line stderr notice — a tau inside the
        # cluster flips admissions between numerically-equivalent prefill
        # paths, which shows up later as baffling parity failures.
        ptoks = jax.random.randint(jax.random.PRNGKey(args.seed + 99),
                                   (1, min(args.prompt_len, 32)), 0,
                                   cfg.vocab_size - 8)
        g = T.forward(params, cfg, ptoks, mode="gated",
                      with_logits=False).gates
        if g is not None:
            with warnings.catch_warnings(record=True) as caught:
                warnings.simplefilter("always", RuntimeWarning)
                margin = A.check_tau_margin(g, cfg.wgkv.tau)
            if any(issubclass(w.category, RuntimeWarning) for w in caught):
                print(f"WARNING: knife-edge admission tau={cfg.wgkv.tau}: "
                      f"min |g - tau| = {margin:.2e} over a "
                      f"{ptoks.shape[1]}-token probe; admission may flip "
                      "between numerically-equivalent prefill paths",
                      file=sys.stderr)
    opts = I.DecodeOptions(quest_pages=args.quest_pages,
                           evict_hard_budget=args.evict_budget)
    mesh = build_mesh(args.mesh)
    if mesh is not None:
        print(f"mesh: {dict(mesh.shape)} over {mesh.size} devices")
    eng = make_backend(args.backend, params, cfg, slots=args.slots,
                       capacity=args.capacity, opts=opts,
                       temperature=args.temperature, seed=args.seed,
                       selection=args.selection, mesh=mesh)
    print(f"backend: {eng.capabilities()}")
    tracer = None
    if args.trace_out or args.device_annotations:
        tracer = Tracer(capacity=args.trace_capacity,
                        annotate_device=args.device_annotations)
    prefix_cache = None
    if args.prefix_cache:
        from repro.serving.prefix_cache import PrefixCache
        prefix_cache = PrefixCache(quantum=args.chunk_tokens,
                                   budget_bytes=args.prefix_cache_mb << 20,
                                   free_fn=eng.release_prefix)
    session = ServeSession(
        eng,
        sched=SchedulerConfig(chunk_tokens=args.chunk_tokens,
                              dispatch_ahead=args.dispatch_ahead,
                              max_prefill_batch=args.max_prefill_batch),
        max_pending=args.max_pending,
        tracer=tracer,
        metrics_interval_s=args.metrics_interval,
        prefix_cache=prefix_cache)

    def on_token(rid: int, tok: int, is_last: bool) -> None:
        if not args.quiet_stream:
            print(f"  stream rid={rid} tok={tok}" + (" <eor>" if is_last else ""),
                  flush=True)

    def submit_bp(prompt, **kw):
        # backpressure: QueueFull is a typed response, so serve until the
        # queue has room instead of counting hammered retries as shed load
        while True:
            try:
                return session.submit(prompt, **kw)
            except QueueFull as qf:
                if not args.quiet_stream:
                    print(f"  backpressure: depth={qf.depth}/"
                          f"{qf.max_pending}, serving to drain")
                session.tick()

    key = jax.random.PRNGKey(args.seed + 7)
    handles = []
    for _ in range(args.requests):
        key, k = jax.random.split(key)
        prompt = jax.random.randint(k, (args.prompt_len,), 0,
                                    cfg.vocab_size - 8).tolist()
        h = submit_bp(prompt, max_new=args.max_new, on_token=on_token,
                      deadline_s=args.deadline_s)
        print(f"submitted rid={h.rid} prompt_len={len(prompt)}")
        handles.append(h)
    session.run()

    print("\nresults:")
    for h in handles:
        tag = " (cancelled: deadline)" if h.cancelled else ""
        print(f"req {h.rid}: state={h.state}{tag} -> out={h.tokens()}")
    print("\ntelemetry:")
    print(session.report())
    if eng.capabilities().paged:
        # verify_paged needs resident caches, and the pool is already empty
        # after the burst drains — so serve one extra request and check the
        # physical-vs-logical deviation while it is live
        vh = submit_bp([int(t) for t in
                        jax.random.randint(key, (args.prompt_len,), 0,
                                           cfg.vocab_size - 8)],
                       max_new=2)
        for _ in range(10_000):
            if vh.state in ("decode", "done", "cancelled"):
                break
            session.tick()
        session.orchestrator.drain()  # settle the mirror before verifying
        dev = eng.verify_paged() if any(eng.live) else 0.0
        print(f"\npaged-vs-logical max deviation (live request): {dev:.2e}")
        session.run()
    session.close()
    if args.trace_out and tracer is not None:
        obj = write_chrome_trace(
            tracer, args.trace_out,
            meta={"arch": args.arch, "backend": args.backend,
                  "requests": args.requests, "slots": args.slots,
                  "dispatch_ahead": args.dispatch_ahead})
        print(f"\ntrace: {args.trace_out} "
              f"({len(obj['traceEvents'])} events, "
              f"{obj['otherData']['spans_dropped']} dropped)")


if __name__ == "__main__":
    main()
