"""Serving driver: batched requests through the WG-KV dual-cache engine
with paged physical memory (and optional Quest / SnapKV composition).

    PYTHONPATH=src python -m repro.launch.serve \
        --arch qwen3-0.6b --reduced --requests 4 --max-new 16 --quest-pages 4
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import ARCH_NAMES, get_config, get_reduced_config
from repro.models import inference as I
from repro.models import transformer as T
from repro.serving.engine import Engine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_NAMES)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--capacity", type=int, default=512)
    ap.add_argument("--quest-pages", type=int, default=None)
    ap.add_argument("--evict-budget", type=int, default=None)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    cfg = get_reduced_config(args.arch) if args.reduced else get_config(args.arch)
    cfg = cfg.replace(dtype="float32")
    if not cfg.has_attention_cache:
        raise SystemExit(f"{args.arch} has no KV cache; engine serves "
                         "attention archs (SSM decode via examples/)")
    if cfg.is_encdec:
        raise SystemExit("enc-dec serving requires audio frontends; see "
                         "examples/ for whisper decode")
    params = T.init_model(jax.random.PRNGKey(args.seed), cfg)
    opts = I.DecodeOptions(quest_pages=args.quest_pages,
                           evict_hard_budget=args.evict_budget)
    eng = Engine(params, cfg, slots=args.slots, capacity=args.capacity,
                 opts=opts, temperature=args.temperature, seed=args.seed)
    key = jax.random.PRNGKey(args.seed + 7)
    for i in range(args.requests):
        key, k = jax.random.split(key)
        prompt = jax.random.randint(k, (args.prompt_len,), 0,
                                    cfg.vocab_size - 8).tolist()
        eng.add_request(prompt, max_new=args.max_new)
    eng.run(max_steps=args.requests * (args.max_new + 2))
    for rid, req in eng.requests.items():
        print(f"req {rid}: prompt[:8]={req.prompt[:8]} -> out={req.out}")
    print(f"steps={eng.stats['steps']} evict_triggers="
          f"{eng.stats['evict_triggers']:.0f} "
          f"pool_pages={eng.pool.pages_in_use} "
          f"pool_util={eng.pool.utilization():.3f}")
    print("paged-vs-logical max deviation:", eng.verify_paged())


if __name__ == "__main__":
    main()
