"""input_specs(): ShapeDtypeStruct stand-ins for every model input, per
(arch x input-shape x step-kind) — weak-type-correct, shardable, no device
allocation — plus direct cache-tree constructors for decode dry-runs.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import InputShape, ModelConfig
from repro.core.dual_cache import init_dual_cache
from repro.models import attention as A
from repro.models import rglru as RG
from repro.models import xlstm as XL

# number of vision patches in the VLM stream (32x32 grid)
VLM_GRID = (32, 32)
VLM_N_IMG = VLM_GRID[0] * VLM_GRID[1]


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


# ==========================================================================
# token / embedding inputs per step kind
# ==========================================================================
def train_inputs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    if cfg.arch_type == "audio":
        s_dec = cfg.dec_max_len
        return {
            "tokens": sds((b, s_dec), jnp.int32),
            "enc_embeds": sds((b, s // cfg.enc_seq_divisor, cfg.d_model), cfg.dtype),
            "loss_mask": sds((b, s_dec), jnp.float32),
        }
    out = {
        "tokens": sds((b, s), jnp.int32),
        "loss_mask": sds((b, s), jnp.float32),
    }
    if cfg.arch_type == "vlm":
        out["patch_embeds"] = sds((b, VLM_N_IMG, cfg.d_model), cfg.dtype)
        out["positions"] = sds((3, b, s), jnp.int32)
    return out


def prefill_inputs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    if cfg.arch_type == "audio":
        return {
            "tokens": sds((b, cfg.dec_max_len), jnp.int32),
            "enc_embeds": sds((b, s // cfg.enc_seq_divisor, cfg.d_model), cfg.dtype),
        }
    out = {"tokens": sds((b, s), jnp.int32)}
    if cfg.arch_type == "vlm":
        out["patch_embeds"] = sds((b, VLM_N_IMG, cfg.d_model), cfg.dtype)
        out["positions"] = sds((3, b, s), jnp.int32)
    return out


def decode_inputs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    return {"token": sds((shape.global_batch,), jnp.int32)}


# ==========================================================================
# decode cache construction (runs under jax.eval_shape for dry-runs)
# ==========================================================================
def _attn_block_cache(cfg: ModelConfig, bt: str, b: int, capacity: int,
                      use_wgkv: bool, s_enc: Optional[int]):
    dt = jnp.dtype(cfg.dtype)
    if use_wgkv:
        w_ring = cfg.sliding_window if bt == "local_attn" else cfg.wgkv.w_local
        self_cache = init_dual_cache(
            b, cfg.n_kv_heads, cfg.head_dim, w_local=w_ring,
            budget=cfg.wgkv.global_budget(capacity), dtype=dt)
    elif bt == "local_attn":
        # baseline sliding-window arch: ring only (streaming)
        self_cache = init_dual_cache(
            b, cfg.n_kv_heads, cfg.head_dim, w_local=cfg.sliding_window,
            budget=max(cfg.wgkv.sink, 16), dtype=dt)
    else:
        self_cache = A.init_dense_cache(b, cfg.n_kv_heads, cfg.head_dim,
                                        capacity, dt)
    if bt == "attn_cross":
        assert s_enc is not None
        cross_len = cfg.wgkv.global_budget(s_enc) if use_wgkv else s_enc
        cross = A.CrossCache(
            k=jnp.zeros((b, cfg.n_kv_heads, cross_len, cfg.head_dim), dt),
            v=jnp.zeros((b, cfg.n_kv_heads, cross_len, cfg.head_dim), dt),
            valid=jnp.ones((b, cfg.n_kv_heads, cross_len), bool),
        )
        return {"self": self_cache, "cross": cross}
    return self_cache


def _block_cache(cfg: ModelConfig, bt: str, b: int, capacity: int,
                 use_wgkv: bool, s_enc: Optional[int]):
    dt = jnp.dtype(cfg.dtype)
    if bt in ("attn", "attn_moe", "local_attn", "attn_cross"):
        return _attn_block_cache(cfg, bt, b, capacity, use_wgkv, s_enc)
    if bt == "rglru":
        return RG.init_rglru_state(cfg, b, dt)
    if bt == "mlstm":
        return XL.init_mlstm_state(cfg, b, dt)
    if bt == "slstm":
        return XL.init_slstm_state(cfg, b)
    raise ValueError(bt)


def build_decode_caches(cfg: ModelConfig, batch: int, capacity: int, *,
                        use_wgkv: bool, s_enc: Optional[int] = None,
                        prefilled: int = 0) -> Dict[str, Any]:
    """Construct the decode cache tree directly (shape source of truth for
    serve_step dry-runs; also used to warm-start serving)."""
    mk = functools.partial(_block_cache, cfg, b=batch, capacity=capacity,
                           use_wgkv=use_wgkv, s_enc=s_enc)
    caches: Dict[str, Any] = {"t": jnp.full((batch,), prefilled, jnp.int32)}
    if cfg.stem_pattern:
        caches["stem"] = tuple(mk(bt=bt) for bt in cfg.stem_pattern)
    one = {f"b{i}": mk(bt=bt) for i, bt in enumerate(cfg.block_pattern)}
    caches["blocks"] = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (cfg.n_repeats,) + x.shape), one)
    return caches


# ==========================================================================
# batched cache splice helpers (serving: batch-1 prefill -> slot insert)
# ==========================================================================
def cache_batch_axis(path) -> int:
    """Batch axis of a decode-cache leaf given its tree path: stacked
    per-superblock caches carry [n_repeats, B, ...]; the eviction
    observation tree is [n_repeats, n_attn, B, ...]; everything else
    (``t``, stem caches) is batch-leading."""
    keys = [getattr(k, "key", None) for k in path]
    if "obs" in keys:
        return 2
    return 1 if "blocks" in keys else 0


def alloc_batched_caches(caches_one: Any, slots: int) -> Any:
    """Zeroed batch-``slots`` cache tree shaped like a batch-1 tree."""
    return jax.tree_util.tree_map_with_path(
        lambda p, x: jnp.repeat(jnp.zeros_like(x), slots,
                                axis=cache_batch_axis(p)),
        caches_one)


def splice_caches(batch_tree: Any, one_tree: Any, slot: int) -> Any:
    """Write a batch-1 cache tree into batch row ``slot`` of the batch
    tree (the JetStream ``insert`` primitive)."""
    return jax.tree_util.tree_map_with_path(
        lambda p, full, one: jax.lax.dynamic_update_index_in_dim(
            full, jnp.take(one, 0, axis=cache_batch_axis(p)), slot,
            cache_batch_axis(p)),
        batch_tree, one_tree)


def extract_slot_caches(batch_tree: Any, slot: int) -> Any:
    """Read batch row ``slot`` back out as a batch-1 cache tree (inverse
    of :func:`splice_caches`; used for slot migration / tests)."""
    return jax.tree_util.tree_map_with_path(
        lambda p, full: jnp.expand_dims(
            jnp.take(full, slot, axis=cache_batch_axis(p)),
            cache_batch_axis(p)),
        batch_tree)


def cache_tree_bytes(tree: Any) -> int:
    """Device-buffer bytes a cache tree holds, from leaf shape/dtype
    metadata only (no device sync). The prefix store budgets its LRU on
    this: a stored batch-1 tree keeps its full-capacity buffers resident
    however few tokens are admitted into them."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(tree):
        total += int(np.prod(np.shape(leaf), dtype=np.int64)) * \
            jnp.dtype(jnp.result_type(leaf)).itemsize
    return total


def decode_cache_structs(cfg: ModelConfig, shape: InputShape, *,
                         use_wgkv: bool) -> Any:
    b, s = shape.global_batch, shape.seq_len
    s_enc = s // cfg.enc_seq_divisor if cfg.is_encdec else None
    return jax.eval_shape(
        functools.partial(build_decode_caches, cfg, b, s,
                          use_wgkv=use_wgkv, s_enc=s_enc, prefilled=0))
