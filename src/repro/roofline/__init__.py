from repro.roofline import hlo_parse  # noqa: F401

# analysis is imported lazily (it pulls launch.steps); hlo_parse is pure.
