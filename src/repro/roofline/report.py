"""Generate the EXPERIMENTS.md §Dry-run and §Roofline tables from
benchmarks/artifacts/*.json.

    PYTHONPATH=src python -m repro.roofline.report > /tmp/tables.md
"""
from __future__ import annotations

import json
import os

ART = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                   "benchmarks", "artifacts")


def _load(name):
    p = os.path.join(ART, name)
    if not os.path.exists(p):
        return []
    with open(p) as f:
        return json.load(f)


def _gb(x):
    return f"{x / 2**30:.2f}" if x is not None else "-"


def dryrun_table() -> str:
    recs = [r for r in _load("dryrun.json")
            if r.get("n_repeats_override") is None]
    out = ["| arch | shape | mesh | status | peak GB/chip | args GB | "
           "coll GB/chip | compile s |",
           "|---|---|---|---|---|---|---|---|"]
    for r in sorted(recs, key=lambda x: (x["arch"], x["shape"],
                                         x.get("mesh") or "")):
        if r.get("skipped"):
            out.append(f"| {r['arch']} | {r['shape']} | {r.get('mesh','-')} "
                       f"| SKIP (documented) | - | - | - | - |")
            continue
        if "error" in r:
            out.append(f"| {r['arch']} | {r['shape']} | {r.get('mesh')} "
                       f"| ERROR {r['error'][:40]} | - | - | - | - |")
            continue
        m = r["memory"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok "
            f"| {_gb(m['peak_bytes'])} | {_gb(m['argument_bytes'])} "
            f"| {r['collectives']['per_chip_bytes'] / 2**30:.2f} "
            f"| {r.get('compile_s', '-')} |")
    return "\n".join(out)


def roofline_table() -> str:
    recs = _load("roofline.json")
    out = ["| arch | shape | compute s | memory s | collective s | "
           "bottleneck | MODEL_FLOPS | useful | note |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(recs, key=lambda x: (x["arch"], x["shape"])):
        if "error" in r:
            out.append(f"| {r['arch']} | {r['shape']} | - | - | - | "
                       f"SKIP/ERR | - | - | {str(r['error'])[:60]} |")
            continue
        note = _note(r)
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4g} "
            f"| {r['memory_s']:.4g} | {r['collective_s']:.4g} "
            f"| **{r['bottleneck']}** | {r['model_flops']:.3g} "
            f"| {r['useful_ratio']:.2f} | {note} |")
    return "\n".join(out)


def _note(r) -> str:
    b = r["bottleneck"]
    if b == "collective":
        det = r.get("collective_detail_L2", {})
        big = max(det.items(), key=lambda kv: kv[1]["bytes"])[0] if det else "?"
        return f"cut {big} traffic (sharding/precision) to move down"
    if b == "memory":
        return "shrink resident KV (higher admission sparsity) / fuse reads"
    return "increase per-chip work (batch) or reduce redundancy"


def main() -> None:
    print("## §Dry-run (production mesh compile evidence)\n")
    print(dryrun_table())
    print("\n## §Roofline (single-pod 16x16, per chip, v5e constants)\n")
    print(roofline_table())


if __name__ == "__main__":
    main()
