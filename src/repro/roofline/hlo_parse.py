"""Parse collective ops out of compiled HLO text and sum their bytes.

Collectives are inserted by the SPMD partitioner, so they only exist in
``compiled.as_text()`` (post-optimization HLO). For each op we count the
bytes a chip moves over ICI (ring-algorithm accounting):

  all-gather        — output bytes x (n-1)/n        (recv full shard set)
  reduce-scatter    — input bytes  x (n-1)/n
  all-reduce        — 2 x output bytes x (n-1)/n    (RS + AG)
  all-to-all        — output bytes x (n-1)/n
  collective-permute— output bytes

NOTE: ops inside ``while`` bodies appear once in the text but execute
trip-count times; roofline/analysis.py removes this ambiguity by comparing
UNROLLED n_repeats=1 vs n_repeats=2 lowering (per-layer diff), so this
parser is only ever pointed at straight-line (unrolled) entry computations
or used for schedule inspection.
"""
from __future__ import annotations

import re
from typing import Dict, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"=\s*(?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_LINE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*)$")
_REPL_GROUPS_RE = re.compile(r"replica_groups=\{(.*?)\}")
_REPL_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def shape_bytes(type_str: str) -> int:
    m = _SHAPE_RE.match(type_str.strip())
    if not m:
        return 0
    dt, dims = m.groups()
    n = 1
    if dims:
        for d in dims.split(","):
            if d:
                n *= int(d)
    return n * _DTYPE_BYTES.get(dt, 4)


def _group_size(line: str, total_devices: int) -> int:
    m = _REPL_GROUPS_V2_RE.search(line)
    if m:
        return int(m.group(2))
    m = _REPL_GROUPS_RE.search(line)
    if m:
        body = m.group(1)
        first = body.split("}")[0].lstrip("{")
        ids = [x for x in first.split(",") if x.strip() != ""]
        return max(1, len(ids))
    return total_devices


def parse_collectives(hlo_text: str, total_devices: int,
                      bf16_wire: bool = True
                      ) -> Tuple[float, Dict[str, Dict[str, float]]]:
    """Returns (per_chip_ici_bytes, per-kind {count, bytes}).

    ``bf16_wire``: XLA:CPU computes bf16 matmuls in f32 and reduces the f32
    (verified empirically) — on the TPU target those tensors travel as
    bf16. Large (>=1 MiB) f32 collectives of a bf16 model are therefore
    counted at half width. Small f32 collectives (loss scalars, gate stats)
    are left as-is.
    """
    per_kind: Dict[str, Dict[str, float]] = {}
    total = 0.0
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind = m.group(1)
        if "-done(" in line:   # async pair: count the -start only
            continue
        # result type(s) sit between "=" and the op keyword; the op name on
        # the LHS may itself contain the kind string (%all-reduce.5 = ...)
        if "=" not in line:
            continue
        rhs = line.split("=", 1)[1]
        head = rhs.split(kind)[0]
        shapes = _SHAPE_RE.findall(head)
        if not shapes:
            continue
        if f"{kind}-start(" in line and len(shapes) > 1:
            # async start: tuple is (input buffer, output buffer, ...);
            # only the output moves on the wire
            shapes = shapes[-1:]
        out_bytes = 0
        for dt, dims in shapes:
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes = n * _DTYPE_BYTES.get(dt, 4)
            if bf16_wire and dt == "f32" and nbytes >= 2 ** 20:
                nbytes //= 2
            out_bytes += nbytes
        if out_bytes == 0:
            continue
        g = _group_size(line, total_devices)
        ring = (g - 1) / g if g > 1 else 0.0
        if kind == "all-gather":
            moved = out_bytes * ring
        elif kind == "reduce-scatter":
            moved = out_bytes * (g - 1) if g > 1 else 0.0  # input = out*g
        elif kind == "all-reduce":
            moved = 2.0 * out_bytes * ring
        elif kind == "all-to-all":
            moved = out_bytes * ring
        else:  # collective-permute
            moved = float(out_bytes)
        k = per_kind.setdefault(kind, {"count": 0, "bytes": 0.0})
        k["count"] += 1
        k["bytes"] += moved
        total += moved
    return total, per_kind
