import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))
# ^ must precede all other imports (jax locks device count on first init).

"""Roofline sweep: L1/L2 differenced terms for every (arch x shape) on the
single-pod mesh. Writes benchmarks/artifacts/roofline.json.

    PYTHONPATH=src python -m repro.roofline.run_all [--arch A] [--shape S]
"""
import argparse
import traceback

from repro.configs import ARCH_NAMES
from repro.launch.dryrun import run_dryrun
from repro.launch.mesh import make_production_mesh
from repro.roofline.analysis import analyze_pair, append_roofline

SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--wgkv", default="auto", choices=["auto", "on", "off"])
    args = ap.parse_args()
    archs = list(ARCH_NAMES) if args.arch == "all" else [args.arch]
    shapes = SHAPES if args.shape == "all" else [args.shape]
    wg = None if args.wgkv == "auto" else (args.wgkv == "on")
    mesh = make_production_mesh(multi_pod=False)
    for arch in archs:
        for shp in shapes:
            try:
                rec = analyze_pair(arch, shp, use_wgkv=wg, mesh=mesh,
                                   run_dryrun=run_dryrun)
            except Exception as e:
                rec = {"arch": arch, "shape": shp, "wgkv": wg,
                       "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-1500:]}
            append_roofline(rec)
            if "error" in rec:
                print(f"[roofline] {arch} x {shp}: ERROR {rec['error']}",
                      flush=True)
            else:
                ur = rec.get("useful_ratio") or 0.0
                print(f"[roofline] {arch} x {shp}: {rec['bottleneck']} "
                      f"c={rec['compute_s']:.4f}s m={rec['memory_s']:.4f}s "
                      f"x={rec['collective_s']:.4f}s ratio={ur:.2f}",
                      flush=True)


if __name__ == "__main__":
    main()
