"""Roofline analysis from dry-run compiled artifacts (TPU v5e target).

Terms per (arch x shape), single-pod mesh, all PER-CHIP (SPMD HLO shapes
are already partitioned, so ``cost_analysis()`` FLOPs/bytes and parsed
collective bytes are per-chip quantities):

    compute_s    = flops / 197e12          (bf16 MXU peak per chip)
    memory_s     = bytes_accessed / 819e9  (HBM bandwidth per chip)
    collective_s = ici_bytes / 4.5e10      (~50 GB/s/link, ring accounting)

``cost_analysis`` counts while-loop bodies ONCE (verified empirically), so
scanned layer stacks undercount by ~n_repeats. We therefore compile
UNROLLED variants with n_repeats=1 and n_repeats=2 (identical dims / mesh /
shape / shardings) and difference them:

    per_block = cost(L=2) - cost(L=1);  base = cost(L=1) - per_block
    total     = base + n_repeats * per_block (+ stem fraction)

The only remaining hidden loop is sLSTM's sequential time scan (xlstm);
its in-scan recurrent FLOPs are added analytically (documented below).
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional

from repro.configs import get_config, get_shape
from repro.configs.base import InputShape, ModelConfig

PEAK_FLOPS = 197e12      # bf16 per chip (v5e)
HBM_BW = 819e9           # bytes/s per chip
ICI_BW = 4.5e10          # ~50 GB/s/link (decimal ~ 45e9 effective)

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                         "benchmarks", "artifacts")


# ==========================================================================
# analytic corrections for hidden (in-layer) loops
# ==========================================================================
def slstm_hidden_flops(cfg: ModelConfig, shape: InputShape, devices: int) -> float:
    """sLSTM recurrent matmuls inside the time scan: 4 gates x H block-diag
    [dh x dh] per step => 4 * d_model * dh * 2 flops/token (per layer)."""
    if "slstm" not in cfg.block_pattern:
        return 0.0
    n_slstm = sum(1 for b in cfg.block_pattern if b == "slstm") * cfg.n_repeats
    dh = cfg.d_model // cfg.n_heads
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    flops = n_slstm * tokens * 4 * cfg.d_model * dh * 2
    return flops / devices


def model_flops(cfg: ModelConfig, shape: InputShape) -> float:
    """MODEL_FLOPS = 6*N*D (train) / 2*N*D (inference); N = active params."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        if cfg.arch_type == "audio":
            tokens = shape.global_batch * (
                shape.seq_len // cfg.enc_seq_divisor + cfg.dec_max_len)
        # gate training runs teacher fwd + student fwd + student bwd ≈ 8ND
        return 8.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        if cfg.arch_type == "audio":
            tokens = shape.global_batch * (
                shape.seq_len // cfg.enc_seq_divisor + cfg.dec_max_len)
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch  # one decode step


# ==========================================================================
# L1/L2 differenced totals
# ==========================================================================
def _lower_vec(rec: Dict[str, Any]) -> Dict[str, float]:
    return {
        "flops": rec["cost_global"]["flops"] or 0.0,
        "bytes_unfused": rec["cost_global"]["bytes_accessed"] or 0.0,
    }


def _scanned_memory_floor(arch: str, shape_name: str, use_wgkv) -> Optional[float]:
    """Per-chip HBM traffic floor for the real (scanned) program: every
    argument read once + every output written once (params, caches, tokens,
    optimizer state). From the production dry-run record."""
    path = os.path.join(ARTIFACTS, "dryrun.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        recs = json.load(f)
    for r in recs:
        if (r.get("arch") == arch and r.get("shape") == shape_name
                and r.get("mesh") == "16x16"
                and r.get("n_repeats_override") is None
                and not r.get("skipped") and "error" not in r
                and (use_wgkv is None or r.get("wgkv") == use_wgkv)):
            m = r["memory"]
            if m["argument_bytes"] is not None:
                return float(m["argument_bytes"]) + float(m["output_bytes"] or 0)
    return None


def differenced_totals(arch: str, shape_name: str, *, use_wgkv=None,
                       mesh=None, run_dryrun=None) -> Dict[str, Any]:
    """Unrolled n_repeats=1,2 differencing.

    FLOPs: from lowered (pre-optimization) cost_analysis — global shapes,
    exactly linear in depth. Collective bytes: from compiled (post-SPMD)
    HLO, with residual-stream shardings pinned so propagation is
    depth-stable. Memory: per-chip argument+output traffic of the real
    scanned program (floor; the roofline convention)."""
    if run_dryrun is None:
        from repro.launch.dryrun import run_dryrun as run_dryrun  # noqa: PLW0127
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    overrides = {"q_chunk": None, "block_chunk": None}
    rl = [run_dryrun(arch, shape_name, use_wgkv=use_wgkv, scan_unroll=True,
                     n_repeats_override=n, mesh=mesh,
                     knob_overrides=overrides, lower_only=True)
          for n in (1, 2)]
    if rl[0].get("skipped"):
        return {"arch": arch, "shape": shape_name,
                "error": rl[0].get("reason")}
    rc = [run_dryrun(arch, shape_name, use_wgkv=use_wgkv, scan_unroll=True,
                     n_repeats_override=n, mesh=mesh,
                     knob_overrides=overrides)
          for n in (1, 2)]
    for r in rl + rc:
        if "error" in r:
            return {"arch": arch, "shape": shape_name, "error": r["error"]}
    l1, l2 = _lower_vec(rl[0]), _lower_vec(rl[1])
    n_eff = cfg.n_repeats + len(cfg.stem_pattern) / max(len(cfg.block_pattern), 1)
    devices = rl[0]["devices"]

    def extrap(v1, v2):
        pb = v2 - v1
        return max((v1 - pb) + n_eff * pb, 0.0)

    # algorithmic (unpartitioned) flops — what the math requires
    flops_algo_global = extrap(l1["flops"], l2["flops"])
    flops_algo_global += slstm_hidden_flops(cfg, shape, 1)
    # executed (partitioned, post-optimization) per-chip flops/bytes —
    # includes SPMD replication redundancy and fusion savings. Linear in
    # depth once activation shardings are pinned (verified).
    c1 = rc[0]["cost"]["flops"] or 0.0
    c2 = rc[1]["cost"]["flops"] or 0.0
    flops_exec_chip = extrap(c1, c2) + slstm_hidden_flops(cfg, shape, devices)
    b1 = rc[0]["cost"]["bytes_accessed"] or 0.0
    b2 = rc[1]["cost"]["bytes_accessed"] or 0.0
    bytes_exec_chip = extrap(b1, b2)
    coll1 = rc[0]["collectives"]["per_chip_bytes"] or 0.0
    coll2 = rc[1]["collectives"]["per_chip_bytes"] or 0.0
    coll_per_chip = extrap(coll1, coll2)
    mem_floor = _scanned_memory_floor(arch, shape_name, use_wgkv)
    total = {
        "flops": flops_exec_chip,
        "bytes": bytes_exec_chip,
        "coll": coll_per_chip,
        "bytes_args_out_floor": mem_floor,
        "bytes_unfused_per_chip": extrap(l1["bytes_unfused"], l2["bytes_unfused"]) / devices,
    }
    return {
        "arch": arch, "shape": shape_name, "devices": devices,
        "wgkv": rl[0]["wgkv"], "kind": rl[0]["kind"],
        "total_per_chip": total, "n_eff_blocks": n_eff,
        "flops_global": flops_exec_chip * devices,
        "flops_algo_global": flops_algo_global,
        "coll_linearity": {"L1": coll1, "L2": coll2},
        "collective_detail_L2": rc[1]["collectives"]["detail"],
        "memory_L2_peak": rc[1]["memory"]["peak_bytes"],
    }


def roofline_terms(totals: Dict[str, float]) -> Dict[str, Any]:
    comp = totals["flops"] / PEAK_FLOPS
    mem = totals["bytes"] / HBM_BW
    coll = totals["coll"] / ICI_BW
    dominant = max(("compute", comp), ("memory", mem), ("collective", coll),
                   key=lambda kv: kv[1])[0]
    return {"compute_s": comp, "memory_s": mem, "collective_s": coll,
            "bottleneck": dominant}


def analyze_pair(arch: str, shape_name: str, *, use_wgkv=None, mesh=None,
                 run_dryrun=None) -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    d = differenced_totals(arch, shape_name, use_wgkv=use_wgkv, mesh=mesh,
                           run_dryrun=run_dryrun)
    if "error" in d:
        return d
    terms = roofline_terms(d["total_per_chip"])
    mf = model_flops(cfg, shape)
    hlo_global = d["flops_global"]  # executed (x devices): shows redundancy
    d.update(terms)
    d["model_flops"] = mf
    d["hlo_flops_global"] = hlo_global
    d["useful_ratio"] = (mf / hlo_global) if hlo_global else 0.0
    d["algo_ratio"] = (d["flops_algo_global"] / hlo_global) if hlo_global else 0.0
    return d


def append_roofline(rec: Dict[str, Any], path: Optional[str] = None) -> None:
    path = path or os.path.join(ARTIFACTS, "roofline.json")
    os.makedirs(os.path.dirname(path), exist_ok=True)
    records = []
    if os.path.exists(path):
        with open(path) as f:
            records = json.load(f)
    key = (rec["arch"], rec["shape"], rec.get("wgkv"))
    records = [r for r in records
               if (r["arch"], r["shape"], r.get("wgkv")) != key]
    records.append(rec)
    with open(path, "w") as f:
        json.dump(records, f, indent=1, default=str)
