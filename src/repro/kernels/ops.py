"""jit'd wrappers around the Pallas kernels, handling model-level shapes
(GQA head folding, global-token gathering, dual-cache paging).

``interpret`` defaults to True off-TPU so the same call sites work in this
CPU container (kernel bodies execute under the Pallas interpreter) and
compile to real Mosaic kernels on TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.gate_mlp import gate_mlp
from repro.kernels.gated_flash import gated_flash
from repro.kernels.paged_decode import paged_decode
from repro.kernels.rglru_scan import rglru_scan_pallas
from repro.kernels.vertical_slash import vertical_slash


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _fold_gqa(q, k, v):
    """q: [B,Hq,S,hd]; k/v: [B,Hkv,S,hd] -> per-(b,kv-head,group) streams
    [B*Hkv*G, S, hd] with k/v broadcast across the group."""
    b, hq, s, hd = q.shape
    hkv = k.shape[1]
    g = hq // hkv
    qf = q.reshape(b, hkv, g, s, hd).reshape(b * hkv * g, s, hd)
    kf = jnp.repeat(k.reshape(b * hkv, s, hd), g, axis=0)
    vf = jnp.repeat(v.reshape(b * hkv, s, hd), g, axis=0)
    return qf, kf, vf, (b, hq, s, hd, g)


@functools.partial(jax.jit, static_argnames=("w_local", "bq", "bk"))
def gated_flash_attention(q, k, v, g, *, w_local: int, bq: int = 128,
                          bk: int = 128):
    """Model-level write-gated attention. q: [B,Hq,S,hd]; k/v: [B,Hkv,S,hd];
    g: [B,Hkv,S] -> [B,Hq,S,hd]."""
    qf, kf, vf, (b, hq, s, hd, grp) = _fold_gqa(q, k, v)
    gf = jnp.repeat(g.reshape(-1, s), grp, axis=0)
    of = gated_flash(qf, kf, vf, gf, w_local=w_local, bq=bq, bk=bk,
                     interpret=_interpret_default())
    return of.reshape(b, hq, s, hd)


@functools.partial(jax.jit, static_argnames=("w_local", "bc"))
def vertical_slash_attention(q, k, v, kg, vg, gpos, *, w_local: int,
                             bc: int = 128):
    """Budgeted vertical-slash prefill. q: [B,Hq,S,hd]; k/v: [B,Hkv,S,hd];
    kg/vg: [B,Hkv,C,hd]; gpos: [B,Hkv,C] -> [B,Hq,S,hd]."""
    qf, kf, vf, (b, hq, s, hd, grp) = _fold_gqa(q, k, v)
    c = kg.shape[2]
    kgf = jnp.repeat(kg.reshape(-1, c, hd), grp, axis=0)
    vgf = jnp.repeat(vg.reshape(-1, c, hd), grp, axis=0)
    gpf = jnp.repeat(gpos.reshape(-1, c), grp, axis=0)
    of = vertical_slash(qf, kf, vf, kgf, vgf, gpf, w_local=w_local, bc=bc,
                        interpret=_interpret_default())
    return of.reshape(b, hq, s, hd)


@jax.jit
def paged_decode_attention(q, k_pool, v_pool, page_table, lengths):
    """Head-folded paged decode (paper Appendix B). q: [B,Hq,hd]; pools
    [P,page,hd]; page_table: [B,Hkv,max_pages]; lengths: [B,Hkv]
    -> [B,Hq,hd]."""
    b, hq, hd = q.shape
    hkv, mp = page_table.shape[1], page_table.shape[2]
    g = hq // hkv
    qf = q.reshape(b * hkv * g, hd)
    tf = jnp.repeat(page_table.reshape(b * hkv, mp), g, axis=0)
    lf = jnp.repeat(lengths.reshape(b * hkv), g, axis=0)
    of = paged_decode(qf, k_pool, v_pool, tf, lf,
                      interpret=_interpret_default())
    return of.reshape(b, hq, hd)


@functools.partial(jax.jit, static_argnames=("bt", "bd"))
def rglru_linear_scan(a, b, *, bt: int = 128, bd: int = 128):
    """[B,S,D] linear recurrence via the blocked Pallas scan."""
    return rglru_scan_pallas(a, b, bt=bt, bd=bd,
                             interpret=_interpret_default())


@functools.partial(jax.jit, static_argnames=("bs",))
def write_gate(x, w1, b1, w2, b2, *, bs: int = 256):
    """Fused Write-Gate MLP. x: [B,H,S,F] (features) with per-head weights
    [H,F,M]/[H,M]/[H,M,1]/[H,1] -> g [B,H,S] float32."""
    b, h, s, f = x.shape
    xf = x.reshape(b * h, s, f)
    w1f = jnp.tile(w1, (b, 1, 1))
    b1f = jnp.tile(b1, (b, 1))
    w2f = jnp.tile(w2, (b, 1, 1))
    b2f = jnp.tile(b2, (b, 1))
    g = gate_mlp(xf, w1f, b1f, w2f, b2f, bs=bs,
                 interpret=_interpret_default())
    return g.reshape(b, h, s)
