"""Pallas TPU kernel: blocked RG-LRU linear scan.

h_t = a_t * h_{t-1} + b_t, elementwise over the recurrence width. The grid
is (batch, d_blocks, time_blocks) with time innermost; the carry h lives in
VMEM scratch across time steps, and each grid step processes a [Bt, Bd]
tile sequentially within the tile (fori over Bt rows) while staying fully
parallel across (batch, d) — the TPU-friendly decomposition of a scan whose
parallel dimension (channels) is wide and whose sequential dimension is
blocked for VMEM residency.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, b_ref, o_ref, h_ref, *, bt: int):
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[0]  # [Bt, Bd]
    b = b_ref[0]

    def body(i, h):
        h = a[i] * h + b[i]
        o_ref[0, i, :] = h
        return h

    h_ref[...] = jax.lax.fori_loop(0, bt, body, h_ref[...])


def rglru_scan_pallas(a, b, *, bt: int = 128, bd: int = 128,
                      interpret: bool = True):
    """a, b: [B, S, D] float32 -> h [B, S, D]."""
    bsz, s, d = a.shape
    bt = min(bt, s)
    bd = min(bd, d)
    assert s % bt == 0 and d % bd == 0, (s, d, bt, bd)
    kernel = functools.partial(_kernel, bt=bt)
    return pl.pallas_call(
        kernel,
        grid=(bsz, d // bd, s // bt),
        in_specs=[
            pl.BlockSpec((1, bt, bd), lambda i, jd, jt: (i, jt, jd)),
            pl.BlockSpec((1, bt, bd), lambda i, jd, jt: (i, jt, jd)),
        ],
        out_specs=pl.BlockSpec((1, bt, bd), lambda i, jd, jt: (i, jt, jd)),
        out_shape=jax.ShapeDtypeStruct((bsz, s, d), a.dtype),
        scratch_shapes=[pltpu.VMEM((bd,), jnp.float32)],
        interpret=interpret,
    )(a, b)
