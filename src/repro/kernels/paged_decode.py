"""Pallas TPU kernel: paged decode attention over the dual-cache pool.

The paper folds the kv-head dimension into batch (Appendix B) so each
(batch x kv-head) stream attends over its own ragged page list. On TPU the
page table is a *scalar-prefetch* operand: the BlockSpec index_map reads
``page_table[stream, j]`` to choose which physical page tile the next grid
step DMAs from HBM into VMEM — the TPU-native analogue of vLLM's gather.

Grid: (n_streams, max_pages_per_stream), pages innermost; flash-combine
scratch across page steps. Pages beyond ``lengths[stream]`` are masked
(their DMA still happens — index_map clamps to page 0 — but contributes 0).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(table_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
            m_ref, l_ref, acc_ref, *, page: int, max_pages: int):
    n = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                              # [1, hd] single query row
    k = k_ref[0]                              # [page, hd]
    hd = q.shape[-1]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * (hd ** -0.5)
    pos = j * page + jax.lax.broadcasted_iota(jnp.int32, (1, page), 1)
    s = jnp.where(pos < len_ref[n], s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    m_safe = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
    p = jnp.exp(s - m_safe[:, None])
    alpha = jnp.where(m_prev <= NEG_INF / 2, 0.0, jnp.exp(m_prev - m_safe))
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
        p.astype(v_ref.dtype), v_ref[0], preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(j == max_pages - 1)
    def _out():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


def paged_decode(q, k_pool, v_pool, page_table, lengths, *,
                 interpret: bool = True):
    """q: [N, hd]; k_pool/v_pool: [P, page, hd]; page_table: [N, max_pages]
    int32 physical page ids; lengths: [N] valid tokens. Returns [N, hd]."""
    n, hd = q.shape
    p_total, page, _ = k_pool.shape
    max_pages = page_table.shape[1]
    kernel = functools.partial(_kernel, page=page, max_pages=max_pages)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # page_table, lengths
        grid=(n, max_pages),
        in_specs=[
            pl.BlockSpec((1, 1, hd), lambda i, j, tbl, ln: (i, 0, 0)),
            pl.BlockSpec((1, page, hd), lambda i, j, tbl, ln: (tbl[i, j], 0, 0)),
            pl.BlockSpec((1, page, hd), lambda i, j, tbl, ln: (tbl[i, j], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, hd), lambda i, j, tbl, ln: (i, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, 1, hd), q.dtype),
        interpret=interpret,
    )(page_table, lengths, q[:, None, :], k_pool, v_pool)
    return out[:, 0]


def _kernel_selected(table_ref, len_ref, sel_ref, nsel_ref,
                     q_ref, k_ref, v_ref, o_ref,
                     m_ref, l_ref, acc_ref, *, page: int, k_pages: int):
    n = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                              # [1, hd]
    k = k_ref[0]                              # [page, hd]
    hd = q.shape[-1]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * (hd ** -0.5)
    # token position within the LOGICAL stream is recovered from the
    # selected page id, so the ragged-tail mask is the same lengths[] test
    # as the dense-page kernel; whole pages past n_sel[stream] are dropped
    logical = sel_ref[n, j]
    pos = logical * page + jax.lax.broadcasted_iota(jnp.int32, (1, page), 1)
    ok = (pos < len_ref[n]) & (j < nsel_ref[n])
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    m_safe = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
    p = jnp.exp(s - m_safe[:, None])
    p = jnp.where(ok, p, 0.0)
    alpha = jnp.where(m_prev <= NEG_INF / 2, 0.0, jnp.exp(m_prev - m_safe))
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
        p.astype(v_ref.dtype), v_ref[0], preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(j == k_pages - 1)
    def _out():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


def paged_decode_selected(q, k_pool, v_pool, page_table, lengths,
                          sel_ids, n_sel, *, interpret: bool = True):
    """Quest-selected paged decode: attend over only the top-K pages.

    Same layout as :func:`paged_decode` plus ``sel_ids`` [N, K] int32
    LOGICAL page indices per stream (sorted ascending — identity
    permutation when K covers every page) and ``n_sel`` [N] valid counts.
    The grid's page axis shrinks from max_pages to K: the index_map
    double-indirects ``page_table[i, sel_ids[i, j]]`` so only the selected
    physical pages are ever DMA'd from HBM — the kernel-level form of the
    gathered decode path, cost O(K·page) per stream instead of
    O(max_pages·page). Returns [N, hd]."""
    n, hd = q.shape
    p_total, page, _ = k_pool.shape
    k_pages = sel_ids.shape[1]
    kernel = functools.partial(_kernel_selected, page=page, k_pages=k_pages)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,  # page_table, lengths, sel_ids, n_sel
        grid=(n, k_pages),
        in_specs=[
            pl.BlockSpec((1, 1, hd), lambda i, j, tbl, ln, sel, ns: (i, 0, 0)),
            pl.BlockSpec((1, page, hd),
                         lambda i, j, tbl, ln, sel, ns: (tbl[i, sel[i, j]], 0, 0)),
            pl.BlockSpec((1, page, hd),
                         lambda i, j, tbl, ln, sel, ns: (tbl[i, sel[i, j]], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, hd),
                               lambda i, j, tbl, ln, sel, ns: (i, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, 1, hd), q.dtype),
        interpret=interpret,
    )(page_table, lengths, sel_ids.astype(jnp.int32),
      n_sel.astype(jnp.int32), q[:, None, :], k_pool, v_pool)
    return out[:, 0]
