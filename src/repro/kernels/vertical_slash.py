"""Pallas TPU kernel: budgeted vertical-slash prefill attention (paper §4.2).

TPU adaptation of MInference's vertical-slash CUDA kernel: TPU has no
warp-level gather, and the MXU wants dense 128-aligned tiles, so the
admitted ("vertical") tokens are pre-gathered into a contiguous budgeted
buffer [C, hd] outside the kernel (ops.py), and the kernel streams dense
tiles over [slash(prev) | slash(cur) | global tiles] with one flash-style
softmax.

Grid: (n_streams, n_q_blocks, 2 + C/Bc) with the kv-source dimension
innermost:
  step 0 — previous slash block (k block b-1; masked out for b == 0)
  step 1 — current slash block  (k block b)
  steps 2.. — global tiles of the gathered buffer, visibility
              gpos_j <= i - W (strictly older than the window).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, kp_ref, vp_ref, kc_ref, vc_ref, kg_ref, vg_ref, gpos_ref,
            o_ref, m_ref, l_ref, acc_ref, *, w: int, bc: int, n_src: int):
    qb = pl.program_id(1)
    src = pl.program_id(2)

    @pl.when(src == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]  # [W, hd]
    hd = q.shape[-1]
    scale = hd ** -0.5
    qi = jax.lax.broadcasted_iota(jnp.int32, (w, 1), 0)  # in-block query row

    def flash_update(s, v):
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        # rows with every key masked so far: keep p/alpha at exact zero
        m_safe = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
        p = jnp.exp(s - m_safe[:, None])
        alpha = jnp.where(m_prev <= NEG_INF / 2, 0.0, jnp.exp(m_prev - m_safe))
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(jnp.logical_and(src == 0, qb > 0))
    def _slash_prev():
        k = kp_ref[0]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        kj = jax.lax.broadcasted_iota(jnp.int32, (1, w), 1) - w  # rel offsets
        ok = (qi >= kj) & (qi - kj < w)
        flash_update(jnp.where(ok, s, NEG_INF), vp_ref[0])

    @pl.when(src == 1)
    def _slash_cur():
        k = kc_ref[0]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        kj = jax.lax.broadcasted_iota(jnp.int32, (1, w), 1)
        ok = (qi >= kj) & (qi - kj < w)
        flash_update(jnp.where(ok, s, NEG_INF), vc_ref[0])

    @pl.when(src >= 2)
    def _vertical():
        k = kg_ref[0]  # [Bc, hd]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        qabs = qb * w + qi                                   # [W, 1]
        gp = gpos_ref[0][None, :]                            # [1, Bc]
        ok = gp <= qabs - w
        flash_update(jnp.where(ok, s, NEG_INF), vg_ref[0])

    @pl.when(src == n_src - 1)
    def _out():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


def vertical_slash(q, k, v, kg, vg, gpos, *, w_local: int, bc: int = 128,
                   interpret: bool = True):
    """q, k, v: [N, S, hd]; kg, vg: [N, C, hd]; gpos: [N, C] int32.
    S % w_local == 0 and C % bc == 0 required. Returns [N, S, hd]."""
    n, s, hd = q.shape
    c = kg.shape[1]
    w = w_local
    assert s % w == 0, (s, w)
    bc = min(bc, c)
    assert c % bc == 0, (c, bc)
    nb = s // w
    n_src = 2 + c // bc
    kernel = functools.partial(_kernel, w=w, bc=bc, n_src=n_src)

    def prev_map(b, i, j):
        return (b, jnp.maximum(i - 1, 0), 0)

    def cur_map(b, i, j):
        return (b, i, 0)

    def glob_map(b, i, j):
        return (b, jnp.maximum(j - 2, 0), 0)

    return pl.pallas_call(
        kernel,
        grid=(n, nb, n_src),
        in_specs=[
            pl.BlockSpec((1, w, hd), cur_map),            # q
            pl.BlockSpec((1, w, hd), prev_map),           # k prev slash
            pl.BlockSpec((1, w, hd), prev_map),           # v prev slash
            pl.BlockSpec((1, w, hd), cur_map),            # k cur slash
            pl.BlockSpec((1, w, hd), cur_map),            # v cur slash
            pl.BlockSpec((1, bc, hd), glob_map),          # k global tile
            pl.BlockSpec((1, bc, hd), glob_map),          # v global tile
            pl.BlockSpec((1, bc), lambda b, i, j: (b, jnp.maximum(j - 2, 0))),
        ],
        out_specs=pl.BlockSpec((1, w, hd), cur_map),
        out_shape=jax.ShapeDtypeStruct((n, s, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((w,), jnp.float32),
            pltpu.VMEM((w,), jnp.float32),
            pltpu.VMEM((w, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, k, v, kg, vg, gpos)
