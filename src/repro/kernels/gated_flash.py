"""Pallas TPU kernel: write-gated flash attention (training hot path).

FlashAttention-style streaming softmax with the paper's log-space gate bias
(§3.2): inside the local window the bias is 0, outside it is log(g_j+eps),
above the causal diagonal -inf. Grid (n_streams, n_q_blocks, n_kv_blocks)
with the kv dimension innermost; running (m, l, acc) live in VMEM scratch
and the output tile is written on the last kv step. Fully-masked kv blocks
(strictly above the diagonal) are skipped via ``pl.when`` — the vertical-
slash sparsity of the gate shows up as early-exit bandwidth savings on TPU.

Tiling: q [Bq, hd], k/v [Bk, hd], g [Bk] — MXU-aligned (multiples of 128 in
production; tests sweep smaller tiles in interpret mode).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, g_ref, o_ref, m_ref, l_ref, acc_ref, *,
            w_local: int, eps: float, bq: int, bk: int, n_kv: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # skip blocks strictly above the causal diagonal
    @pl.when(ki * bk <= qi * bq + bq - 1)
    def _compute():
        q = q_ref[0]                   # [Bq, hd]
        k = k_ref[0]                   # [Bk, hd]
        g = g_ref[0]                   # [Bk]
        hd = q.shape[-1]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * (hd ** -0.5)
        rows = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        cols = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        causal = rows >= cols
        in_win = causal & (rows - cols < w_local)
        logg = jnp.log(g.astype(jnp.float32) + eps)[None, :]
        bias = jnp.where(in_win, 0.0, logg)
        s = s + jnp.where(causal, bias, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
            p.astype(v_ref.dtype), v_ref[0],
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _out():
        o_ref[0] = (acc_ref[...] / l_ref[...][:, None]).astype(o_ref.dtype)


def gated_flash(q, k, v, g, *, w_local: int, eps: float = 1e-6,
                bq: int = 128, bk: int = 128, interpret: bool = True):
    """q: [N, S, hd]; k, v: [N, S, hd]; g: [N, S] -> [N, S, hd]."""
    n, s, hd = q.shape
    bq = min(bq, s)
    bk = min(bk, s)
    assert s % bq == 0 and s % bk == 0, (s, bq, bk)
    n_q, n_kv = s // bq, s // bk
    kernel = functools.partial(_kernel, w_local=w_local, eps=eps, bq=bq,
                               bk=bk, n_kv=n_kv)
    return pl.pallas_call(
        kernel,
        grid=(n, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk), lambda b, i, j: (b, j)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, s, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),       # m
            pltpu.VMEM((bq,), jnp.float32),       # l
            pltpu.VMEM((bq, hd), jnp.float32),    # acc
        ],
        interpret=interpret,
    )(q, k, v, g)
