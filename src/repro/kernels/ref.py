"""Pure-jnp oracles for every Pallas kernel (the correctness contracts).

These are deliberately straightforward (dense, O(S^2) where applicable) and
are used by tests/test_kernels.py to validate the kernels across shape and
dtype sweeps in interpret mode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def gated_flash_ref(q, k, v, g, *, w_local: int, eps: float = 1e-6):
    """Write-gated attention (training form), single head-group.

    q: [N, Sq, hd]; k, v: [N, Sk, hd]; g: [N, Sk]. Queries are the last Sq
    positions of the Sk-long stream (Sq == Sk here). Returns [N, Sq, hd].
    """
    n, sq, hd = q.shape
    sk = k.shape[1]
    qi = jnp.arange(sq)[:, None]
    kj = jnp.arange(sk)[None, :]
    causal = qi >= kj
    in_win = causal & (qi - kj < w_local)
    logits = jnp.einsum("nqd,nkd->nqk", q, k).astype(jnp.float32) * (hd ** -0.5)
    logg = jnp.log(g.astype(jnp.float32) + eps)[:, None, :]
    bias = jnp.where(in_win[None], 0.0, logg)
    logits = logits + jnp.where(causal[None], bias, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("nqk,nkd->nqd", w.astype(v.dtype), v)


def vertical_slash_ref(q, k, v, kg, vg, gpos, *, w_local: int):
    """Budgeted vertical-slash prefill attention, single head-group.

    q, k, v: [N, S, hd]; kg, vg: [N, C, hd] gathered global tokens with
    absolute positions gpos [N, C] (int32; out-of-range => never visible).
    Query i sees: local window (i-j < w_local, causal) from k, plus global
    tokens with gpos <= i - w_local. One joint softmax. Returns [N, S, hd].
    """
    n, s, hd = q.shape
    qi = jnp.arange(s)[:, None]
    kj = jnp.arange(s)[None, :]
    local_ok = (qi >= kj) & (qi - kj < w_local)
    l1 = jnp.einsum("nqd,nkd->nqk", q, k).astype(jnp.float32) * (hd ** -0.5)
    l1 = jnp.where(local_ok[None], l1, NEG_INF)
    l2 = jnp.einsum("nqd,ncd->nqc", q, kg).astype(jnp.float32) * (hd ** -0.5)
    vis = gpos[:, None, :] <= (jnp.arange(s)[None, :, None] - w_local)
    l2 = jnp.where(vis, l2, NEG_INF)
    logits = jnp.concatenate([l1, l2], axis=-1)
    w = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("nqk,nkd->nqd", w[..., :s].astype(v.dtype), v)
    o = o + jnp.einsum("nqc,ncd->nqd", w[..., s:].astype(vg.dtype), vg)
    return o


def paged_decode_ref(q, k_pool, v_pool, page_table, lengths):
    """Paged decode attention, head-folded-into-batch (paper Appendix B).

    q: [N, hd] one query per (batch x kv-head) stream;
    k_pool, v_pool: [P, page, hd]; page_table: [N, max_pages] int32;
    lengths: [N] valid token count per stream. Returns [N, hd].
    """
    n, hd = q.shape
    p, page, _ = k_pool.shape
    mp = page_table.shape[1]
    k = k_pool[page_table]  # [N, mp, page, hd]
    v = v_pool[page_table]
    k = k.reshape(n, mp * page, hd)
    v = v.reshape(n, mp * page, hd)
    pos = jnp.arange(mp * page)[None]
    valid = pos < lengths[:, None]
    logits = jnp.einsum("nd,nkd->nk", q, k).astype(jnp.float32) * (hd ** -0.5)
    logits = jnp.where(valid, logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("nk,nkd->nd", w.astype(v.dtype), v)


def paged_decode_selected_ref(q, k_pool, v_pool, page_table, lengths,
                              sel_ids, n_sel):
    """Quest-selected paged decode oracle: like :func:`paged_decode_ref`
    but only pages listed in ``sel_ids`` [N, K] (logical indices, first
    ``n_sel[stream]`` valid) contribute."""
    n, hd = q.shape
    p, page, _ = k_pool.shape
    kp = sel_ids.shape[1]
    phys = jnp.take_along_axis(page_table, sel_ids, axis=1)  # [N, K]
    k = k_pool[phys].reshape(n, kp * page, hd)
    v = v_pool[phys].reshape(n, kp * page, hd)
    pos = sel_ids[:, :, None] * page + jnp.arange(page)[None, None]
    pos = pos.reshape(n, kp * page)
    page_ok = (jnp.arange(kp)[None] < n_sel[:, None])[:, :, None]
    valid = (pos < lengths[:, None]) & jnp.broadcast_to(
        page_ok, (n, kp, page)).reshape(n, kp * page)
    logits = jnp.einsum("nd,nkd->nk", q, k).astype(jnp.float32) * (hd ** -0.5)
    logits = jnp.where(valid, logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    w = jnp.where(valid, w, 0.0)
    return jnp.einsum("nk,nkd->nd", w.astype(v.dtype), v)


def rglru_scan_ref(a, b, h0=None):
    """Linear recurrence h_t = a_t * h_{t-1} + b_t. a, b: [B, S, D]."""
    if h0 is None:
        h0 = jnp.zeros(a[:, 0].shape, a.dtype)

    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h

    _, hs = jax.lax.scan(step, h0, (a.transpose(1, 0, 2), b.transpose(1, 0, 2)))
    return hs.transpose(1, 0, 2)


def gate_mlp_ref(x, w1, b1, w2, b2):
    """Write-Gate MLP. x: [H, S, F]; w1: [H, F, M]; w2: [H, M, 1].
    Returns g [H, S] in (0,1), float32."""
    h = jnp.einsum("hsf,hfm->hsm", x, w1) + b1[:, None]
    h = jax.nn.gelu(h)
    y = jnp.einsum("hsm,hmo->hso", h, w2) + b2[:, None]
    return jax.nn.sigmoid(y[..., 0].astype(jnp.float32))
