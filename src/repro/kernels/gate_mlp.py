"""Pallas TPU kernel: fused Write-Gate MLP (paper §3.2 overhead analysis).

Computes g = sigmoid(W2 @ gelu(W1 @ x + b1) + b2) per kv-head in one VMEM
pass: the feature tile [Bs, F] and both weight tiles stay resident, so the
gate adds a single HBM round-trip per key tile (the paper's "negligible
overhead" claim, realized as fusion on TPU).

Grid: (H, S / Bs).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref):
    x = x_ref[0]            # [Bs, F]
    w1 = w1_ref[0]          # [F, M]
    h = jnp.dot(x, w1, preferred_element_type=jnp.float32) + b1_ref[0]
    h = jax.nn.gelu(h)
    y = jnp.dot(h.astype(w2_ref.dtype), w2_ref[0],
                preferred_element_type=jnp.float32) + b2_ref[0]
    o_ref[0] = jax.nn.sigmoid(y[..., 0]).astype(o_ref.dtype)


def gate_mlp(x, w1, b1, w2, b2, *, bs: int = 256, interpret: bool = True):
    """x: [H, S, F]; w1: [H, F, M]; b1: [H, M]; w2: [H, M, 1]; b2: [H, 1]
    -> g [H, S] float32."""
    h, s, f = x.shape
    m = w1.shape[-1]
    bs = min(bs, s)
    assert s % bs == 0
    return pl.pallas_call(
        _kernel,
        grid=(h, s // bs),
        in_specs=[
            pl.BlockSpec((1, bs, f), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, f, m), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, m), lambda i, j: (i, 0)),
            pl.BlockSpec((1, m, 1), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, bs), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((h, s), jnp.float32),
        interpret=interpret,
    )(x, w1, b1, w2, b2)
