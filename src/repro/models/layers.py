"""Shared model building blocks (pure-JAX, functional params-as-pytrees)."""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

Params = Dict[str, jax.Array]


# --------------------------------------------------------------------------
# init helpers
# --------------------------------------------------------------------------
def dense_init(key: jax.Array, shape: Tuple[int, ...], dtype, scale: float | None = None):
    fan_in = shape[0] if len(shape) >= 2 else shape[-1]
    if scale is None:
        scale = fan_in ** -0.5
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def embed_init(key: jax.Array, shape, dtype):
    return (jax.random.normal(key, shape) * 0.02).astype(dtype)


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------
def init_rmsnorm(dim: int, dtype) -> Params:
    return {"scale": jnp.ones((dim,), dtype)}


def rmsnorm(p: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    x32 = x.astype(jnp.float32)
    y = x32 * jax.lax.rsqrt(jnp.mean(jnp.square(x32), -1, keepdims=True) + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def init_layernorm(dim: int, dtype) -> Params:
    return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}


def layernorm(p: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    mu = x32.mean(-1, keepdims=True)
    var = jnp.var(x32, -1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def rmsnorm_nowt(x: jax.Array, eps: float = 1e-6) -> jax.Array:
    x32 = x.astype(jnp.float32)
    y = x32 * jax.lax.rsqrt(jnp.mean(jnp.square(x32), -1, keepdims=True) + eps)
    return y.astype(x.dtype)


# --------------------------------------------------------------------------
# rotary embeddings (standard + Qwen2-VL M-RoPE)
# --------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """[head_dim // 2] inverse frequencies."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., T, hd]; positions: broadcastable to [..., T] (int). Rotates
    pairs (x[2i], x[2i+1]). Returns same dtype as x."""
    if theta <= 0:
        return x
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)  # [hd/2]
    ang = positions[..., None].astype(jnp.float32) * inv  # [..., T, hd/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x32 = x.astype(jnp.float32)
    x1, x2 = x32[..., 0::2], x32[..., 1::2]
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    out = jnp.stack([r1, r2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


def mrope_sections(head_dim: int) -> Tuple[int, int, int]:
    """Qwen2-VL splits the hd/2 frequency slots into (t, h, w) sections;
    for hd=128 the reference uses (16, 24, 24). We generalize by ratio."""
    half = head_dim // 2
    t = half // 4
    h = (half - t) // 2
    w = half - t - h
    return t, h, w


def apply_mrope(x: jax.Array, positions3: jax.Array, theta: float) -> jax.Array:
    """Multimodal RoPE. x: [..., T, hd]; positions3: [3, ..., T] (t, h, w
    position ids — equal for text tokens, spatial for vision tokens)."""
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)  # [hd/2]
    st, sh, sw = mrope_sections(hd)
    sec = jnp.concatenate([
        jnp.zeros((st,), jnp.int32),
        jnp.ones((sh,), jnp.int32),
        jnp.full((sw,), 2, jnp.int32),
    ])  # [hd/2] -> which position stream drives each freq slot
    # gather per-slot positions: [..., T, hd/2]
    pos = jnp.moveaxis(positions3, 0, -1)  # [..., T, 3]
    slot_pos = jnp.take_along_axis(
        pos.astype(jnp.float32),
        jnp.broadcast_to(sec[None], pos.shape[:-1] + (hd // 2,)).astype(jnp.int32),
        axis=-1,
    )
    ang = slot_pos * inv  # [..., T, hd/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x32 = x.astype(jnp.float32)
    x1, x2 = x32[..., 0::2], x32[..., 1::2]
    out = jnp.stack([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1).reshape(x.shape)
    return out.astype(x.dtype)


def sinusoidal_positions(seq: int, dim: int) -> jax.Array:
    """Whisper-style absolute sinusoidal embeddings [seq, dim]."""
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    inv = jnp.exp(-jnp.log(10000.0) * jnp.arange(dim // 2, dtype=jnp.float32)
                  / max(dim // 2 - 1, 1))
    ang = pos * inv[None]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# --------------------------------------------------------------------------
# FFN (SwiGLU, llama-family) and whisper-style GELU MLP
# --------------------------------------------------------------------------
def init_swiglu(key: jax.Array, d_model: int, d_ff: int, dtype) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, (d_model, d_ff), dtype),
        "w_up": dense_init(k2, (d_model, d_ff), dtype),
        "w_down": dense_init(k3, (d_ff, d_model), dtype),
    }


def swiglu(p: Params, x: jax.Array) -> jax.Array:
    dt = x.dtype
    h = jax.nn.silu(x @ p["w_gate"].astype(dt)) * (x @ p["w_up"].astype(dt))
    return h @ p["w_down"].astype(dt)


def init_gelu_mlp(key: jax.Array, d_model: int, d_ff: int, dtype) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "w_in": dense_init(k1, (d_model, d_ff), dtype),
        "b_in": jnp.zeros((d_ff,), dtype),
        "w_out": dense_init(k2, (d_ff, d_model), dtype),
        "b_out": jnp.zeros((d_model,), dtype),
    }


def gelu_mlp(p: Params, x: jax.Array) -> jax.Array:
    dt = x.dtype
    h = jax.nn.gelu(x @ p["w_in"].astype(dt) + p["b_in"].astype(dt))
    return h @ p["w_out"].astype(dt) + p["b_out"].astype(dt)


# --------------------------------------------------------------------------
# embedding / unembedding
# --------------------------------------------------------------------------
def init_embedding(key: jax.Array, cfg: ModelConfig) -> Params:
    dt = jnp.dtype(cfg.param_dtype)
    p = {"tok": embed_init(key, (cfg.vocab_size, cfg.d_model), dt)}
    if not cfg.tie_embeddings:
        key2 = jax.random.fold_in(key, 1)
        p["unembed"] = embed_init(key2, (cfg.d_model, cfg.vocab_size), dt)
    return p


def embed(p: Params, tokens: jax.Array, dtype) -> jax.Array:
    return p["tok"].astype(dtype)[tokens]


def unembed(p: Params, x: jax.Array) -> jax.Array:
    w = p.get("unembed")
    if w is None:
        w = p["tok"].T
    return x @ w.astype(x.dtype)
