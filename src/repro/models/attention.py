"""GQA attention with first-class WG-KV integration.

Modes:
  * train  — dense causal attention, optionally write-gated (log-space bias,
    paper §3.2) for gate training, or hard vertical-slash for eval.
  * prefill (budgeted, production) — banded local attention (the "slash")
    + budgeted global attention over admitted tokens (the "vertical"),
    sub-quadratic: O(S * (2*W + C)) instead of O(S^2). Populates the dual
    cache.
  * decode — one token vs. the dual cache (global ‖ local ‖ self) with
    lazy promotion, or vs. a dense cache for the full-attention baseline.

All paths are pure jnp (the pjit/dry-run path); Pallas TPU kernels in
repro/kernels mirror the train/prefill/decode hot loops and are validated
against these semantics.
"""
from __future__ import annotations

from typing import Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import masks as M
from repro.core import selection as SEL
from repro.core.admission import GlobalSelection, select_global
from repro.core.dual_cache import (
    DualCache,
    cache_kv_for_attention,
    lazy_promote_and_write,
)
from repro.core.gate import gate_scores, init_gate
from repro.models import layers as L

Params = Dict[str, jax.Array]


# ==========================================================================
# dense-cache baseline (full attention)
# ==========================================================================
class DenseCache(NamedTuple):
    k: jax.Array   # [B, Hkv, S_max, hd]
    v: jax.Array
    t: jax.Array   # [B] int32 current length


def init_dense_cache(batch: int, n_kv: int, head_dim: int, max_len: int,
                     dtype=jnp.float32) -> DenseCache:
    z = jnp.zeros((batch, n_kv, max_len, head_dim), dtype)
    return DenseCache(z, z, jnp.zeros((batch,), jnp.int32))


def dense_cache_append(cache: DenseCache, k_new: jax.Array, v_new: jax.Array
                       ) -> DenseCache:
    """k_new: [B, H, hd] appended at per-batch position t."""
    s = cache.k.shape[2]
    slot = jnp.arange(s)[None] == cache.t[:, None]         # [B, S]
    k = jnp.where(slot[:, None, :, None], k_new[:, :, None, :].astype(cache.k.dtype), cache.k)
    v = jnp.where(slot[:, None, :, None], v_new[:, :, None, :].astype(cache.v.dtype), cache.v)
    return DenseCache(k, v, cache.t + 1)


# ==========================================================================
# parameter init
# ==========================================================================
def init_attention(key: jax.Array, cfg: ModelConfig, *, kind: str = "self",
                   with_gate: Optional[bool] = None) -> Params:
    """kind: "self" (causal), "cross" (enc-dec), "enc" (bidirectional)."""
    dt = jnp.dtype(cfg.param_dtype)
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 5)
    p: Params = {
        "w_q": L.dense_init(ks[0], (d, hq * hd), dt),
        "w_k": L.dense_init(ks[1], (d, hkv * hd), dt),
        "w_v": L.dense_init(ks[2], (d, hkv * hd), dt),
        "w_o": L.dense_init(ks[3], (hq * hd, d), dt),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dt)
        p["k_norm"] = jnp.ones((hd,), dt)
    if with_gate is None:
        with_gate = cfg.wgkv.enabled and kind != "enc"
    if with_gate:
        p["gate"] = init_gate(ks[4], cfg)
    return p


# ==========================================================================
# projections
# ==========================================================================
def _heads(x: jax.Array, n: int, hd: int) -> jax.Array:
    """[B, S, n*hd] -> [B, n, S, hd]"""
    b, s, _ = x.shape
    return x.reshape(b, s, n, hd).transpose(0, 2, 1, 3)


def _qk_norm(p: Params, q, k):
    if "q_norm" in p:
        q = L.rmsnorm_nowt(q) * p["q_norm"].astype(q.dtype)
        k = L.rmsnorm_nowt(k) * p["k_norm"].astype(k.dtype)
    return q, k


def project_qkv(p: Params, cfg: ModelConfig, x: jax.Array, positions: jax.Array
                ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Returns (q_rope [B,Hq,S,hd], k_pre [B,Hkv,S,hd], k_rope, v).

    positions: [B, S] int32, or [3, B, S] for M-RoPE archs.
    """
    q = _heads(x @ p["w_q"].astype(x.dtype), cfg.n_heads, cfg.head_dim)
    k_pre = _heads(x @ p["w_k"].astype(x.dtype), cfg.n_kv_heads, cfg.head_dim)
    v = _heads(x @ p["w_v"].astype(x.dtype), cfg.n_kv_heads, cfg.head_dim)
    q, k_pre = _qk_norm(p, q, k_pre)
    if cfg.mrope and positions.ndim == 3:
        pos3q = positions[:, :, None, :]  # [3, B, 1, S] broadcast over heads
        q_r = L.apply_mrope(q, pos3q, cfg.rope_theta)
        k_r = L.apply_mrope(k_pre, pos3q, cfg.rope_theta)
    elif cfg.rope_theta > 0:
        posq = positions[:, None, :]  # [B, 1, S]
        q_r = L.apply_rope(q, posq, cfg.rope_theta)
        k_r = L.apply_rope(k_pre, posq, cfg.rope_theta)
    else:
        q_r, k_r = q, k_pre
    return q_r, k_pre, k_r, v


def compute_gates(p: Params, k_pre: jax.Array, k_rope: jax.Array) -> jax.Array:
    """g: [B, Hkv, S] (float32)."""
    return gate_scores(p["gate"], k_pre, k_rope)


# ==========================================================================
# scaled-dot-product attention with optional query chunking
# ==========================================================================
def sdpa(q: jax.Array, k: jax.Array, v: jax.Array,
         bias_fn: Callable[[int, int], jax.Array],
         *, q_chunk: Optional[int] = None) -> jax.Array:
    """q: [B,Hq,Sq,hd]; k,v: [B,Hkv,Sk,hd]. ``bias_fn(q_start, q_len)``
    returns an additive f32 bias broadcastable to [B,Hkv,G,q_len,Sk]
    (use masks.NEG_INF for disallowed). Chunking bounds the materialized
    score tensor for long sequences (roofline-corrected; see
    roofline/analysis.py hidden-loop accounting)."""
    b, hq, sq, hd = q.shape
    hkv = k.shape[1]
    g = hq // hkv
    qg = q.reshape(b, hkv, g, sq, hd)
    scale = hd ** -0.5

    def block(q_blk: jax.Array, q_start: int, q_len: int) -> jax.Array:
        logits = jnp.einsum("bhgqd,bhkd->bhgqk", q_blk, k).astype(jnp.float32)
        logits = logits * scale + bias_fn(q_start, q_len)
        w = jax.nn.softmax(logits, axis=-1)
        o = jnp.einsum("bhgqk,bhkd->bhgqd", w.astype(v.dtype), v)
        return o

    if q_chunk is None or q_chunk >= sq:
        out = block(qg, 0, sq)
    else:
        assert sq % q_chunk == 0, (sq, q_chunk)
        n = sq // q_chunk

        def body(carry, i):
            q_blk = jax.lax.dynamic_slice_in_dim(qg, i * q_chunk, q_chunk, axis=3)
            return carry, block(q_blk, i * q_chunk, q_chunk)

        # bias_fn must be traceable with dynamic q_start
        _, outs = jax.lax.scan(body, 0, jnp.arange(n))
        out = jnp.moveaxis(outs, 0, 3).reshape(b, hkv, g, sq, hd)
    return out.reshape(b, hq, sq, hd)


# ==========================================================================
# train-mode forward (dense; teacher / write-gated student / hard eval)
# ==========================================================================
def attn_train(p: Params, cfg: ModelConfig, x: jax.Array, positions: jax.Array,
               *, gate_mode: str = "off", window: Optional[int] = None,
               gate_override: Optional[jax.Array] = None,
               q_chunk: Optional[int] = None
               ) -> Tuple[jax.Array, Optional[jax.Array]]:
    """gate_mode: "off" (full/windowed causal teacher), "gated"
    (differentiable log-space write-gate bias), "hard" (binary
    vertical-slash mask at tau). ``window``: sliding-window width for
    local_attn blocks (doubles as W_local in the gate bias)."""
    b, s, _ = x.shape
    q, k_pre, k_rope, v = project_qkv(p, cfg, x, positions)
    g = None
    if gate_mode != "off":
        g = gate_override if gate_override is not None else compute_gates(p, k_pre, k_rope)
    w_local = window if window is not None else cfg.wgkv.w_local

    def bias_fn(q_start, q_len):
        qi = jnp.arange(q_len)[:, None] + q_start
        kj = jnp.arange(s)[None, :]
        causal = qi >= kj
        in_win = causal & (qi - kj < w_local)
        if gate_mode == "off":
            vis = in_win if window is not None else causal
            return jnp.where(vis, 0.0, M.NEG_INF)[None, None, None]
        if gate_mode == "gated":
            logg = jnp.log(g + cfg.wgkv.log_eps)[:, :, None, None, :]  # [B,H,1,1,S]
            bias = jnp.where(in_win[None, None, None], 0.0, logg)
            return jnp.where(causal[None, None, None], bias, M.NEG_INF)
        if gate_mode == "hard":
            admitted = (g >= cfg.wgkv.tau) | (kj[0] < cfg.wgkv.sink)[None, None]
            vis = in_win[None, None, None] | admitted[:, :, None, None, :]
            return jnp.where(vis & causal[None, None, None], 0.0, M.NEG_INF)
        raise ValueError(gate_mode)

    out = sdpa(q, k_rope, v, bias_fn, q_chunk=q_chunk)
    b_, hq, s_, hd = out.shape
    y = out.transpose(0, 2, 1, 3).reshape(b, s, hq * hd) @ p["w_o"].astype(x.dtype)
    return y, g


# ==========================================================================
# budgeted vertical-slash prefill (production, sub-quadratic)
# ==========================================================================
class PrefillResult(NamedTuple):
    out: jax.Array           # [B, S, D]
    k_rope: jax.Array        # [B, Hkv, S, hd]
    v: jax.Array
    g: jax.Array             # [B, Hkv, S]
    sel: GlobalSelection


def attn_prefill_budgeted(p: Params, cfg: ModelConfig, x: jax.Array,
                          positions: jax.Array, *, budget: int,
                          window: Optional[int] = None,
                          gate_override: Optional[jax.Array] = None,
                          block_chunk: Optional[int] = None) -> PrefillResult:
    """Vertical-slash attention (paper §4.2), budgeted for static shapes.

    Every query attends to (a) the slash: its local window of width W via
    banded block attention (key blocks b-1, b for query block b) and (b)
    the vertical: up to ``budget`` admitted tokens (g >= tau) strictly
    older than the window. One softmax over [2W | C] per query.
    """
    b, s, d_model = x.shape
    w = window if window is not None else cfg.wgkv.w_local
    assert s % w == 0, f"seq {s} must be a multiple of the window {w}"
    nb = s // w
    q, k_pre, k_rope, v = project_qkv(p, cfg, x, positions)
    g = gate_override if gate_override is not None else compute_gates(p, k_pre, k_rope)
    sel = select_global(g, budget=budget, tau=cfg.wgkv.tau, sink=cfg.wgkv.sink,
                        exclude_from=s - min(w, s))
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    grp = hq // hkv
    c = sel.idx.shape[-1]

    # gather the vertical (global) keys/values once: [B, Hkv, C, hd]
    bi = jnp.arange(b)[:, None, None]
    hi = jnp.arange(hkv)[None, :, None]
    kg = k_rope[bi, hi, sel.idx]
    vg = v[bi, hi, sel.idx]
    gpos = jnp.where(sel.valid, sel.idx, jnp.iinfo(jnp.int32).max)  # invalid -> never visible

    # block views
    qb = q.reshape(b, hkv, grp, nb, w, hd)
    kb = k_rope.reshape(b, hkv, nb, w, hd)
    vb = v.reshape(b, hkv, nb, w, hd)
    zeros = jnp.zeros_like(kb[:, :, :1])
    k_prev = jnp.concatenate([zeros, kb[:, :, :-1]], axis=2)
    v_prev = jnp.concatenate([jnp.zeros_like(vb[:, :, :1]), vb[:, :, :-1]], axis=2)
    k_band = jnp.concatenate([k_prev, kb], axis=3)   # [B,Hkv,nb,2W,hd]
    v_band = jnp.concatenate([v_prev, vb], axis=3)
    scale = hd ** -0.5

    def one_block(nb_idx_arr):
        """Compute attention for a slice of query blocks (indices array)."""
        qs = qb[:, :, :, nb_idx_arr]                     # [B,H,G,nbc,W,hd]
        ks = k_band[:, :, nb_idx_arr]                    # [B,H,nbc,2W,hd]
        vs = v_band[:, :, nb_idx_arr]
        # slash logits [B,H,G,nbc,W,2W]
        sl = jnp.einsum("bhgnqd,bhnkd->bhgnqk", qs, ks).astype(jnp.float32) * scale
        qi_rel = jnp.arange(w)[:, None]                  # in-block query offset
        kj_rel = jnp.arange(2 * w)[None, :] - w          # key offset rel. block start
        band_ok = (qi_rel >= kj_rel) & (qi_rel - kj_rel < w)
        # first block has no previous block
        first = (nb_idx_arr == 0)[:, None, None]         # [nbc,1,1]
        band_ok = band_ok[None] & (~first | (kj_rel >= 0)[None])
        sl = jnp.where(band_ok[None, None, None], sl, M.NEG_INF)
        # vertical logits [B,H,G,nbc,W,C]
        vl = jnp.einsum("bhgnqd,bhcd->bhgnqc", qs, kg).astype(jnp.float32) * scale
        qabs = nb_idx_arr[:, None] * w + jnp.arange(w)[None]   # [nbc, W]
        # global token j visible iff j <= i - W  (disjoint from the slash)
        vis = gpos[:, :, None, None, :] <= (qabs[..., None] - w)[None, None]
        vl = jnp.where(vis[:, :, None], vl, M.NEG_INF)
        logits = jnp.concatenate([sl, vl], axis=-1)
        wts = jax.nn.softmax(logits, axis=-1)
        o_sl = jnp.einsum("bhgnqk,bhnkd->bhgnqd", wts[..., : 2 * w].astype(vs.dtype), vs)
        o_vl = jnp.einsum("bhgnqc,bhcd->bhgnqd", wts[..., 2 * w:].astype(vg.dtype), vg)
        return o_sl + o_vl                               # [B,H,G,nbc,W,hd]

    if block_chunk is None or block_chunk >= nb:
        out = one_block(jnp.arange(nb))
    else:
        assert nb % block_chunk == 0

        def body(carry, i):
            idx = i * block_chunk + jnp.arange(block_chunk)
            return carry, one_block(idx)

        _, outs = jax.lax.scan(body, 0, jnp.arange(nb // block_chunk))
        # outs: [nchunks, B, H, G, block_chunk, W, hd] -> [B,H,G,nb,W,hd]
        out = jnp.moveaxis(outs, 0, 3).reshape(b, hkv, grp, nb, w, hd)
    y = out.reshape(b, hkv * grp, s, hd).transpose(0, 2, 1, 3)
    y = y.reshape(b, s, hq * hd) @ p["w_o"].astype(x.dtype)
    return PrefillResult(y, k_rope, v, g, sel)


def attn_prefill_full(p: Params, cfg: ModelConfig, x: jax.Array,
                      positions: jax.Array, *, window: Optional[int] = None,
                      q_chunk: Optional[int] = None) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Full-attention baseline prefill: dense causal (optionally windowed).
    Returns (out, k_rope, v)."""
    q, k_pre, k_rope, v = project_qkv(p, cfg, x, positions)
    b, s, _ = x.shape

    def bias_fn(q_start, q_len):
        qi = jnp.arange(q_len)[:, None] + q_start
        kj = jnp.arange(s)[None, :]
        ok = qi >= kj
        if window is not None:
            ok = ok & (qi - kj < window)
        return jnp.where(ok, 0.0, M.NEG_INF)[None, None, None]

    out = sdpa(q, k_rope, v, bias_fn, q_chunk=q_chunk)
    hq, hd = cfg.n_heads, cfg.head_dim
    y = out.transpose(0, 2, 1, 3).reshape(b, s, hq * hd) @ p["w_o"].astype(x.dtype)
    return y, k_rope, v


# ==========================================================================
# decode
# ==========================================================================
def _rope_single(cfg: ModelConfig, x: jax.Array, t: jax.Array) -> jax.Array:
    """x: [B, H, hd] at per-batch position t [B]. (M-RoPE with equal
    (t,t,t) ids degenerates to standard RoPE — used for text decode.)"""
    if cfg.rope_theta <= 0:
        return x
    return L.apply_rope(x[:, :, None, :], t[:, None, None], cfg.rope_theta)[:, :, 0]


def attn_decode_wgkv(p: Params, cfg: ModelConfig, x_t: jax.Array,
                     cache: DualCache, *,
                     gate_override: Optional[jax.Array] = None,
                     token_select_fn: Optional[Callable] = None,
                     select_pages_k: Optional[int] = None
                     ) -> Tuple[jax.Array, DualCache, jax.Array,
                                Optional[jax.Array]]:
    """One decode step against the dual cache. x_t: [B, D].

    Order matters for exact equivalence with the dense vertical-slash mask:
    the cache is updated FIRST (victim at age W promoted iff admitted, new
    token written into the ring), then attention runs over the updated
    cache — so the local window seen by query t is exactly {t-W+1..t} and
    the just-exited token is visible iff admitted, matching
    ``masks.vertical_slash_mask`` semantics token-for-token.

    ``token_select_fn(cache, q) -> [B, Hkv, C+W]``: optional read-time
    Selection mask (Quest composition) computed on the updated cache,
    further restricting visible entries — full-width einsum, no FLOPs
    saved.

    ``select_pages_k``: GATHERED read-time Selection — score the cache's
    incremental page metadata (pkmin/pkmax) against the live query, take
    the top-K pages, and run attention over only the gathered
    ``K*PAGE_SIZE + W`` entries, so decode cost scales with the selection
    budget instead of the admission budget. When K covers every page the
    sorted page-ID gather is the identity permutation and the output is
    bit-identical to the full path. Mutually exclusive with
    ``token_select_fn``.

    Returns (out [B, D], new cache, g_new [B, Hkv], sel_pages) where
    sel_pages is [B, Hkv] valid selected-page counts (None when the
    gathered path is off)."""
    b, d_model = x_t.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    x = x_t[:, None, :]  # [B,1,D]
    q = _heads(x @ p["w_q"].astype(x.dtype), hq, hd)[:, :, 0]       # [B,Hq,hd]
    k_pre = _heads(x @ p["w_k"].astype(x.dtype), hkv, hd)[:, :, 0]
    v_new = _heads(x @ p["w_v"].astype(x.dtype), hkv, hd)[:, :, 0]
    q, k_pre = _qk_norm(p, q[:, :, None], k_pre[:, :, None])
    q, k_pre = q[:, :, 0], k_pre[:, :, 0]
    q = _rope_single(cfg, q, cache.t)
    k_new = _rope_single(cfg, k_pre, cache.t)
    if gate_override is not None:
        g_new = gate_override
    else:
        g_new = gate_scores(p["gate"], k_pre[:, :, None], k_new[:, :, None])[..., 0]

    # update first (promote victim, write self), then attend — see docstring
    new_cache = lazy_promote_and_write(cache, k_new, v_new, g_new, tau=cfg.wgkv.tau)
    sel_pages = None
    if select_pages_k is not None:
        assert token_select_fn is None, "mask and gather selection are exclusive"
        c = new_cache.budget
        assert c % SEL.PAGE_SIZE == 0, \
            "global budget must be page-aligned for gathered Quest selection"
        p_pages = c // SEL.PAGE_SIZE
        meta = SEL.PageMeta(
            new_cache.pkmin, new_cache.pkmax,
            SEL.page_valid_from_count(new_cache.gcnt, p_pages))
        ids, sel_pages = SEL.topk_page_ids(q, meta, select_pages_k)
        gk_s, gv_s, gvalid = SEL.gather_pages(
            new_cache.gk, new_cache.gv, new_cache.gcnt, ids)
        k_all = jnp.concatenate([gk_s, new_cache.lk], axis=2)
        v_all = jnp.concatenate([gv_s, new_cache.lv], axis=2)
        lvalid = jnp.broadcast_to((new_cache.lpos >= 0)[:, None, :],
                                  new_cache.lg.shape)
        valid = jnp.concatenate([gvalid, lvalid], axis=2)
    else:
        k_all, v_all, valid = cache_kv_for_attention(new_cache)      # [B,H,C+W,*]
        if token_select_fn is not None:
            valid = valid & token_select_fn(new_cache, q)
    grp = hq // hkv
    qg = q.reshape(b, hkv, grp, hd)
    logits = jnp.einsum("bhgd,bhkd->bhgk", qg, k_all).astype(jnp.float32)
    logits = logits * (hd ** -0.5)
    logits = jnp.where(valid[:, :, None], logits, M.NEG_INF)
    wts = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bhgk,bhkd->bhgd", wts.astype(v_all.dtype), v_all)
    y = o.reshape(b, hq * hd) @ p["w_o"].astype(x_t.dtype)
    return y, new_cache, g_new, sel_pages


def attn_decode_dense(p: Params, cfg: ModelConfig, x_t: jax.Array,
                      cache: DenseCache, *, window: Optional[int] = None
                      ) -> Tuple[jax.Array, DenseCache]:
    """Full-attention baseline decode step. x_t: [B, D]."""
    b, _ = x_t.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    x = x_t[:, None, :]
    q = _heads(x @ p["w_q"].astype(x.dtype), hq, hd)[:, :, 0]
    k_pre = _heads(x @ p["w_k"].astype(x.dtype), hkv, hd)[:, :, 0]
    v_new = _heads(x @ p["w_v"].astype(x.dtype), hkv, hd)[:, :, 0]
    q, k_pre = _qk_norm(p, q[:, :, None], k_pre[:, :, None])
    q, k_pre = q[:, :, 0], k_pre[:, :, 0]
    q = _rope_single(cfg, q, cache.t)
    k_new = _rope_single(cfg, k_pre, cache.t)
    cache = dense_cache_append(cache, k_new, v_new)
    s = cache.k.shape[2]
    pos = jnp.arange(s)[None]                                       # [1, S]
    valid = pos < cache.t[:, None]
    if window is not None:
        valid = valid & (pos >= cache.t[:, None] - window)
    grp = hq // hkv
    qg = q.reshape(b, hkv, grp, hd)
    logits = jnp.einsum("bhgd,bhkd->bhgk", qg, cache.k).astype(jnp.float32)
    logits = logits * (hd ** -0.5)
    logits = jnp.where(valid[:, None, None], logits, M.NEG_INF)
    wts = jax.nn.softmax(logits, -1)
    o = jnp.einsum("bhgk,bhkd->bhgd", wts.astype(cache.v.dtype), cache.v)
    y = o.reshape(b, hq * hd) @ p["w_o"].astype(x_t.dtype)
    return y, cache


# ==========================================================================
# cross-attention (whisper decoder); optional admission on encoder memory
# ==========================================================================
class CrossCache(NamedTuple):
    k: jax.Array      # [B, Hkv, S_enc_or_budget, hd]
    v: jax.Array
    valid: jax.Array  # [B, Hkv, S]


def build_cross_cache(p: Params, cfg: ModelConfig, enc_out: jax.Array, *,
                      budget: Optional[int] = None) -> CrossCache:
    """Precompute cross-attn K/V from encoder output; when ``budget`` is
    given and the layer has a gate, admit only the top-budget encoder
    tokens (learned encoder-memory pruning — WG-KV on the cross stream)."""
    b, s, _ = enc_out.shape
    hkv, hd = cfg.n_kv_heads, cfg.head_dim
    k = _heads(enc_out @ p["w_k"].astype(enc_out.dtype), hkv, hd)
    v = _heads(enc_out @ p["w_v"].astype(enc_out.dtype), hkv, hd)
    if budget is not None and "gate" in p and budget < s:
        g = gate_scores(p["gate"], k, k)  # no RoPE on whisper cross keys
        sel = select_global(g, budget=budget, tau=cfg.wgkv.tau, sink=cfg.wgkv.sink)
        bi = jnp.arange(b)[:, None, None]
        hi = jnp.arange(hkv)[None, :, None]
        return CrossCache(k[bi, hi, sel.idx], v[bi, hi, sel.idx], sel.valid)
    return CrossCache(k, v, jnp.ones((b, hkv, s), bool))


def attn_cross(p: Params, cfg: ModelConfig, x: jax.Array, cc: CrossCache
               ) -> jax.Array:
    """x: [B, Sq, D] decoder stream attending to the (possibly budgeted)
    encoder memory."""
    b, sq, _ = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = _heads(x @ p["w_q"].astype(x.dtype), hq, hd)
    grp = hq // hkv
    qg = q.reshape(b, hkv, grp, sq, hd)
    logits = jnp.einsum("bhgqd,bhkd->bhgqk", qg, cc.k).astype(jnp.float32)
    logits = logits * (hd ** -0.5)
    logits = jnp.where(cc.valid[:, :, None, None], logits, M.NEG_INF)
    wts = jax.nn.softmax(logits, -1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", wts.astype(cc.v.dtype), cc.v)
    y = o.reshape(b, hq, sq, hd).transpose(0, 2, 1, 3).reshape(b, sq, hq * hd)
    return y @ p["w_o"].astype(x.dtype)


def attn_encoder(p: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """Bidirectional encoder self-attention (whisper)."""
    b, s, _ = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = _heads(x @ p["w_q"].astype(x.dtype), hq, hd)
    k = _heads(x @ p["w_k"].astype(x.dtype), hkv, hd)
    v = _heads(x @ p["w_v"].astype(x.dtype), hkv, hd)
    out = sdpa(q, k, v, lambda qs, ql: jnp.zeros((1, 1, 1, ql, s), jnp.float32))
    y = out.transpose(0, 2, 1, 3).reshape(b, s, hq * hd)
    return y @ p["w_o"].astype(x.dtype)
