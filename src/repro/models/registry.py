"""Model registry: config -> init/forward, analytic parameter counting,
and modality-frontend stubs (VLM patch embeddings, whisper frames)."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.gate import gate_param_count
from repro.models import inference, transformer

init_model = transformer.init_model
forward = transformer.forward
prefill = inference.prefill
decode_step = inference.decode_step
DecodeOptions = inference.DecodeOptions


# ==========================================================================
# analytic parameter counting (mirrors init_* exactly; verified by tests)
# ==========================================================================
def _block_params(cfg: ModelConfig, bt: str, active_only: bool) -> int:
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    norm = 2 * d if cfg.arch_type == "audio" else d  # layernorm has bias

    def attn_p() -> int:
        n = d * hq * hd + 2 * d * hkv * hd + hq * hd * d
        if cfg.qk_norm:
            n += 2 * hd
        if cfg.wgkv.enabled:
            n += gate_param_count(cfg)
        return n

    if bt in ("attn", "local_attn"):
        return 2 * norm + attn_p() + 3 * d * cfg.d_ff
    if bt == "attn_moe":
        mc = cfg.moe
        full = cfg.moe.n_experts * 3 * d * mc.expert_d_ff
        act = mc.top_k * 3 * d * mc.expert_d_ff
        return 2 * norm + attn_p() + d * mc.n_experts + (act if active_only else full)
    if bt == "attn_cross":
        mlp = 2 * d * cfg.d_ff + cfg.d_ff + d  # gelu mlp with biases
        return 3 * norm + 2 * attn_p() + mlp
    if bt == "enc_attn":
        base = d * hq * hd + 2 * d * hkv * hd + hq * hd * d  # no gate on enc
        mlp = 2 * d * cfg.d_ff + cfg.d_ff + d
        return 2 * norm + base + mlp
    if bt == "rglru":
        dr = int(cfg.rglru_expand * d)
        dh = dr // hq
        rec = (2 * d * dr + cfg.rglru_conv_width * dr
               + 2 * hq * dh * dh + 2 * dr + dr + dr * d)
        return 2 * norm + rec + 3 * d * cfg.d_ff
    if bt == "mlstm":
        dm = int(cfg.xlstm_proj_factor * d)
        return (d + 2 * d * dm + cfg.xlstm_conv_width * dm + 3 * dm * dm
                + 2 * (dm * hq + hq) + dm + dm * d)
    if bt == "slstm":
        dh = d // hq
        dff = int(d * 4 / 3 / 2) * 2
        return (d + d * 4 * d + 4 * hq * dh * dh + 4 * d + d
                + 2 * d * dff + dff * d)
    raise ValueError(bt)


def count_params_analytic(cfg: ModelConfig, active_only: bool = False) -> int:
    n = cfg.vocab_size * cfg.d_model
    if not cfg.tie_embeddings:
        n += cfg.d_model * cfg.vocab_size
    for bt in cfg.stem_pattern:
        n += _block_params(cfg, bt, active_only)
    for bt in cfg.block_pattern:
        n += cfg.n_repeats * _block_params(cfg, bt, active_only)
    for bt in cfg.enc_block_pattern:
        n += cfg.n_enc_repeats * _block_params(cfg, bt, active_only)
    n += 2 * cfg.d_model if cfg.arch_type == "audio" else cfg.d_model  # ln_f
    if cfg.is_encdec:
        n += 2 * cfg.d_model if cfg.arch_type == "audio" else cfg.d_model
    return n


def count_params_tree(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


def gate_params_tree(params) -> int:
    """Parameters belonging to Write-Gate MLPs (paper: ~0.4% of total)."""
    total = 0

    def walk(tree, in_gate=False):
        nonlocal total
        if isinstance(tree, dict):
            for k, v in tree.items():
                walk(v, in_gate or k == "gate")
        elif isinstance(tree, (tuple, list)):
            for v in tree:
                walk(v, in_gate)
        elif in_gate and hasattr(tree, "size"):
            total += tree.size

    walk(params)
    return total


# ==========================================================================
# modality-frontend stubs (the one sanctioned carve-out)
# ==========================================================================
def build_vlm_embeds(params, cfg: ModelConfig, tokens: jax.Array,
                     patch_embeds: jax.Array, grid_hw: Tuple[int, int]
                     ) -> Tuple[jax.Array, jax.Array]:
    """embeds [B,S,D] with image patches in the leading slots; positions3
    [3,B,S] with spatial (t,h,w) ids for the vision span and equal text ids
    after it (Qwen2-VL M-RoPE scheme)."""
    from repro.models import layers as L

    b, s = tokens.shape
    n_img = patch_embeds.shape[1]
    gh, gw = grid_hw
    assert gh * gw == n_img and n_img <= s
    dt = jnp.dtype(cfg.dtype)
    emb = L.embed(params["embed"], tokens, dt)
    emb = emb.at[:, :n_img].set(patch_embeds.astype(dt))
    # vision span: t=0, h=row, w=col; text: all three advance together
    rows = jnp.repeat(jnp.arange(gh), gw)
    cols = jnp.tile(jnp.arange(gw), gh)
    t_img = jnp.zeros((n_img,), jnp.int32)
    text_start = max(gh, gw)  # Qwen2-VL: text resumes at max spatial extent
    text_pos = jnp.arange(s - n_img, dtype=jnp.int32) + text_start
    pt = jnp.concatenate([t_img, text_pos])
    ph = jnp.concatenate([rows.astype(jnp.int32), text_pos])
    pw = jnp.concatenate([cols.astype(jnp.int32), text_pos])
    pos3 = jnp.stack([pt, ph, pw])  # [3, S]
    pos3 = jnp.broadcast_to(pos3[:, None], (3, b, s))
    return emb, pos3


def whisper_frame_embeds(key: jax.Array, cfg: ModelConfig, batch: int,
                         n_frames: int) -> jax.Array:
    """STUB for mel-spectrogram + conv feature extractor: random frame
    embeddings [B, n_frames // enc_seq_divisor, D] standing in for the conv
    stack's output (2x temporal downsample)."""
    s_enc = n_frames // cfg.enc_seq_divisor
    return jax.random.normal(key, (batch, s_enc, cfg.d_model),
                             jnp.dtype(cfg.dtype)) * 0.1
