"""xLSTM blocks (Beck et al., 2024): mLSTM (matrix memory, parallelizable)
and sLSTM (scalar memory, sequential exponential gating).

mLSTM training uses the parallel (attention-like) form with log-space
cumulative forget gates and a row-wise stabilizer; decode uses the O(1)
recurrent form (C, n, m state). sLSTM is inherently sequential
(``jax.lax.scan`` over time, block-diagonal recurrent weights per head);
its in-scan FLOPs are added analytically in roofline/analysis.py since XLA
cost analysis counts while-bodies once.

No KV cache exists in either block — WG-KV is inapplicable to this arch
(DESIGN.md §4); the framework runs it with its native O(1) state.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L

Params = Dict[str, jax.Array]


# ==========================================================================
# mLSTM
# ==========================================================================
class MLSTMState(NamedTuple):
    conv: jax.Array  # [B, cw-1, dm] trailing conv inputs
    c: jax.Array     # [B, H, dh, dh] matrix memory
    n: jax.Array     # [B, H, dh] normalizer
    m: jax.Array     # [B, H] stabilizer


def _mdims(cfg: ModelConfig) -> Tuple[int, int, int]:
    dm = int(cfg.xlstm_proj_factor * cfg.d_model)
    h = cfg.n_heads
    return dm, h, dm // h


def init_mlstm(key: jax.Array, cfg: ModelConfig) -> Params:
    dt = jnp.dtype(cfg.param_dtype)
    d = cfg.d_model
    dm, h, dh = _mdims(cfg)
    ks = jax.random.split(key, 9)
    return {
        "norm": L.init_rmsnorm(d, dt),
        "w_up_x": L.dense_init(ks[0], (d, dm), dt),
        "w_up_z": L.dense_init(ks[1], (d, dm), dt),
        "conv": (jax.random.normal(ks[2], (cfg.xlstm_conv_width, dm)) * 0.02).astype(dt),
        "w_q": L.dense_init(ks[3], (dm, dm), dt),
        "w_k": L.dense_init(ks[4], (dm, dm), dt),
        "w_v": L.dense_init(ks[5], (dm, dm), dt),
        "w_i": L.dense_init(ks[6], (dm, h), dt, scale=0.02),
        "b_i": jnp.zeros((h,), dt),
        "w_f": L.dense_init(ks[7], (dm, h), dt, scale=0.02),
        # positive forget bias => long memory at init
        "b_f": jnp.full((h,), 3.0, dt),
        "out_norm": L.init_rmsnorm(dm, dt),
        "w_down": L.dense_init(ks[8], (dm, d), dt),
    }


def _mlstm_proj(p, cfg, x, conv_state):
    """Shared projections. x: [B, S, D]."""
    dm, h, dh = _mdims(cfg)
    xm = x @ p["w_up_x"].astype(x.dtype)             # [B,S,dm]
    z = jax.nn.silu(x @ p["w_up_z"].astype(x.dtype))
    cw = p["conv"].shape[0]
    if conv_state is None:
        conv_state = jnp.zeros((x.shape[0], cw - 1, dm), x.dtype)
    xp = jnp.concatenate([conv_state.astype(x.dtype), xm], 1)
    xc = sum(xp[:, i:i + x.shape[1]] * p["conv"][i].astype(x.dtype) for i in range(cw))
    xc = jax.nn.silu(xc)
    heads = lambda y: y.reshape(y.shape[0], y.shape[1], h, dh).transpose(0, 2, 1, 3)
    q = heads(xc @ p["w_q"].astype(x.dtype))
    k = heads(xc @ p["w_k"].astype(x.dtype)) / (dh ** 0.5)
    v = heads(xm @ p["w_v"].astype(x.dtype))
    i_t = (xc @ p["w_i"].astype(x.dtype) + p["b_i"].astype(x.dtype))  # [B,S,H]
    f_t = (xc @ p["w_f"].astype(x.dtype) + p["b_f"].astype(x.dtype))
    return xm, z, q, k, v, i_t.astype(jnp.float32), f_t.astype(jnp.float32), xp[:, -(cw - 1):]


def mlstm_block(p: Params, cfg: ModelConfig, x: jax.Array,
                state: MLSTMState | None = None
                ) -> Tuple[jax.Array, MLSTMState]:
    """Parallel-form forward (single chunk of the chunkwise formulation —
    kept as the readable O(S^2) reference; ``mlstm_block_chunkwise`` is the
    production path for long sequences)."""
    if state is not None:
        # the single-chunk quadratic derivation below assumes a fresh
        # stream; delegate streaming continuation to the chunkwise form
        return mlstm_block_chunkwise(p, cfg, x, state, chunk=x.shape[1])
    xin = L.rmsnorm(p["norm"], x)
    conv_state = state.conv if state is not None else None
    xm, z, q, k, v, i_t, f_t, new_conv = _mlstm_proj(p, cfg, xin, conv_state)
    b, s, d = xin.shape
    dm, h, dh = _mdims(cfg)
    logf = jax.nn.log_sigmoid(f_t).transpose(0, 2, 1)    # [B,H,S]
    cum = jnp.cumsum(logf, axis=-1)
    i_bh = i_t.transpose(0, 2, 1)                        # [B,H,S]
    # log D_ij = i_j + cum_i - cum_j for j <= i
    ld = i_bh[:, :, None, :] + cum[:, :, :, None] - cum[:, :, None, :]
    causal = jnp.tril(jnp.ones((s, s), bool))
    ld = jnp.where(causal[None, None], ld, -jnp.inf)
    m_row = jnp.max(ld, axis=-1)                         # [B,H,S] stabilizer
    dmat = jnp.exp(ld - m_row[..., None])
    scores = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32))
    w = scores * dmat
    denom = jnp.maximum(jnp.abs(w.sum(-1)), jnp.exp(-m_row))  # [B,H,S]
    hsa = jnp.einsum("bhqk,bhkd->bhqd", w, v.astype(jnp.float32))
    hsa = hsa / denom[..., None]
    hsa = hsa.transpose(0, 2, 1, 3).reshape(b, s, dm).astype(x.dtype)
    out = L.rmsnorm(p["out_norm"], hsa) * z
    y = out @ p["w_down"].astype(x.dtype)
    # closed-form final recurrent state (for prefill -> decode handoff)
    m_fin = jnp.max(i_bh + cum[:, :, -1:] - cum, axis=-1)          # [B,H]
    wfin = jnp.exp(i_bh + cum[:, :, -1:] - cum - m_fin[..., None])  # [B,H,S]
    c_fin = jnp.einsum("bhs,bhsd,bhse->bhde", wfin, k.astype(jnp.float32),
                       v.astype(jnp.float32))
    n_fin = jnp.einsum("bhs,bhsd->bhd", wfin, k.astype(jnp.float32))
    if state is not None:
        # fold in pre-existing state (prefill continuing a stream)
        carry = jnp.exp(state.m + cum[:, :, -1] - m_fin)
        c_fin = c_fin + carry[..., None, None] * state.c
        n_fin = n_fin + carry[..., None] * state.n
    new_state = MLSTMState(conv=new_conv, c=c_fin, n=n_fin, m=m_fin)
    return x + y, new_state


def _chunk_combine(s1, s2):
    """Associative combine of stabilized (m, C, n, F) chunk states."""
    m1, c1, n1, f1 = s1
    m2, c2, n2, f2 = s2
    f = f1 + f2
    m = jnp.maximum(m1 + f2, m2)
    w1 = jnp.exp(m1 + f2 - m)
    w2 = jnp.exp(m2 - m)
    c = w1[..., None, None] * c1 + w2[..., None, None] * c2
    n = w1[..., None] * n1 + w2[..., None] * n2
    return m, c, n, f


def mlstm_block_chunkwise(p: Params, cfg: ModelConfig, x: jax.Array,
                          state: MLSTMState | None = None, *,
                          chunk: int = 512) -> Tuple[jax.Array, MLSTMState]:
    """Chunkwise-parallel mLSTM: O(S/L * (L^2 + L*dh)*dh) instead of O(S^2*dh),
    with the cross-chunk state recurrence evaluated by a log-depth
    ``associative_scan`` (TPU-native; no hidden while-loop, exact roofline
    accounting). Matches ``mlstm_block`` semantics exactly."""
    xin = L.rmsnorm(p["norm"], x)
    conv_state = state.conv if state is not None else None
    xm, z, q, k, v, i_t, f_t, new_conv = _mlstm_proj(p, cfg, xin, conv_state)
    b, s, d = xin.shape
    dm, h, dh = _mdims(cfg)
    nl = chunk
    assert s % nl == 0, (s, nl)
    nc = s // nl
    logf = jax.nn.log_sigmoid(f_t).transpose(0, 2, 1).reshape(b, h, nc, nl)
    i_bh = i_t.transpose(0, 2, 1).reshape(b, h, nc, nl)
    qc = q.reshape(b, h, nc, nl, dh).astype(jnp.float32)
    kc = k.reshape(b, h, nc, nl, dh).astype(jnp.float32)
    vc = v.reshape(b, h, nc, nl, dh).astype(jnp.float32)
    bcum = jnp.cumsum(logf, axis=-1)            # [B,H,nc,L] inclusive
    f_tot = bcum[..., -1]                       # [B,H,nc]
    # per-chunk stabilized state contribution
    m_loc = jnp.max(i_bh + f_tot[..., None] - bcum, axis=-1)          # [B,H,nc]
    w_loc = jnp.exp(i_bh + f_tot[..., None] - bcum - m_loc[..., None])  # [B,H,nc,L]
    c_loc = jnp.einsum("bhcl,bhcld,bhcle->bhcde", w_loc, kc, vc)
    n_loc = jnp.einsum("bhcl,bhcld->bhcd", w_loc, kc)
    # prefix (exclusive) states across chunks
    m_in, c_in, n_in, f_in = jax.lax.associative_scan(
        _chunk_combine, (m_loc, c_loc, n_loc, f_tot), axis=2)
    shift = lambda a, fill: jnp.concatenate(
        [jnp.full_like(a[:, :, :1], fill), a[:, :, :-1]], axis=2)
    m_prev = shift(m_in, -1e30)
    c_prev = shift(c_in, 0.0)
    n_prev = shift(n_in, 0.0)
    if state is not None:
        # fold the incoming stream state into every prefix
        m0 = state.m[:, :, None]
        mm = jnp.maximum(m0 + jnp.concatenate(
            [jnp.zeros_like(f_in[:, :, :1]),
             jnp.cumsum(f_tot, 2)[:, :, :-1]], 2), m_prev)
        w0 = jnp.exp(m0 + jnp.concatenate(
            [jnp.zeros_like(f_in[:, :, :1]),
             jnp.cumsum(f_tot, 2)[:, :, :-1]], 2) - mm)
        wp = jnp.exp(m_prev - mm)
        c_prev = w0[..., None, None] * state.c[:, :, None] + wp[..., None, None] * c_prev
        n_prev = w0[..., None] * state.n[:, :, None] + wp[..., None] * n_prev
        m_prev = mm
    # per-token stabilizers and outputs
    intra_log = (i_bh[:, :, :, None, :] + bcum[..., :, None] - bcum[..., None, :])
    causal = jnp.tril(jnp.ones((nl, nl), bool))
    intra_log = jnp.where(causal[None, None, None], intra_log, -jnp.inf)
    m_intra = jnp.max(intra_log, axis=-1)                      # [B,H,nc,L]
    m_tot = jnp.maximum(m_prev[..., None] + bcum, m_intra)     # [B,H,nc,L]
    w_intra = jnp.exp(intra_log - m_tot[..., None])            # [B,H,nc,L,L]
    w_inter = jnp.exp(m_prev[..., None] + bcum - m_tot)        # [B,H,nc,L]
    scores = jnp.einsum("bhcld,bhcmd->bhclm", qc, kc)          # [B,H,nc,L,L]
    num = (jnp.einsum("bhclm,bhclm,bhcme->bhcle", scores, w_intra, vc)
           + w_inter[..., None] * jnp.einsum("bhcld,bhcde->bhcle", qc, c_prev))
    den = (jnp.einsum("bhclm,bhclm->bhcl", scores, w_intra)
           + w_inter * jnp.einsum("bhcld,bhcd->bhcl", qc, n_prev))
    den = jnp.maximum(jnp.abs(den), jnp.exp(-m_tot))
    hsa = (num / den[..., None]).reshape(b, h, s, dh)
    hsa = hsa.transpose(0, 2, 1, 3).reshape(b, s, dm).astype(x.dtype)
    out = L.rmsnorm(p["out_norm"], hsa) * z
    y = out @ p["w_down"].astype(x.dtype)
    # final stream state = last inclusive prefix (+ incoming state)
    m_fin, c_fin, n_fin = m_in[:, :, -1], c_in[:, :, -1], n_in[:, :, -1]
    if state is not None:
        ftot_all = jnp.sum(f_tot, axis=2)
        mm = jnp.maximum(state.m + ftot_all, m_fin)
        w0 = jnp.exp(state.m + ftot_all - mm)
        wp = jnp.exp(m_fin - mm)
        c_fin = w0[..., None, None] * state.c + wp[..., None, None] * c_fin
        n_fin = w0[..., None] * state.n + wp[..., None] * n_fin
        m_fin = mm
    return x + y, MLSTMState(conv=new_conv, c=c_fin, n=n_fin, m=m_fin)


def mlstm_auto(p: Params, cfg: ModelConfig, x: jax.Array,
               state: MLSTMState | None = None
               ) -> Tuple[jax.Array, MLSTMState]:
    """Dispatch: quadratic parallel form for short sequences, chunkwise
    (chunk=512) for long ones — keeps the materialized [.., L, L] tile
    VMEM/HBM-friendly at 32k-500k tokens."""
    s = x.shape[1]
    if s > 1024 and s % 512 == 0:
        return mlstm_block_chunkwise(p, cfg, x, state, chunk=512)
    return mlstm_block(p, cfg, x, state)


def mlstm_step(p: Params, cfg: ModelConfig, x_t: jax.Array,
               state: MLSTMState) -> Tuple[jax.Array, MLSTMState]:
    """O(1) recurrent decode step. x_t: [B, D]."""
    xin = L.rmsnorm(p["norm"], x_t)[:, None]             # [B,1,D]
    dm, h, dh = _mdims(cfg)
    xm = xin @ p["w_up_x"].astype(xin.dtype)
    z = jax.nn.silu(xin @ p["w_up_z"].astype(xin.dtype))
    window = jnp.concatenate([state.conv.astype(xm.dtype), xm], 1)  # [B,cw,dm]
    xc = jax.nn.silu(jnp.einsum("bcd,cd->bd", window, p["conv"].astype(xm.dtype)))[:, None]
    heads = lambda y: y.reshape(y.shape[0], h, dh)
    q = heads(xc[:, 0] @ p["w_q"].astype(xc.dtype)).astype(jnp.float32)
    k = heads(xc[:, 0] @ p["w_k"].astype(xc.dtype)).astype(jnp.float32) / (dh ** 0.5)
    v = heads(xm[:, 0] @ p["w_v"].astype(xm.dtype)).astype(jnp.float32)
    i_t = (xc[:, 0] @ p["w_i"].astype(xc.dtype) + p["b_i"].astype(xc.dtype)).astype(jnp.float32)
    f_t = (xc[:, 0] @ p["w_f"].astype(xc.dtype) + p["b_f"].astype(xc.dtype)).astype(jnp.float32)
    logf = jax.nn.log_sigmoid(f_t)                        # [B,H]
    m_new = jnp.maximum(logf + state.m, i_t)
    fprime = jnp.exp(logf + state.m - m_new)
    iprime = jnp.exp(i_t - m_new)
    c_new = fprime[..., None, None] * state.c + iprime[..., None, None] * (
        k[..., :, None] * v[..., None, :])
    n_new = fprime[..., None] * state.n + iprime[..., None] * k
    num = jnp.einsum("bhde,bhd->bhe", c_new, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n_new, q)),
                      jnp.exp(-m_new))
    hsa = (num / den[..., None]).reshape(x_t.shape[0], dm).astype(x_t.dtype)
    out = L.rmsnorm(p["out_norm"], hsa) * z[:, 0]
    y = out @ p["w_down"].astype(x_t.dtype)
    return x_t + y, MLSTMState(conv=window[:, 1:], c=c_new, n=n_new, m=m_new)


def init_mlstm_state(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> MLSTMState:
    dm, h, dh = _mdims(cfg)
    return MLSTMState(
        conv=jnp.zeros((batch, cfg.xlstm_conv_width - 1, dm), dtype),
        c=jnp.zeros((batch, h, dh, dh), jnp.float32),
        n=jnp.zeros((batch, h, dh), jnp.float32),
        m=jnp.full((batch, h), -1e30, jnp.float32),
    )


# ==========================================================================
# sLSTM
# ==========================================================================
class SLSTMState(NamedTuple):
    c: jax.Array  # [B, D]
    n: jax.Array  # [B, D]
    h: jax.Array  # [B, D]
    m: jax.Array  # [B, D]


def init_slstm(key: jax.Array, cfg: ModelConfig) -> Params:
    dt = jnp.dtype(cfg.param_dtype)
    d = cfg.d_model
    h = cfg.n_heads
    dh = d // h
    ks = jax.random.split(key, 7)
    dff = int(d * 4 / 3 / 2) * 2  # post-cell gated MLP (xLSTM: pf 4/3)
    return {
        "norm": L.init_rmsnorm(d, dt),
        "w_in": L.dense_init(ks[0], (d, 4 * d), dt),   # z, i, f, o pre-acts
        "r": L.dense_init(ks[1], (4, h, dh, dh), dt, scale=(dh ** -0.5)),
        "b": jnp.concatenate([jnp.zeros((d,)), jnp.zeros((d,)),
                              jnp.full((d,), 3.0), jnp.zeros((d,))]).astype(dt),
        "out_norm": L.init_rmsnorm(d, dt),
        "w_up1": L.dense_init(ks[2], (d, dff), dt),
        "w_up2": L.dense_init(ks[3], (d, dff), dt),
        "w_down": L.dense_init(ks[4], (dff, d), dt),
    }


def _slstm_cell(p, cfg, pre, state: SLSTMState):
    """pre: [B, 4D] input pre-activations (W x + b). One time step."""
    d = cfg.d_model
    h = cfg.n_heads
    dh = d // h
    hp = state.h.reshape(-1, h, dh)
    rec = jnp.einsum("bhd,ghde->gbhe", hp.astype(p["r"].dtype), p["r"])
    rec = rec.reshape(4, -1, d).astype(jnp.float32)
    z_t, i_t, f_t, o_t = jnp.split(pre.astype(jnp.float32), 4, axis=-1)
    z_t = jnp.tanh(z_t + rec[0])
    i_t = i_t + rec[1]
    f_t = jax.nn.log_sigmoid(f_t + rec[2])
    o_t = jax.nn.sigmoid(o_t + rec[3])
    m_new = jnp.maximum(f_t + state.m, i_t)
    ip = jnp.exp(i_t - m_new)
    fp = jnp.exp(f_t + state.m - m_new)
    c_new = fp * state.c + ip * z_t
    n_new = jnp.maximum(fp * state.n + ip, 1e-6)
    h_new = o_t * c_new / n_new
    return SLSTMState(c=c_new, n=n_new, h=h_new, m=m_new)


def slstm_block(p: Params, cfg: ModelConfig, x: jax.Array,
                state: SLSTMState | None = None
                ) -> Tuple[jax.Array, SLSTMState]:
    """Sequential forward over time. x: [B, S, D]."""
    b, s, d = x.shape
    if state is None:
        state = init_slstm_state(cfg, b)
    xin = L.rmsnorm(p["norm"], x)
    pre = xin @ p["w_in"].astype(x.dtype) + p["b"].astype(x.dtype)  # [B,S,4D]

    def step(st, pre_t):
        st = _slstm_cell(p, cfg, pre_t, st)
        return st, st.h

    final, hs = jax.lax.scan(step, state, pre.transpose(1, 0, 2))
    hs = hs.transpose(1, 0, 2).astype(x.dtype)                      # [B,S,D]
    out = L.rmsnorm(p["out_norm"], hs)
    y = (jax.nn.gelu(out @ p["w_up1"].astype(x.dtype))
         * (out @ p["w_up2"].astype(x.dtype))) @ p["w_down"].astype(x.dtype)
    return x + y, final


def slstm_step(p: Params, cfg: ModelConfig, x_t: jax.Array,
               state: SLSTMState) -> Tuple[jax.Array, SLSTMState]:
    xin = L.rmsnorm(p["norm"], x_t)
    pre = xin @ p["w_in"].astype(x_t.dtype) + p["b"].astype(x_t.dtype)
    st = _slstm_cell(p, cfg, pre, state)
    out = L.rmsnorm(p["out_norm"], st.h.astype(x_t.dtype))
    y = (jax.nn.gelu(out @ p["w_up1"].astype(x_t.dtype))
         * (out @ p["w_up2"].astype(x_t.dtype))) @ p["w_down"].astype(x_t.dtype)
    return x_t + y, st


def init_slstm_state(cfg: ModelConfig, batch: int) -> SLSTMState:
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return SLSTMState(c=z, n=z + 1e-6, h=z, m=z - 1e30)
