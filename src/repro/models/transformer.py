"""Generic pattern-super-block decoder (and enc-dec) assembly.

A model = embed -> [stem blocks] -> scan over ``n_repeats`` copies of the
``block_pattern`` super-block (stacked params, MaxText-style) -> final norm
-> unembed. Heterogeneous patterns (hybrid/ssm) put several block types in
one super-block, so the scan body stays uniform.

Three execution families:
  forward(...)      — full-sequence training/teacher/eval forward
  prefill(...)      — inference prefill; returns logits + per-layer caches
  decode_step(...)  — one-token step updating caches

Caches mirror the param tree: {"stem": (cache,...), "blocks": stacked}.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as MoE
from repro.models import rglru as RG
from repro.models import xlstm as XL
from repro.sharding.rules import constrain_tokens

Params = Dict[str, Any]


def _norm_init(cfg: ModelConfig, dt):
    if cfg.arch_type == "audio":
        return L.init_layernorm(cfg.d_model, dt)
    return L.init_rmsnorm(cfg.d_model, dt)


def _norm(cfg: ModelConfig, p, x):
    if cfg.arch_type == "audio":
        return L.layernorm(p, x)
    return L.rmsnorm(p, x)


# ==========================================================================
# block init
# ==========================================================================
def init_block(key: jax.Array, cfg: ModelConfig, bt: str) -> Params:
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    if bt in ("attn", "local_attn", "attn_moe"):
        p = {
            "ln1": _norm_init(cfg, dt),
            "attn": A.init_attention(ks[0], cfg, kind="self"),
            "ln2": _norm_init(cfg, dt),
        }
        if bt == "attn_moe":
            p["moe"] = MoE.init_moe(ks[1], cfg)
        else:
            p["mlp"] = L.init_swiglu(ks[1], cfg.d_model, cfg.d_ff, dt)
        return p
    if bt == "attn_cross":
        return {
            "ln1": _norm_init(cfg, dt),
            "attn": A.init_attention(ks[0], cfg, kind="self"),
            "ln_x": _norm_init(cfg, dt),
            "xattn": A.init_attention(ks[1], cfg, kind="cross",
                                      with_gate=cfg.wgkv.enabled),
            "ln2": _norm_init(cfg, dt),
            "mlp": L.init_gelu_mlp(ks[2], cfg.d_model, cfg.d_ff, dt),
        }
    if bt == "enc_attn":
        return {
            "ln1": _norm_init(cfg, dt),
            "attn": A.init_attention(ks[0], cfg, kind="enc"),
            "ln2": _norm_init(cfg, dt),
            "mlp": L.init_gelu_mlp(ks[1], cfg.d_model, cfg.d_ff, dt),
        }
    if bt == "rglru":
        return {
            "ln1": _norm_init(cfg, dt),
            "rec": RG.init_rglru(ks[0], cfg),
            "ln2": _norm_init(cfg, dt),
            "mlp": L.init_swiglu(ks[1], cfg.d_model, cfg.d_ff, dt),
        }
    if bt == "mlstm":
        return {"cell": XL.init_mlstm(ks[0], cfg)}
    if bt == "slstm":
        return {"cell": XL.init_slstm(ks[0], cfg)}
    raise ValueError(f"unknown block type {bt!r}")


def init_superblock(key: jax.Array, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, len(cfg.block_pattern))
    return {f"b{i}": init_block(ks[i], cfg, bt)
            for i, bt in enumerate(cfg.block_pattern)}


def init_model(key: jax.Array, cfg: ModelConfig) -> Params:
    dt = jnp.dtype(cfg.param_dtype)
    ke, kb, ks, kenc = jax.random.split(key, 4)
    params: Params = {"embed": L.init_embedding(ke, cfg)}
    if cfg.stem_pattern:
        kst = jax.random.split(ks, len(cfg.stem_pattern))
        params["stem"] = tuple(
            init_block(kst[i], cfg, bt) for i, bt in enumerate(cfg.stem_pattern)
        )
    params["blocks"] = jax.vmap(lambda k: init_superblock(k, cfg))(
        jax.random.split(kb, cfg.n_repeats))
    params["ln_f"] = _norm_init(cfg, dt)
    if cfg.is_encdec:
        kencb, kencn = jax.random.split(kenc)
        params["enc"] = {
            "blocks": jax.vmap(lambda k: {
                f"b{i}": init_block(jax.random.fold_in(k, i), cfg, bt)
                for i, bt in enumerate(cfg.enc_block_pattern)
            })(jax.random.split(kencb, cfg.n_enc_repeats)),
            "ln_f": _norm_init(cfg, dt),
        }
    return params


# ==========================================================================
# full-sequence block forward (train / teacher / hard-eval)
# ==========================================================================
class BlockAux(NamedTuple):
    gates: Optional[jax.Array]  # [n_attn_in_block(=1), B, Hkv, S] or None
    lb_loss: jax.Array


def block_forward(p: Params, cfg: ModelConfig, bt: str, x: jax.Array,
                  positions: jax.Array, *, mode: str,
                  enc_out: Optional[jax.Array] = None,
                  moe_groups: int = 1, q_chunk: Optional[int] = None,
                  gate_override: Optional[jax.Array] = None
                  ) -> Tuple[jax.Array, BlockAux]:
    """mode: "teacher" | "gated" | "hard". ``gate_override``: [B, Hkv, S]
    static admission scores replacing the learned gate (Local-Attention /
    DuoAttention baselines re-contextualized as admission policies)."""
    gate_mode = {"teacher": "off", "gated": "gated", "hard": "hard"}[mode]
    zero = jnp.zeros((), jnp.float32)
    if bt in ("attn", "attn_moe", "local_attn", "attn_cross"):
        window = cfg.sliding_window if bt == "local_attn" else None
        h, g = A.attn_train(p["attn"], cfg, _norm(cfg, p["ln1"], x), positions,
                            gate_mode=gate_mode, window=window, q_chunk=q_chunk,
                            gate_override=gate_override)
        x = x + h
        if bt == "attn_cross":
            cc = A.build_cross_cache(p["xattn"], cfg, enc_out)
            x = x + A.attn_cross(p["xattn"], cfg, _norm(cfg, p["ln_x"], x), cc)
        lb = zero
        if bt == "attn_moe":
            y, aux = MoE.moe_ffn(p["moe"], cfg, _norm(cfg, p["ln2"], x),
                                 groups=moe_groups)
            x = x + y
            lb = aux["lb_loss"]
        elif cfg.arch_type == "audio":
            x = x + L.gelu_mlp(p["mlp"], _norm(cfg, p["ln2"], x))
        else:
            x = x + L.swiglu(p["mlp"], _norm(cfg, p["ln2"], x))
        gates = None if g is None else g[None]
        return x, BlockAux(gates, lb)
    if bt == "enc_attn":
        x = x + A.attn_encoder(p["attn"], cfg, _norm(cfg, p["ln1"], x))
        x = x + L.gelu_mlp(p["mlp"], _norm(cfg, p["ln2"], x))
        return x, BlockAux(None, zero)
    if bt == "rglru":
        y, _ = RG.rglru_block(p["rec"], cfg, _norm(cfg, p["ln1"], x))
        x = x + y
        x = x + L.swiglu(p["mlp"], _norm(cfg, p["ln2"], x))
        return x, BlockAux(None, zero)
    if bt == "mlstm":
        x, _ = XL.mlstm_auto(p["cell"], cfg, x)
        return x, BlockAux(None, zero)
    if bt == "slstm":
        x, _ = XL.slstm_block(p["cell"], cfg, x)
        return x, BlockAux(None, zero)
    raise ValueError(bt)


def _encode(params: Params, cfg: ModelConfig, enc_embeds: jax.Array) -> jax.Array:
    """Whisper encoder over precomputed (stub) frame embeddings."""
    s = enc_embeds.shape[1]
    x = enc_embeds + L.sinusoidal_positions(s, cfg.d_model)[None].astype(enc_embeds.dtype)

    def body(xc, bp):
        for i, bt in enumerate(cfg.enc_block_pattern):
            xc, _ = block_forward(bp[f"b{i}"], cfg, bt, xc,
                                  jnp.zeros((1, 1), jnp.int32), mode="teacher")
        return xc, None

    x, _ = jax.lax.scan(body, x, params["enc"]["blocks"])
    return _norm(cfg, params["enc"]["ln_f"], x)


class ForwardResult(NamedTuple):
    logits: jax.Array
    hidden: jax.Array                 # final-layer hidden states [B, S, D]
    gates: Optional[jax.Array]        # [L_attn, B, Hkv, S]
    lb_loss: jax.Array


def forward(params: Params, cfg: ModelConfig, tokens: Optional[jax.Array] = None,
            *, positions: Optional[jax.Array] = None, mode: str = "teacher",
            embeds: Optional[jax.Array] = None,
            enc_embeds: Optional[jax.Array] = None,
            moe_groups: int = 1, q_chunk: Optional[int] = None,
            with_logits: bool = True, remat: bool = False,
            scan_unroll: bool = False,
            gate_override: Optional[jax.Array] = None) -> ForwardResult:
    """Full-sequence forward.

    tokens: [B, S] int32 (or ``embeds`` [B, S, D] for VLM vision streams).
    positions: [B, S] or [3, B, S] (M-RoPE). enc_embeds: [B, S_enc, D]
    for enc-dec archs (whisper frame embeddings, conv-frontend stub).
    gate_override: [L_attn, B, Hkv, S] (per attn layer) or [B, Hkv, S]
    (broadcast) static admission scores for baseline policies.
    """
    dt = jnp.dtype(cfg.dtype)
    if embeds is None:
        x = L.embed(params["embed"], tokens, dt)
    else:
        x = embeds.astype(dt)
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    enc_out = None
    if cfg.is_encdec:
        assert enc_embeds is not None, "enc-dec arch needs enc_embeds"
        enc_out = _encode(params, cfg, enc_embeds.astype(dt))
        pos_emb = L.sinusoidal_positions(s, cfg.d_model).astype(dt)
        x = x + pos_emb[None]

    fwd = functools.partial(block_forward, cfg=cfg, mode=mode, enc_out=enc_out,
                            moe_groups=moe_groups, q_chunk=q_chunk)
    n_attn_pb = cfg.attn_blocks_per_pattern
    go_stem, go_blocks = None, None
    if gate_override is not None:
        if gate_override.ndim == 3:  # broadcast one policy to all layers
            n_stem = sum(1 for t in cfg.stem_pattern
                         if t in ("attn", "attn_moe", "local_attn", "attn_cross"))
            go_stem = [gate_override] * n_stem
            go_blocks = jnp.broadcast_to(
                gate_override[None, None],
                (cfg.n_repeats, n_attn_pb) + gate_override.shape)
        else:  # [L_attn, B, H, S]: stem layers first, then scanned stack
            n_stem = sum(1 for t in cfg.stem_pattern
                         if t in ("attn", "attn_moe", "local_attn", "attn_cross"))
            go_stem = [gate_override[i] for i in range(n_stem)]
            go_blocks = gate_override[n_stem:].reshape(
                (cfg.n_repeats, n_attn_pb) + gate_override.shape[1:])
    stem_gates = []
    lb_total = jnp.zeros((), jnp.float32)
    si = 0
    for i, bt in enumerate(cfg.stem_pattern):
        ov = None
        if go_stem is not None and bt in ("attn", "attn_moe", "local_attn",
                                          "attn_cross"):
            ov = go_stem[si]
            si += 1
        x, aux = fwd(params["stem"][i], bt=bt, x=x, positions=positions,
                     gate_override=ov)
        if aux.gates is not None:
            stem_gates.append(aux.gates)
        lb_total = lb_total + aux.lb_loss

    x = constrain_tokens(x)

    def body(carry, xs):
        bp = xs[0] if go_blocks is not None else xs
        ov_blk = xs[1] if go_blocks is not None else None
        xc, lb = carry
        xc = constrain_tokens(xc)
        gs = []
        ai = 0
        for i, bt in enumerate(cfg.block_pattern):
            ov = None
            if ov_blk is not None and bt in ("attn", "attn_moe", "local_attn",
                                             "attn_cross"):
                ov = ov_blk[ai]
                ai += 1
            xc, aux = fwd(bp[f"b{i}"], bt=bt, x=xc, positions=positions,
                          gate_override=ov)
            if aux.gates is not None:
                gs.append(aux.gates)
            lb = lb + aux.lb_loss
        g = jnp.concatenate(gs, 0) if gs else jnp.zeros((0, b, cfg.n_kv_heads, s))
        return (constrain_tokens(xc), lb), g

    if remat:
        body = jax.checkpoint(body)
    xs = (params["blocks"], go_blocks) if go_blocks is not None \
        else params["blocks"]
    (x, lb_total), gstack = jax.lax.scan(body, (x, lb_total), xs,
                                         unroll=scan_unroll)
    # gstack: [n_repeats, n_attn_pb, B, H, S] -> [L_attn, B, H, S]
    gates = None
    if mode != "teacher" and cfg.wgkv.enabled:
        parts = list(stem_gates)
        if gstack.shape[1] > 0:
            parts.append(gstack.reshape((-1,) + gstack.shape[2:]))
        gates = jnp.concatenate(parts, 0) if parts else None
    hidden = _norm(cfg, params["ln_f"], x)
    logits = L.unembed(params["embed"], hidden) if with_logits else jnp.zeros(())
    return ForwardResult(logits, hidden, gates, lb_total)
