"""Inference paths: prefill (populate caches) and decode_step (one token).

Cache tree mirrors the parameter tree:
  {"t": [B] int32, "stem": (block_cache, ...), "blocks": <stacked>,
   "obs": <stacked ObsWindow> (only when eviction is enabled)}

Per-block caches:
  attn / attn_moe / local_attn — DualCache (WG-KV) or DenseCache (baseline)
  attn_cross                  — {"self": DualCache|DenseCache, "cross": CrossCache}
  rglru                       — RGLRUState;  mlstm/slstm — their states

Composability (paper §5.4): ``DecodeOptions.quest_pages`` applies Quest
read-time selection over the (global) cache as a MASK (full-width einsum,
accuracy studies); ``DecodeOptions.selection_policy = "quest:K"`` applies
it as a GATHER (top-K pages materialized, decode FLOPs scale with K — the
serving path); ``evict_hard_budget`` applies SnapKV-style eviction when a
head's global count hits the bound.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import baselines as BL
from repro.core import eviction as EV
from repro.core import selection as SEL
from repro.core.dual_cache import DualCache, init_dual_cache, prefill_populate
from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as MoE
from repro.models import rglru as RG
from repro.models import xlstm as XL
from repro.models.transformer import _encode, _norm
from repro.sharding.rules import constrain_tokens

Params = Dict[str, Any]
CacheTree = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class DecodeOptions:
    quest_pages: Optional[int] = None      # read-time Selection budget (pages, MASK mode)
    # gathered read-time Selection: None | "quest:K" (top-K pages GATHERED
    # into the decode einsum — cost scales with K; parse_selection_policy)
    selection_policy: Optional[str] = None
    evict_hard_budget: Optional[int] = None  # post-write Eviction bound (tokens/head)
    evict_frac: float = 0.10
    w_obs: int = 256
    # static admission override (core/baselines.py): replaces the learned
    # write-gate with a position-only policy — "streaming_llm" (sinks only)
    # or "duo" (retrieval heads admit all; streaming heads sinks only).
    # None = learned gate. Fields stay hashable (tuple) for jit partials.
    admission_policy: Optional[str] = None
    admission_sink: int = 16
    duo_retrieval_heads: Tuple[int, ...] = ()


def parse_selection_policy(policy: Optional[str]) -> Optional[int]:
    """"quest:K" -> K (page budget); None -> None."""
    if policy is None:
        return None
    kind, _, arg = policy.partition(":")
    if kind != "quest" or not arg.isdigit() or int(arg) < 1:
        raise ValueError(
            f"unknown selection policy {policy!r} (expected 'quest:K')")
    return int(arg)


def _static_gates(cfg: ModelConfig, opts: DecodeOptions,
                  positions: jax.Array) -> Optional[jax.Array]:
    """Static admission gates at ``positions`` ([B] decode / [B, S] prefill);
    None when the learned gate is in effect."""
    if opts.admission_policy is None:
        return None
    pos = positions if positions.ndim <= 2 else positions[0]  # M-RoPE stack
    return BL.gates_from_positions(
        opts.admission_policy, pos, cfg.n_kv_heads,
        sink=opts.admission_sink, retrieval_heads=opts.duo_retrieval_heads)


class PrefillOut(NamedTuple):
    logits: jax.Array          # [B, V] for the last position
    hidden: jax.Array          # [B, S, D]
    mean_admission: jax.Array  # scalar: fraction of tokens with g >= tau


# ==========================================================================
# per-block prefill
# ==========================================================================
def _attn_block_prefill(p, cfg: ModelConfig, bt: str, x, positions, *,
                        use_wgkv: bool, budget: int, max_len: int,
                        block_chunk, q_chunk, enc_out, moe_groups,
                        opts: DecodeOptions = None, gate_override=None):
    window = cfg.sliding_window if bt == "local_attn" else None
    xin = _norm(cfg, p["ln1"], x)
    b, s, _ = x.shape
    dt = jnp.dtype(cfg.dtype)
    adm = jnp.zeros((), jnp.float32)
    if use_wgkv:
        if gate_override is None and opts is not None:
            gate_override = _static_gates(cfg, opts, positions)
        w_ring = window if window is not None else cfg.wgkv.w_local
        r = A.attn_prefill_budgeted(
            p["attn"], cfg, xin, positions, budget=budget, window=window,
            block_chunk=block_chunk, gate_override=gate_override)
        cache = init_dual_cache(b, cfg.n_kv_heads, cfg.head_dim,
                                w_local=w_ring, budget=budget, dtype=dt)
        cache = prefill_populate(cache, r.k_rope, r.v, r.g,
                                 tau=cfg.wgkv.tau, sink=cfg.wgkv.sink)
        h = r.out
        adm = (r.g >= cfg.wgkv.tau).mean()
    else:
        h, k_rope, v = A.attn_prefill_full(p["attn"], cfg, xin, positions,
                                           window=window, q_chunk=q_chunk)
        cache = A.init_dense_cache(b, cfg.n_kv_heads, cfg.head_dim, max_len, dt)
        cache = cache._replace(
            k=cache.k.at[:, :, :s].set(k_rope.astype(dt)),
            v=cache.v.at[:, :, :s].set(v.astype(dt)),
            t=jnp.full((b,), s, jnp.int32),
        )
    x = x + h
    if bt == "attn_cross":
        xbudget = budget if use_wgkv else None
        cc = A.build_cross_cache(p["xattn"], cfg, enc_out, budget=xbudget)
        x = x + A.attn_cross(p["xattn"], cfg, _norm(cfg, p["ln_x"], x), cc)
        cache = {"self": cache, "cross": cc}
    if bt == "attn_moe":
        y, _ = MoE.moe_ffn(p["moe"], cfg, _norm(cfg, p["ln2"], x), groups=moe_groups)
        x = x + y
    elif bt == "attn_cross" or cfg.arch_type == "audio":
        x = x + L.gelu_mlp(p["mlp"], _norm(cfg, p["ln2"], x))
    else:
        x = x + L.swiglu(p["mlp"], _norm(cfg, p["ln2"], x))
    return x, cache, adm


def _block_prefill(p, cfg: ModelConfig, bt: str, x, positions, **kw):
    if bt in ("attn", "attn_moe", "local_attn", "attn_cross"):
        return _attn_block_prefill(p, cfg, bt, x, positions, **kw)
    zero = jnp.zeros((), jnp.float32)
    if bt == "rglru":
        y, state = RG.rglru_block(p["rec"], cfg, _norm(cfg, p["ln1"], x))
        x = x + y
        x = x + L.swiglu(p["mlp"], _norm(cfg, p["ln2"], x))
        return x, state, zero
    if bt == "mlstm":
        x, state = XL.mlstm_auto(p["cell"], cfg, x)
        return x, state, zero
    if bt == "slstm":
        x, state = XL.slstm_block(p["cell"], cfg, x)
        return x, state, zero
    raise ValueError(bt)


def prefill(params: Params, cfg: ModelConfig, tokens: Optional[jax.Array] = None,
            *, positions: Optional[jax.Array] = None,
            embeds: Optional[jax.Array] = None,
            enc_embeds: Optional[jax.Array] = None,
            use_wgkv: Optional[bool] = None, budget: Optional[int] = None,
            max_len: Optional[int] = None, moe_groups: int = 1,
            block_chunk: Optional[int] = None, q_chunk: Optional[int] = None,
            opts: DecodeOptions = DecodeOptions(), scan_unroll: bool = False,
            ) -> Tuple[PrefillOut, CacheTree]:
    dt = jnp.dtype(cfg.dtype)
    if use_wgkv is None:
        use_wgkv = cfg.wgkv.enabled
    x = L.embed(params["embed"], tokens, dt) if embeds is None else embeds.astype(dt)
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    if budget is None:
        budget = cfg.wgkv.global_budget(max_len or s)
    if max_len is None:
        max_len = s + 64
    enc_out = None
    if cfg.is_encdec:
        enc_out = _encode(params, cfg, enc_embeds.astype(dt))
        x = x + L.sinusoidal_positions(s, cfg.d_model)[None].astype(dt)

    pf = functools.partial(
        _block_prefill, cfg=cfg, use_wgkv=use_wgkv, budget=budget,
        max_len=max_len, block_chunk=block_chunk, q_chunk=q_chunk,
        enc_out=enc_out, moe_groups=moe_groups, opts=opts)

    caches: CacheTree = {"t": jnp.full((b,), s, jnp.int32)}
    adm_sum, adm_n = jnp.zeros(()), 0
    stem_caches = []
    for i, bt in enumerate(cfg.stem_pattern):
        x, c, adm = pf(params["stem"][i], bt=bt, x=x, positions=positions)
        stem_caches.append(c)
        adm_sum, adm_n = adm_sum + adm, adm_n + 1
    if stem_caches:
        caches["stem"] = tuple(stem_caches)

    x = constrain_tokens(x)

    def body(carry, bp):
        xc, asum = carry
        xc = constrain_tokens(xc)
        bl_caches = {}
        for i, bt in enumerate(cfg.block_pattern):
            xc, c, adm = pf(bp[f"b{i}"], bt=bt, x=xc, positions=positions)
            bl_caches[f"b{i}"] = c
            asum = asum + adm
        return (constrain_tokens(xc), asum), bl_caches

    (x, adm_sum), blk_caches = jax.lax.scan(body, (x, adm_sum),
                                            params["blocks"], unroll=scan_unroll)
    adm_n += cfg.n_repeats * max(cfg.attn_blocks_per_pattern, 1)
    caches["blocks"] = blk_caches
    if opts.evict_hard_budget is not None:
        caches["obs"] = _init_obs_tree(cfg, b, opts)
    hidden = _norm(cfg, params["ln_f"], x)
    logits = L.unembed(params["embed"], hidden[:, -1])
    return PrefillOut(logits, hidden, adm_sum / max(adm_n, 1)), caches


def _init_obs_tree(cfg: ModelConfig, b: int, opts: DecodeOptions):
    one = lambda: EV.init_obs(b, cfg.n_heads, cfg.head_dim, opts.w_obs,
                              jnp.dtype(cfg.dtype))
    n_attn = cfg.attn_blocks_per_pattern
    stacked = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (cfg.n_repeats, n_attn) + x.shape),
        one())
    return stacked


# ==========================================================================
# decode
# ==========================================================================
def _quest_mask(cfg: ModelConfig, cache: DualCache, q: jax.Array,
                pages: int) -> jax.Array:
    """Read-time Selection over the *global* cache (local + self always
    visible). Returns [B, Hkv, C + W + 1] bool. Scores the cache's
    incrementally-maintained page metadata (no O(C) rebuild per step)."""
    c = cache.budget
    assert c % SEL.PAGE_SIZE == 0, "global budget must be page-aligned for Quest"
    gvalid = jnp.arange(c)[None, None] < cache.gcnt[..., None]
    p_pages = c // SEL.PAGE_SIZE
    meta = SEL.PageMeta(cache.pkmin, cache.pkmax,
                        SEL.page_valid_from_count(cache.gcnt, p_pages))
    pmask = SEL.select_pages(q, meta, pages)
    gmask = SEL.token_mask_from_pages(pmask) & gvalid
    b, h = gvalid.shape[:2]
    rest = jnp.ones((b, h, cache.w_local), bool)  # local ring always visible
    return jnp.concatenate([gmask, rest], axis=-1)  # jaxlint: allow-concat(joins along the kv-position axis - batch axis untouched)


def _attn_block_decode(p, cfg: ModelConfig, bt: str, x_t, cache, *,
                       opts: DecodeOptions, obs=None, moe_groups: int):
    xin = _norm(cfg, p["ln1"], x_t[:, None])[:, 0]
    self_cache = cache["self"] if bt == "attn_cross" else cache
    window = cfg.sliding_window if bt == "local_attn" else None
    trig = jnp.zeros((), jnp.float32)
    adm = None
    selp = None
    if isinstance(self_cache, DualCache):
        sel_fn = None
        if opts.quest_pages is not None:
            sel_fn = lambda cache, q: _quest_mask(cfg, cache, q, opts.quest_pages)
        h, new_cache, g_new, sel_pages = A.attn_decode_wgkv(
            p["attn"], cfg, xin, self_cache, token_select_fn=sel_fn,
            select_pages_k=parse_selection_policy(opts.selection_policy),
            gate_override=_static_gates(cfg, opts, self_cache.t))
        adm = (g_new >= cfg.wgkv.tau).mean(axis=-1)  # per-row [B]
        if sel_pages is not None:
            selp = sel_pages.astype(jnp.float32).mean(axis=-1)  # per-row [B]
        if opts.evict_hard_budget is not None and obs is not None:
            q_obs = A._heads((xin[:, None] @ p["attn"]["w_q"].astype(xin.dtype)),
                             cfg.n_heads, cfg.head_dim)[:, :, 0]
            obs = EV.push_query(obs, q_obs)
            new_cache, trg = EV.maybe_evict(
                new_cache, obs, hard_budget=opts.evict_hard_budget,
                evict_frac=opts.evict_frac)
            trig = trg.astype(jnp.float32).mean(axis=-1)  # per-row [B]
    else:
        h, new_cache = A.attn_decode_dense(p["attn"], cfg, xin, self_cache,
                                           window=window)
    x_t = x_t + h
    if bt == "attn_cross":
        x_t = x_t + A.attn_cross(p["xattn"], cfg,
                                 _norm(cfg, p["ln_x"], x_t[:, None]),
                                 cache["cross"])[:, 0]
        new_cache = {"self": new_cache, "cross": cache["cross"]}
    if bt == "attn_moe":
        y, _ = MoE.moe_ffn(p["moe"], cfg, _norm(cfg, p["ln2"], x_t[:, None]),
                           groups=moe_groups)
        x_t = x_t + y[:, 0]
    elif bt == "attn_cross" or cfg.arch_type == "audio":
        x_t = x_t + L.gelu_mlp(p["mlp"], _norm(cfg, p["ln2"], x_t[:, None]))[:, 0]
    else:
        x_t = x_t + L.swiglu(p["mlp"], _norm(cfg, p["ln2"], x_t[:, None]))[:, 0]
    return x_t, new_cache, obs, trig, adm, selp


def _block_decode(p, cfg: ModelConfig, bt: str, x_t, cache, *, opts, obs,
                  moe_groups):
    if bt in ("attn", "attn_moe", "local_attn", "attn_cross"):
        return _attn_block_decode(p, cfg, bt, x_t, cache, opts=opts, obs=obs,
                                  moe_groups=moe_groups)
    zero = jnp.zeros((), jnp.float32)
    if bt == "rglru":
        y, state = RG.rglru_step(p["rec"], cfg,
                                 _norm(cfg, p["ln1"], x_t[:, None])[:, 0], cache)
        x_t = x_t + y
        x_t = x_t + L.swiglu(p["mlp"], _norm(cfg, p["ln2"], x_t[:, None]))[:, 0]
        return x_t, state, obs, zero, None, None
    if bt == "mlstm":
        x_t, state = XL.mlstm_step(p["cell"], cfg, x_t, cache)
        return x_t, state, obs, zero, None, None
    if bt == "slstm":
        x_t, state = XL.slstm_step(p["cell"], cfg, x_t, cache)
        return x_t, state, obs, zero, None, None
    raise ValueError(bt)


def decode_step(params: Params, cfg: ModelConfig, token: jax.Array,
                caches: CacheTree, *, moe_groups: int = 1,
                opts: DecodeOptions = DecodeOptions(),
                scan_unroll: bool = False
                ) -> Tuple[jax.Array, CacheTree, Dict[str, jax.Array]]:
    """token: [B] int32 -> (logits [B, V], new caches, stats)."""
    dt = jnp.dtype(cfg.dtype)
    x = L.embed(params["embed"], token[:, None], dt)[:, 0]  # [B, D]
    b = x.shape[0]
    t = caches["t"]
    if cfg.is_encdec:
        # sinusoid at per-batch position t
        dmax = cfg.d_model
        inv = jnp.exp(-jnp.log(10000.0) * jnp.arange(dmax // 2) / max(dmax // 2 - 1, 1))
        ang = t[:, None].astype(jnp.float32) * inv[None]
        x = x + jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1).astype(dt)  # jaxlint: allow-concat(feature-axis sinusoid halves - batch axis untouched)

    new_caches: CacheTree = {"t": t + 1}
    trig_sum = jnp.zeros((b,), jnp.float32)  # per-row eviction triggers
    adm_sum = jnp.zeros((b,), jnp.float32)  # per-row: batch rows may be dead
    adm_n = jnp.zeros((), jnp.float32)
    sel_sum = jnp.zeros((b,), jnp.float32)  # per-row selected pages (layer sum)
    bd = functools.partial(_block_decode, cfg=cfg, opts=opts,
                           moe_groups=moe_groups)
    stem_new = []
    for i, bt in enumerate(cfg.stem_pattern):
        x, c, _, trg, adm, selp = bd(params["stem"][i], bt=bt, x_t=x,
                                     cache=caches["stem"][i], obs=None)
        stem_new.append(c)
        trig_sum = trig_sum + trg
        if adm is not None:
            adm_sum, adm_n = adm_sum + adm, adm_n + 1.0
        if selp is not None:
            sel_sum = sel_sum + selp
    if stem_new:
        new_caches["stem"] = tuple(stem_new)

    has_obs = "obs" in caches

    x = constrain_tokens(x)

    def body(carry, xs):
        xc, trig, asum, an, ssum = carry
        xc = constrain_tokens(xc)
        if has_obs:
            bp, bc, obs_b = xs
        else:
            bp, bc = xs
            obs_b = None
        new_bc = {}
        new_obs = []
        ai = 0
        for i, bt in enumerate(cfg.block_pattern):
            obs_i = None
            if obs_b is not None and bt in ("attn", "attn_moe", "local_attn", "attn_cross"):
                obs_i = jax.tree.map(lambda v: v[ai], obs_b)
            xc, c, obs_o, trg, adm, selp = bd(bp[f"b{i}"], bt=bt, x_t=xc,
                                              cache=bc[f"b{i}"], obs=obs_i)
            new_bc[f"b{i}"] = c
            if obs_i is not None:
                new_obs.append(obs_o)
                ai += 1
            trig = trig + trg
            if adm is not None:
                asum, an = asum + adm, an + 1.0
            if selp is not None:
                ssum = ssum + selp
        # jaxlint: allow-concat(stacks per-repeat obs on a NEW leading axis - rows replicate)
        ys = (new_bc, jax.tree.map(lambda *v: jnp.stack(v), *new_obs)) if new_obs \
            else (new_bc,)
        return (xc, trig, asum, an, ssum), ys

    xs = (params["blocks"], caches["blocks"], caches["obs"]) if has_obs \
        else (params["blocks"], caches["blocks"])
    (x, trig_sum, adm_sum, adm_n, sel_sum), ys = jax.lax.scan(
        body, (x, trig_sum, adm_sum, adm_n, sel_sum), xs, unroll=scan_unroll)
    new_caches["blocks"] = ys[0]
    if has_obs:
        new_caches["obs"] = ys[1]
    hidden = _norm(cfg, params["ln_f"], x[:, None])[:, 0]
    logits = L.unembed(params["embed"], hidden)
    return logits, new_caches, {
        "evict_triggers": trig_sum.mean(),
        # per-row [B] so serving backends can re-sync the paged mirror for
        # (and average admission over) live slots only
        "evict_trigger_rows": trig_sum,
        "mean_admission": adm_sum / jnp.maximum(adm_n, 1.0),
        # per-row pages gathered this step under selection_policy (mean
        # over kv heads, summed over attention layers; zeros when off)
        "selected_pages_rows": sel_sum}


def prefill_extend(params: Params, cfg: ModelConfig, tokens: jax.Array,
                   caches: CacheTree, *, moe_groups: int = 1,
                   opts: DecodeOptions = DecodeOptions(),
                   scan_unroll: bool = False
                   ) -> Tuple[jax.Array, CacheTree, Dict[str, jax.Array]]:
    """Teacher-forced multi-token cache extension (chunked prefill).

    Feeds ``tokens`` [B, S] one position at a time through
    :func:`decode_step` under a scan, so a prompt can be processed in
    bounded chunks interleaved with other requests' decode steps. The
    resulting cache state matches a one-shot prefill over the
    concatenated sequence (lazy promotion admits exactly the same tokens
    the write-gate bias admits at prefill time); a single device call per
    chunk keeps it schedulable. Returns (logits of the LAST fed position
    [B, V], caches, stats)."""
    def body(carry, tok):
        logits, new_caches, st = decode_step(
            params, cfg, tok, carry, moe_groups=moe_groups, opts=opts,
            scan_unroll=scan_unroll)
        return new_caches, (logits, st["evict_triggers"], st["mean_admission"])
    caches, (logits, trig, adm) = jax.lax.scan(body, caches, tokens.T)
    return logits[-1], caches, {"evict_triggers": trig.sum(),
                                "mean_admission": adm.mean()}


def prefill_extend_ragged(params: Params, cfg: ModelConfig,
                          tokens: jax.Array, lengths: jax.Array,
                          caches: CacheTree, *, moe_groups: int = 1,
                          opts: DecodeOptions = DecodeOptions(),
                          scan_unroll: bool = False
                          ) -> Tuple[jax.Array, CacheTree,
                                     Dict[str, jax.Array]]:
    """Ragged multi-row chunked prefill: advance B tasks in ONE scan.

    ``tokens`` [B, S] holds each row's next prompt chunk left-aligned;
    ``lengths`` [B] says how many of those S positions are real. Every
    position runs through :func:`decode_step` exactly like the batch-1
    extend, but all cache writes (KV, ring pointer, gate/eviction state)
    at positions >= ``lengths[i]`` are masked out by a per-row select
    against the pre-step tree — a short row's final cache state is
    bit-identical to running the sequential scan over its real tokens
    only, and a length-0 row is pure padding. Returns

      * ``last_logits`` [B, V]: each row's logits at its LAST real
        position (zeros for length-0 rows — the caller keeps its prior
        logits for those),
      * the advanced caches,
      * per-row stats ``{"evict_trigger_rows": [B], "adm_sum_rows":
        [B]}`` (sums over that row's real positions only), so serving
        backends can account admission/eviction per request.

    Rows are independent per position, which is what the fused serving
    megabatch tick (serving/engine.py ``step_batch``) builds on: a
    FIRST-CHUNK row is just a freshly-spliced EMPTY cache row (per-row
    ``t`` starts its scan at position 0 — no separate batch-1 open
    path), and a live DECODE row rides along as a length-1 row whose
    single position computes exactly the batch-1 ``decode_step`` —
    so opens, mid-prefill extends, and decode steps share this one
    compiled call.
    """
    # batch axes differ per subtree ("t"/stem batch-leading, "blocks"
    # stacked [n_repeats, B, ...], "obs" [n_repeats, n_attn, B, ...]);
    # the splice helpers own that rule (lazy import: no load-time cycle)
    from repro.launch.specs import cache_batch_axis

    b, s = tokens.shape
    active_mat = (jnp.arange(s, dtype=jnp.int32)[:, None]
                  < lengths[None, :].astype(jnp.int32))       # [S, B]
    logits_s = jax.eval_shape(
        lambda c: decode_step(params, cfg, tokens[:, 0], c,
                              moe_groups=moe_groups, opts=opts,
                              scan_unroll=scan_unroll)[0], caches)

    def body(carry, xs):  # jaxlint: masked-scan-body
        old, last_logits = carry
        tok, active = xs                                      # [B], [B] bool

        def keep(path, new_leaf, old_leaf):
            shape = [1] * jnp.ndim(new_leaf)
            shape[cache_batch_axis(path)] = b
            return jnp.where(active.reshape(shape), new_leaf, old_leaf)

        logits, new, st = decode_step(params, cfg, tok, old,
                                      moe_groups=moe_groups, opts=opts,
                                      scan_unroll=scan_unroll)
        merged = jax.tree_util.tree_map_with_path(keep, new, old)
        last_logits = jnp.where(active[:, None], logits, last_logits)
        trig = jnp.where(active, st["evict_trigger_rows"], 0.0)
        adm = jnp.where(active, st["mean_admission"], 0.0)
        selp = jnp.where(active, st["selected_pages_rows"], 0.0)
        return (merged, last_logits), (trig, adm, selp)

    init = (caches, jnp.zeros(logits_s.shape, logits_s.dtype))
    (caches, last_logits), (trig, adm, selp) = jax.lax.scan(
        body, init, (tokens.T, active_mat))
    return last_logits, caches, {"evict_trigger_rows": trig.sum(axis=0),
                                 "adm_sum_rows": adm.sum(axis=0),
                                 "selected_pages_rows": selp.sum(axis=0)}
