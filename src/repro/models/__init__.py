from repro.models import (  # noqa: F401
    attention,
    inference,
    layers,
    moe,
    registry,
    rglru,
    transformer,
    xlstm,
)
