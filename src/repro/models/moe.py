"""Mixture-of-Experts FFN with top-k routing and capacity-bounded,
argsort-based dispatch (no giant one-hot tensors; expert-parallel friendly).

Tokens are routed in ``groups`` (one per data shard in the distributed
setting) so the dispatch buffer is [G, E, C, D] with G sharded over "data"
and E over "model" — the all-to-all pattern the paper-pool MoE archs
(qwen3-moe, granite-moe) need.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L

Params = Dict[str, jax.Array]


def init_moe(key: jax.Array, cfg: ModelConfig) -> Params:
    assert cfg.moe is not None
    dt = jnp.dtype(cfg.param_dtype)
    d, e, f = cfg.d_model, cfg.moe.n_experts, cfg.moe.expert_d_ff
    ks = jax.random.split(key, 4)
    return {
        "router": L.dense_init(ks[0], (d, e), dt, scale=0.02),
        "w_gate": L.dense_init(ks[1], (e, d, f), dt),
        "w_up": L.dense_init(ks[2], (e, d, f), dt),
        "w_down": L.dense_init(ks[3], (e, f, d), dt),
    }


def _capacity(tokens_per_group: int, n_experts: int, top_k: int,
              factor: float) -> int:
    c = int(tokens_per_group * top_k / n_experts * factor) + 1
    return max(4, -(-c // 4) * 4)  # round up to multiple of 4


def _dispatch_one_group(x, probs, top_idx, top_w, capacity, n_experts):
    """x: [T, D]; top_idx/top_w: [T, K]. Returns (y [T, D], load [E])."""
    t, k = top_idx.shape
    flat_e = top_idx.reshape(t * k)
    flat_tok = jnp.repeat(jnp.arange(t), k)
    flat_w = top_w.reshape(t * k)
    order = jnp.argsort(flat_e, stable=True)
    se = flat_e[order]
    stok = flat_tok[order]
    sw = flat_w[order]
    start = jnp.searchsorted(se, jnp.arange(n_experts), side="left")
    # slot position of each sorted entry within its expert
    slot = jnp.arange(t * k) - start[se]
    keep = slot < capacity
    # build [E, C] -> sorted-position table
    pos_ec = start[:, None] + jnp.arange(capacity)[None]          # [E, C]
    in_range = pos_ec < jnp.searchsorted(se, jnp.arange(n_experts), side="right")[:, None]
    pos_ec = jnp.minimum(pos_ec, t * k - 1)
    tok_ec = stok[pos_ec]                                         # [E, C]
    w_ec = jnp.where(in_range, sw[pos_ec], 0.0)                   # [E, C]
    valid_ec = in_range
    x_ec = x[tok_ec] * valid_ec[..., None].astype(x.dtype)        # [E, C, D]
    load = jax.ops.segment_sum(keep.astype(jnp.float32), se,
                               num_segments=n_experts)
    return x_ec, tok_ec, w_ec, valid_ec, load


def moe_ffn(p: Params, cfg: ModelConfig, x: jax.Array, *, groups: int = 1
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x: [B, S, D] -> (y [B, S, D], aux dict with load-balance loss)."""
    mc = cfg.moe
    b, s, d = x.shape
    tot = b * s
    assert tot % groups == 0, (tot, groups)
    tg = tot // groups
    e, k = mc.n_experts, mc.top_k
    cap = _capacity(tg, e, k, mc.capacity_factor)
    xf = x.reshape(groups, tg, d)
    logits = xf @ p["router"].astype(x.dtype)                     # [G, Tg, E]
    probs = jax.nn.softmax(logits.astype(jnp.float32), -1)
    top_w, top_idx = jax.lax.top_k(probs, k)                      # [G, Tg, K]
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    disp = jax.vmap(
        lambda xx, pp, ti, tw: _dispatch_one_group(xx, pp, ti, tw, cap, e)
    )(xf, probs, top_idx, top_w)
    x_ec, tok_ec, w_ec, valid_ec, load = disp                     # [G, E, C, *]

    # pin shardings: groups over data, experts over model. Without these
    # XLA's backward pass replicates [G, E, C, D]-shaped tensors over the
    # data axis, inflating all-reduce traffic ~G-fold (EXPERIMENTS.md §Perf)
    from repro.sharding.rules import constrain_moe

    x_ec = constrain_moe(x_ec, "dispatch")
    h = jnp.einsum("gecd,edf->gecf", x_ec, p["w_gate"].astype(x.dtype))
    u = jnp.einsum("gecd,edf->gecf", x_ec, p["w_up"].astype(x.dtype))
    yo = jnp.einsum("gecf,efd->gecd", jax.nn.silu(h) * u,
                    p["w_down"].astype(x.dtype))
    yo = yo * (w_ec[..., None] * valid_ec[..., None]).astype(yo.dtype)
    yo = constrain_moe(yo, "dispatch")

    def combine(y_e, tok_e):
        return jax.ops.segment_sum(y_e.reshape(e * cap, d),
                                   tok_e.reshape(e * cap), num_segments=tg)

    y = constrain_moe(jax.vmap(combine)(yo, tok_ec), "grouped")
    y = y.reshape(b, s, d)

    # Switch-style load-balance aux loss
    frac_tokens = load / jnp.maximum(load.sum(-1, keepdims=True), 1.0)  # [G,E]
    mean_prob = probs.mean(axis=1)                                      # [G,E]
    lb = e * (frac_tokens * mean_prob).sum(-1).mean()
    dropped = 1.0 - load.sum() / (groups * tg * k)
    return y, {"lb_loss": lb, "router_drop_frac": dropped}
