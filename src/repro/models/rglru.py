"""Griffin / RecurrentGemma recurrent block: temporal conv + RG-LRU.

RG-LRU (Real-Gated Linear Recurrent Unit):
    r_t = sigmoid(W_r x_t)          recurrence gate (block-diagonal per head)
    i_t = sigmoid(W_i x_t)          input gate
    a_t = exp(c * r_t * log sigmoid(Lambda))       (a = sigmoid(Λ)^(c·r))
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t^2) ⊙ (i_t ⊙ x_t)

TPU adaptation: the linear recurrence is evaluated with
``jax.lax.associative_scan`` (log-depth, fully unrolled in HLO — no hidden
while-loop, exact roofline accounting) instead of a sequential CUDA scan.
A Pallas kernel (kernels/rglru_scan.py) provides the blocked VMEM-resident
variant for the TPU hot path.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L

Params = Dict[str, jax.Array]
_C = 8.0  # Griffin's gate sharpness constant


class RGLRUState(NamedTuple):
    conv: jax.Array  # [B, cw-1, dr] trailing conv inputs
    h: jax.Array     # [B, dr]


def init_rglru(key: jax.Array, cfg: ModelConfig) -> Params:
    dt = jnp.dtype(cfg.param_dtype)
    d = cfg.d_model
    dr = int(cfg.rglru_expand * d)
    hb = cfg.n_heads  # block-diagonal gate blocks
    dh = dr // hb
    ks = jax.random.split(key, 7)
    # Lambda init so that a ~ Uniform(0.9, 0.999)^... Griffin: a init in [0.9, 0.999]
    u = jax.random.uniform(ks[5], (dr,), minval=0.9, maxval=0.999)
    lam = jnp.log(u ** (1.0 / _C) / (1 - u ** (1.0 / _C)))  # sigmoid^-1
    return {
        "w_gelu": L.dense_init(ks[0], (d, dr), dt),
        "w_x": L.dense_init(ks[1], (d, dr), dt),
        "conv": (jax.random.normal(ks[2], (cfg.rglru_conv_width, dr)) * 0.02).astype(dt),
        "w_r": L.dense_init(ks[3], (hb, dh, dh), dt),
        "b_r": jnp.zeros((dr,), dt),
        "w_i": L.dense_init(ks[4], (hb, dh, dh), dt),
        "b_i": jnp.zeros((dr,), dt),
        "lam": lam.astype(jnp.float32),
        "w_out": L.dense_init(ks[6], (dr, d), dt),
    }


def _blockdiag(x: jax.Array, w: jax.Array) -> jax.Array:
    """x: [..., dr]; w: [H, dh, dh] -> [..., dr]."""
    hb, dh, _ = w.shape
    xs = x.reshape(x.shape[:-1] + (hb, dh))
    y = jnp.einsum("...hd,hde->...he", xs, w.astype(x.dtype))
    return y.reshape(x.shape)


def _causal_conv(x: jax.Array, kernel: jax.Array,
                 state: jax.Array | None) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal temporal conv. x: [B, S, dr]; kernel: [cw, dr].
    state: [B, cw-1, dr] trailing context (zeros at sequence start).
    Returns (y [B, S, dr], new_state)."""
    cw = kernel.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], cw - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)  # [B, S+cw-1, dr]
    y = sum(xp[:, i:i + x.shape[1]] * kernel[i].astype(x.dtype)
            for i in range(cw))
    return y, xp[:, -(cw - 1):]


def _rg_lru_gates(p: Params, xc: jax.Array):
    r = jax.nn.sigmoid(_blockdiag(xc, p["w_r"]) + p["b_r"].astype(xc.dtype))
    i = jax.nn.sigmoid(_blockdiag(xc, p["w_i"]) + p["b_i"].astype(xc.dtype))
    log_a = _C * r.astype(jnp.float32) * jax.nn.log_sigmoid(p["lam"])
    a = jnp.exp(log_a)
    gated = (i.astype(jnp.float32) * xc.astype(jnp.float32)
             * jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)))
    return a, gated


def rglru_scan(a: jax.Array, b: jax.Array, h0: jax.Array | None) -> jax.Array:
    """h_t = a_t h_{t-1} + b_t via associative scan over axis 1 (time).
    a, b: [B, S, dr] float32. h0: [B, dr] or None."""
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a2 * a1, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def rglru_block(p: Params, cfg: ModelConfig, x: jax.Array,
                state: RGLRUState | None = None
                ) -> Tuple[jax.Array, RGLRUState]:
    """Full-sequence forward. x: [B, S, D] -> (y [B, S, D], final state)."""
    x1 = jax.nn.gelu(x @ p["w_gelu"].astype(x.dtype))
    x2 = x @ p["w_x"].astype(x.dtype)
    conv_state = state.conv if state is not None else None
    xc, new_conv = _causal_conv(x2, p["conv"], conv_state)
    a, gated = _rg_lru_gates(p, xc)
    h0 = state.h if state is not None else None
    h = rglru_scan(a, gated, h0)
    y = (h.astype(x.dtype) * x1) @ p["w_out"].astype(x.dtype)
    return y, RGLRUState(conv=new_conv, h=h[:, -1])


def rglru_step(p: Params, cfg: ModelConfig, x_t: jax.Array,
               state: RGLRUState) -> Tuple[jax.Array, RGLRUState]:
    """Single decode step. x_t: [B, D]."""
    x1 = jax.nn.gelu(x_t @ p["w_gelu"].astype(x_t.dtype))
    x2 = x_t @ p["w_x"].astype(x_t.dtype)
    cw = p["conv"].shape[0]
    window = jnp.concatenate([state.conv.astype(x2.dtype), x2[:, None]], 1)  # [B, cw, dr]
    xc = jnp.einsum("bcd,cd->bd", window, p["conv"].astype(x2.dtype))
    a, gated = _rg_lru_gates(p, xc)
    h = a * state.h.astype(jnp.float32) + gated
    y = (h.astype(x_t.dtype) * x1) @ p["w_out"].astype(x_t.dtype)
    return y, RGLRUState(conv=window[:, 1:], h=h)


def init_rglru_state(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> RGLRUState:
    dr = int(cfg.rglru_expand * cfg.d_model)
    return RGLRUState(
        conv=jnp.zeros((batch, cfg.rglru_conv_width - 1, dr), dtype),
        h=jnp.zeros((batch, dr), jnp.float32),
    )
