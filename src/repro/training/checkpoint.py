"""Minimal npz checkpointing for param / gate / optimizer pytrees."""
from __future__ import annotations

import json
import os
from typing import Any, Dict

import jax.numpy as jnp
import numpy as np


def _flatten(tree, prefix=()):
    if isinstance(tree, dict):
        for k, v in tree.items():
            yield from _flatten(v, prefix + (k,))
    elif isinstance(tree, (tuple, list)):
        for i, v in enumerate(tree):
            yield from _flatten(v, prefix + (str(i),))
    else:
        yield "/".join(prefix), tree


def save(path: str, tree: Any, meta: Dict | None = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = dict(_flatten(tree))
    arrays = {k: np.asarray(v) for k, v in flat.items()}
    np.savez(path, **arrays)
    if meta is not None:
        with open(path + ".meta.json", "w") as f:
            json.dump(meta, f, indent=2)


def restore(path: str, like: Any) -> Any:
    """Restore into the structure of ``like`` (same treedef)."""
    if not path.endswith(".npz"):
        path = path + ".npz"
    data = np.load(path)

    def rebuild(tree, prefix=()):
        if isinstance(tree, dict):
            return {k: rebuild(v, prefix + (k,)) for k, v in tree.items()}
        if isinstance(tree, tuple):
            return tuple(rebuild(v, prefix + (str(i),)) for i, v in enumerate(tree))
        if isinstance(tree, list):
            return [rebuild(v, prefix + (str(i),)) for i, v in enumerate(tree)]
        key = "/".join(prefix)
        arr = data[key]
        return jnp.asarray(arr, dtype=tree.dtype if hasattr(tree, "dtype") else None)

    return rebuild(like)


def load_meta(path: str) -> Dict:
    with open(path + ".meta.json") as f:
        return json.load(f)
