"""Gate-only distillation trainer (paper §3.3, Appendix C).

The backbone is FROZEN: only Write-Gate MLP parameters are optimized. We
extract the gate sub-leaves into a flat dict so (a) grads/Adam moments
exist only for ~0.4% of parameters and (b) XLA never emits dW matmuls for
the backbone (it is a closed-over constant, not a differentiated input).

    L_total = || h_gated - h_teacher ||^2  +  lambda * L_sparsity(g)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.losses import total_loss
from repro.models import transformer as T
from repro.training.optimizer import AdamWState, adamw_init, adamw_update

GateDict = Dict[str, jax.Array]


# ==========================================================================
# gate-parameter extraction / injection
# ==========================================================================
def _walk_paths(tree, prefix=()):
    if isinstance(tree, dict):
        for k, v in tree.items():
            yield from _walk_paths(v, prefix + (k,))
    elif isinstance(tree, (tuple, list)):
        for i, v in enumerate(tree):
            yield from _walk_paths(v, prefix + (str(i),))
    else:
        yield prefix, tree


def get_gates(params) -> GateDict:
    out = {}
    for path, leaf in _walk_paths(params):
        if "gate" in path:
            out["/".join(path)] = leaf
    return out


def set_gates(params, gates: GateDict):
    def rebuild(tree, prefix=()):
        if isinstance(tree, dict):
            return {k: rebuild(v, prefix + (k,)) for k, v in tree.items()}
        if isinstance(tree, tuple):
            return tuple(rebuild(v, prefix + (str(i),)) for i, v in enumerate(tree))
        if isinstance(tree, list):
            return [rebuild(v, prefix + (str(i),)) for i, v in enumerate(tree)]
        key = "/".join(prefix)
        return gates.get(key, tree) if "gate" in prefix else tree

    return rebuild(params)


# ==========================================================================
# loss / step
# ==========================================================================
def distill_loss_fn(gates: GateDict, params, cfg: ModelConfig, batch,
                    *, lam: float, moe_groups: int = 1,
                    q_chunk: Optional[int] = None, remat: bool = False,
                    scan_unroll: bool = False):
    """batch: {"tokens": [B,S], "loss_mask": [B,S] or None, ...}."""
    p = set_gates(params, gates)
    kw = {}
    if "enc_embeds" in batch:
        kw["enc_embeds"] = batch["enc_embeds"]
    if "positions" in batch:
        kw["positions"] = batch["positions"]
    if "embeds" in batch:
        kw["embeds"] = batch["embeds"]
    teacher = T.forward(p, cfg, batch.get("tokens"), mode="teacher",
                        with_logits=False, moe_groups=moe_groups,
                        q_chunk=q_chunk, remat=remat,
                        scan_unroll=scan_unroll, **kw)
    student = T.forward(p, cfg, batch.get("tokens"), mode="gated",
                        with_logits=False, moe_groups=moe_groups,
                        q_chunk=q_chunk, remat=remat,
                        scan_unroll=scan_unroll, **kw)
    h_t = jax.lax.stop_gradient(teacher.hidden)
    loss, aux = total_loss(student.hidden, h_t, student.gates, lam,
                           batch.get("loss_mask"))
    return loss, aux


class TrainState(NamedTuple):
    gates: GateDict
    opt: AdamWState


def init_train_state(params) -> TrainState:
    # copy: train steps donate the state; without the copy the first step
    # would delete the gate buffers still referenced by ``params``
    gates = jax.tree.map(jnp.copy, get_gates(params))
    return TrainState(gates, adamw_init(gates))


def train_step(state: TrainState, params, cfg: ModelConfig, batch, *,
               lr, lam: Optional[float] = None, moe_groups: int = 1,
               q_chunk: Optional[int] = None, remat: bool = False,
               scan_unroll: bool = False
               ) -> Tuple[TrainState, Dict[str, jax.Array]]:
    lam = cfg.wgkv.lam if lam is None else lam
    (loss, aux), grads = jax.value_and_grad(distill_loss_fn, has_aux=True)(
        state.gates, params, cfg, batch, lam=lam, moe_groups=moe_groups,
        q_chunk=q_chunk, remat=remat, scan_unroll=scan_unroll)
    new_gates, new_opt = adamw_update(grads, state.opt, state.gates, lr=lr)
    metrics = dict(aux, loss=loss)
    return TrainState(new_gates, new_opt), metrics


def make_train_step(cfg: ModelConfig, *, lr, lam=None, moe_groups=1,
                    q_chunk=None, remat=False, scan_unroll=False, donate=True):
    fn = functools.partial(train_step, cfg=cfg, lr=lr, lam=lam,
                           moe_groups=moe_groups, q_chunk=q_chunk,
                           remat=remat, scan_unroll=scan_unroll)
    return jax.jit(fn, donate_argnums=(0,) if donate else ())


# ==========================================================================
# standard LM training (for WG-KV-inapplicable archs, e.g. xlstm — no gates
# to distill; train_4k exercises full-parameter training instead)
# ==========================================================================
class LMTrainState(NamedTuple):
    params: Any
    opt: AdamWState


def init_lm_train_state(params) -> "LMTrainState":
    return LMTrainState(params, adamw_init(params))


def lm_loss_fn(params, cfg: ModelConfig, batch, *, moe_groups=1,
               q_chunk=None, remat=False, scan_unroll=False):
    kw = {k: batch[k] for k in ("enc_embeds", "positions", "embeds")
          if k in batch}
    out = T.forward(params, cfg, batch.get("tokens"), mode="teacher",
                    moe_groups=moe_groups, q_chunk=q_chunk, remat=remat,
                    scan_unroll=scan_unroll, **kw)
    from repro.data.synthetic import lm_loss
    ll = lm_loss(out.logits, batch["tokens"], batch.get("loss_mask"))
    return ll + 0.01 * out.lb_loss, {"lm_loss": ll, "lb_loss": out.lb_loss}


def lm_train_step(state: "LMTrainState", cfg: ModelConfig, batch, *, lr,
                  moe_groups=1, q_chunk=None, remat=False, scan_unroll=False
                  ) -> Tuple["LMTrainState", Dict[str, jax.Array]]:
    (loss, aux), grads = jax.value_and_grad(lm_loss_fn, has_aux=True)(
        state.params, cfg, batch, moe_groups=moe_groups, q_chunk=q_chunk,
        remat=remat, scan_unroll=scan_unroll)
    new_params, new_opt = adamw_update(grads, state.opt, state.params, lr=lr)
    return LMTrainState(new_params, new_opt), dict(aux, loss=loss)
