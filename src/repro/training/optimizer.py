"""Pure-JAX AdamW with cosine schedule + linear warmup (paper Appendix C:
AdamW, wd 0.01, peak lr 1e-3, 10% warmup, cosine decay)."""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def cosine_schedule(peak_lr: float, total_steps: int, warmup_frac: float = 0.1):
    warmup = max(1, int(total_steps * warmup_frac))

    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / warmup
        prog = jnp.clip((step - warmup) / max(total_steps - warmup, 1), 0.0, 1.0)
        cos = peak_lr * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)

    return lr


def adamw_init(params: Any) -> AdamWState:
    z = jax.tree.map(jnp.zeros_like, params)
    return AdamWState(jnp.zeros((), jnp.int32), z, jax.tree.map(jnp.zeros_like, params))


def adamw_update(grads: Any, state: AdamWState, params: Any, *, lr,
                 b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
                 weight_decay: float = 0.01) -> Tuple[Any, AdamWState]:
    step = state.step + 1
    lr_t = lr(step) if callable(lr) else lr
    b1t = 1.0 - b1 ** step.astype(jnp.float32)
    b2t = 1.0 - b2 ** step.astype(jnp.float32)
    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state.m, grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state.v, grads)
    new_params = jax.tree.map(
        lambda p, m_, v_: p - lr_t * ((m_ / b1t) / (jnp.sqrt(v_ / b2t) + eps)
                                      + weight_decay * p),
        params, m, v)
    return new_params, AdamWState(step, m, v)
